//! spMV kernels on the simulated machine (paper Algorithms 1–2 + baselines).
//!
//! Convention shared by all kernels: the dense activation vector is
//! resident in the TCM at element offset 0 (the paper keeps activations in
//! the TCM and streams weights through the caches, §X); weights / indices
//! / indptr stream through the L1/L2 hierarchy at fp16/u16 width; results
//! are stored as fp16.

use crate::sim::machine::{Machine, MachineConfig, SimReport, Stream};
use crate::sparse::block::BlockSparse;
use crate::sparse::csr::Csr;
use crate::sparse::dense::Dense;
use crate::sparse::format::GsFormat;

/// Result vector + cycle report.
#[derive(Clone, Debug)]
pub struct SpmvOutput {
    pub y: Vec<f32>,
    pub report: SimReport,
}

fn machine_with_act(cfg: MachineConfig, act: &[f32]) -> Machine {
    let mut m = Machine::new(cfg);
    assert!(
        act.len() <= m.config.tcm.capacity_elems,
        "activations do not fit the TCM; partition first (paper §X)"
    );
    m.tcm.fill(0, act);
    m.reset(); // fill is DMA setup, not kernel time
    m
}

/// Dense spMV baseline: per row, stream `B`-wide weight vectors and load
/// matching activations sequentially from the TCM.
pub fn spmv_dense_sim(w: &Dense, act: &[f32], cfg: MachineConfig) -> SpmvOutput {
    assert_eq!(act.len(), w.cols);
    let b = cfg.tcm.subbanks;
    let mut m = machine_with_act(cfg, act);
    let mut y = vec![0.0f32; w.rows];
    let mut avec = vec![0.0f32; b];
    for r in 0..w.rows {
        m.row_prologue();
        let mut res = vec![0.0f32; b];
        let row = w.row(r);
        for (gi, chunk) in row.chunks(b).enumerate() {
            m.stream_load(Stream::Weights, chunk.len() * 2); // fp16 weights
            m.tcm_load_seq(gi * b, &mut avec[..chunk.len()]);
            m.simd_mac(chunk, &avec[..chunk.len()], &mut res[..chunk.len()]);
            m.loop_tick();
        }
        y[r] = m.simd_reduce(&res);
        m.store_result(2);
    }
    SpmvOutput { y, report: m.report() }
}

/// GS spMV (Algorithm 1 for `k=B`, Algorithm 2 for `k=1`, and the hybrid
/// and scatter generalizations — the group walk is identical; only the
/// epilogue differs: horizontal reduces one row per band, vertical/hybrid
/// store `B/k` per-row partials, scatter stores them through the engine).
pub fn spmv_gs_sim(gs: &GsFormat, act: &[f32], cfg: MachineConfig) -> SpmvOutput {
    spmv_gs_sim_impl(gs, act, cfg, false)
}

/// The §V "joined array" optimization: value and index arrays merged into
/// one buffer, so each group costs a single wide LSU load with better
/// cache locality ("which has better cache locality characteristics").
/// Compared against the separate-array kernel in
/// `benches/ablation_patterns.rs`.
pub fn spmv_gs_sim_joined(gs: &GsFormat, act: &[f32], cfg: MachineConfig) -> SpmvOutput {
    spmv_gs_sim_impl(gs, act, cfg, true)
}

fn spmv_gs_sim_impl(gs: &GsFormat, act: &[f32], cfg: MachineConfig, joined: bool) -> SpmvOutput {
    assert_eq!(act.len(), gs.cols);
    assert_eq!(cfg.tcm.subbanks, gs.b, "machine lanes must equal format B");
    let b = gs.b;
    let mut m = machine_with_act(cfg, act);
    // Output region lives in the TCM after the activations (aligned to B
    // so scatter residues match row numbers).
    let out_base = (act.len() + b - 1) / b * b;
    let mut y = vec![0.0f32; gs.rows];
    let mut gathered = vec![0.0f32; b];
    for band in 0..gs.nbands() {
        m.row_prologue(); // indptr[band] fetch + pointer setup
        m.stream_load(Stream::Indptr, 4);
        let mut res = vec![0.0f32; b];
        for g in gs.indptr[band] as usize..gs.indptr[band + 1] as usize {
            let vals = &gs.value[g * b..(g + 1) * b];
            let idx = &gs.index[g * b..(g + 1) * b];
            if joined {
                // One wide load of the interleaved [idx;vals] group.
                m.stream_load(Stream::Weights, b * 4);
            } else {
                m.stream_load(Stream::Weights, b * 2); // fp16 values
                m.stream_load(Stream::Indices, b * 2); // u16 offsets
            }
            m.gather(0, idx, &mut gathered);
            m.simd_mac(vals, &gathered, &mut res);
            m.loop_tick();
        }
        // Epilogue.
        if gs.band_rows() == 1 {
            // Horizontal: reduce all lanes into one output (Alg. 1 line 9).
            let row = gs.entry_row(band, 0);
            y[row] = m.simd_reduce(&res);
            m.store_result(2);
        } else {
            // Vertical/hybrid: lane block j/k holds row-slot partials; fold
            // the k lanes of each slot (free for k=1), then store B/k
            // results — sequentially for consecutive rows, or via an
            // engine scatter when a rowmap is present.
            if gs.k > 1 {
                m.simd_reduce(&res); // segmented fold modeled as one reduce
            }
            let slots = gs.band_rows();
            let mut outs = vec![0.0f32; slots];
            for (j, &v) in res.iter().enumerate() {
                outs[j / gs.k] += v;
            }
            let rows: Vec<usize> = (0..slots).map(|s| gs.entry_row(band, s * gs.k)).collect();
            if gs.rowmap.is_some() {
                let offsets: Vec<u32> = rows.iter().map(|&r| r as u32).collect();
                m.scatter(out_base, &offsets, &outs);
            } else {
                m.store_result(slots * 2);
            }
            for (s, &row) in rows.iter().enumerate() {
                y[row] = outs[s];
            }
        }
    }
    SpmvOutput { y, report: m.report() }
}

/// Block-sparse spMV baseline. `Block(B,B)` streams one B-wide weight
/// vector + one scalar block index per block and loads B consecutive
/// activations; `Block(B,k)` with `k<B` broadcasts k activations across
/// B/k row lanes.
pub fn spmv_block_sim(bs: &BlockSparse, act: &[f32], cfg: MachineConfig) -> SpmvOutput {
    assert_eq!(act.len(), bs.cols);
    assert_eq!(cfg.tcm.subbanks, bs.b, "machine lanes must equal block B");
    let b = bs.b;
    let br = bs.block_rows();
    let mut m = machine_with_act(cfg, act);
    let mut y = vec![0.0f32; bs.rows];
    let mut avec = vec![0.0f32; bs.k];
    for band in 0..bs.indptr.len() - 1 {
        m.row_prologue();
        m.stream_load(Stream::Indptr, 4);
        let mut res = vec![0.0f32; b];
        for blk in bs.indptr[band] as usize..bs.indptr[band + 1] as usize {
            let c0 = bs.index[blk] as usize * bs.k;
            m.stream_load(Stream::Weights, b * 2); // fp16 block payload
            m.stream_load(Stream::Indices, 2); // u16 block-column index
            m.tcm_load_seq(c0, &mut avec); // k consecutive activations
            // One SIMD MAC over all B lanes: lane (i*k+j) does
            // w[i][j] * a[c0+j] for row-slot i.
            let wv = &bs.value[blk * b..(blk + 1) * b];
            let abroad: Vec<f32> = (0..b).map(|l| avec[l % bs.k]).collect();
            m.simd_mac(wv, &abroad, &mut res);
            m.loop_tick();
        }
        // Epilogue mirrors the GS kernels: one reduce for k=B, a segmented
        // fold + vector store otherwise.
        if br == 1 {
            y[band] = m.simd_reduce(&res);
            m.store_result(2);
        } else {
            if bs.k > 1 {
                m.simd_reduce(&res);
            }
            for (l, &v) in res.iter().enumerate() {
                y[band * br + l / bs.k] += v;
            }
            m.store_result(br * 2);
        }
    }
    SpmvOutput { y, report: m.report() }
}

/// Irregular CSR on the gather engine (§IV's negative result): indices are
/// taken `B` at a time either in stored ascending order or greedily
/// reordered per row to minimize conflicts; bank-conflict serialization is
/// charged by the TCM model.
pub fn spmv_csr_sim(csr: &Csr, act: &[f32], cfg: MachineConfig, reorder: bool) -> SpmvOutput {
    assert_eq!(act.len(), csr.cols);
    let b = cfg.tcm.subbanks;
    let mut m = machine_with_act(cfg, act);
    let mut y = vec![0.0f32; csr.rows];
    for r in 0..csr.rows {
        m.row_prologue();
        m.stream_load(Stream::Indptr, 4);
        let lo = csr.indptr[r] as usize;
        let hi = csr.indptr[r + 1] as usize;
        let mut idx: Vec<u32> = csr.index[lo..hi].to_vec();
        let mut val: Vec<f32> = csr.value[lo..hi].to_vec();
        if reorder {
            // Greedy round-robin over residue buckets (the §IV mitigation;
            // reordering happens offline, so no cycle cost).
            let mut buckets: Vec<Vec<(u32, f32)>> = vec![Vec::new(); b];
            for (&i, &v) in idx.iter().zip(&val) {
                buckets[i as usize % b].push((i, v));
            }
            let mut ridx = Vec::with_capacity(idx.len());
            let mut rval = Vec::with_capacity(val.len());
            let mut level = 0;
            while ridx.len() < idx.len() {
                for bucket in &buckets {
                    if let Some(&(i, v)) = bucket.get(level) {
                        ridx.push(i);
                        rval.push(v);
                    }
                }
                level += 1;
            }
            idx = ridx;
            val = rval;
        }
        let mut res = vec![0.0f32; b];
        let mut gathered = vec![0.0f32; b];
        for (ichunk, vchunk) in idx.chunks(b).zip(val.chunks(b)) {
            m.stream_load(Stream::Weights, ichunk.len() * 2);
            m.stream_load(Stream::Indices, ichunk.len() * 2);
            m.gather(0, ichunk, &mut gathered[..ichunk.len()]);
            m.simd_mac(vchunk, &gathered[..ichunk.len()], &mut res[..ichunk.len()]);
            m.loop_tick();
        }
        y[r] = m.simd_reduce(&res);
        m.store_result(2);
    }
    SpmvOutput { y, report: m.report() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::native::gs_matvec;
    use crate::pruning::prune;
    use crate::sparse::pattern::Pattern;
    use crate::util::prng::Prng;

    fn pruned(rows: usize, cols: usize, p: Pattern, s: f64, seed: u64) -> Dense {
        let mut rng = Prng::new(seed);
        let mut w = Dense::random(rows, cols, 1.0, &mut rng);
        let mask = prune(&w, p, s).unwrap();
        w.apply_mask(&mask);
        w
    }

    fn assert_close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < 1e-3, "row {i}: {x} vs {y}");
        }
    }

    #[test]
    fn dense_sim_matches_oracle() {
        let mut rng = Prng::new(1);
        let w = Dense::random(16, 64, 1.0, &mut rng);
        let x = rng.normal_vec(64, 1.0);
        let out = spmv_dense_sim(&w, &x, MachineConfig::with_subbanks(8));
        assert_close(&out.y, &w.matvec(&x));
        assert!(out.report.cycles > 0);
        assert_eq!(out.report.conflict_slots, 0, "dense loads are sequential");
    }

    #[test]
    fn gs_sim_matches_native_and_dense_all_patterns() {
        let mut rng = Prng::new(2);
        for p in [
            Pattern::Gs { b: 8, k: 8 },
            Pattern::Gs { b: 8, k: 1 },
            Pattern::Gs { b: 8, k: 2 },
            Pattern::GsScatter { b: 8, k: 1 },
        ] {
            let w = pruned(32, 64, p, 0.7, 3);
            let gs = GsFormat::from_dense(&w, p).unwrap();
            let x = rng.normal_vec(64, 1.0);
            let out = spmv_gs_sim(&gs, &x, MachineConfig::with_subbanks(8));
            assert_close(&out.y, &w.matvec(&x));
            assert_close(&out.y, &gs_matvec(&gs, &x));
            if gs.rowmap.is_none() {
                assert_eq!(
                    out.report.conflict_slots, 0,
                    "{}: GS gathers must be conflict-free",
                    p.name()
                );
            } else {
                // Scatter pattern: activation gathers are conflict-free by
                // construction, but the per-band *output scatter* hits
                // whatever residues the permuted rows have — at most B-1
                // extra slots per band (the paper's "negligible overhead").
                assert!(
                    out.report.conflict_slots <= (gs.nbands() * (gs.b - 1)) as u64,
                    "scatter output conflicts exceed per-band bound"
                );
            }
        }
    }

    #[test]
    fn block_sim_matches_oracle() {
        for p in [Pattern::Block { b: 8, k: 8 }, Pattern::Block { b: 8, k: 1 }] {
            let w = pruned(32, 64, p, 0.7, 4);
            let bs = BlockSparse::from_dense(&w, p).unwrap();
            let mut rng = Prng::new(5);
            let x = rng.normal_vec(64, 1.0);
            let out = spmv_block_sim(&bs, &x, MachineConfig::with_subbanks(8));
            assert_close(&out.y, &w.matvec(&x));
        }
    }

    #[test]
    fn csr_sim_matches_oracle_and_counts_conflicts() {
        let w = pruned(32, 64, Pattern::Irregular, 0.7, 6);
        let csr = Csr::from_dense(&w);
        let mut rng = Prng::new(7);
        let x = rng.normal_vec(64, 1.0);
        let sorted = spmv_csr_sim(&csr, &x, MachineConfig::with_subbanks(8), false);
        let reordered = spmv_csr_sim(&csr, &x, MachineConfig::with_subbanks(8), true);
        assert_close(&sorted.y, &w.matvec(&x));
        assert_close(&reordered.y, &w.matvec(&x));
        assert!(
            sorted.report.conflict_slots >= reordered.report.conflict_slots,
            "reordering should not increase conflicts"
        );
        assert!(
            sorted.report.conflict_slots > 0,
            "irregular pattern should conflict somewhere"
        );
    }

    #[test]
    fn gs_faster_than_csr_at_same_nnz() {
        // The headline mechanism: identical sparsity, but load-balanced
        // groups beat conflict-ridden CSR chunks.
        let p = Pattern::Gs { b: 8, k: 8 };
        let w = pruned(64, 128, p, 0.8, 8);
        let gs = GsFormat::from_dense(&w, p).unwrap();
        let csr = Csr::from_dense(&w);
        let mut rng = Prng::new(9);
        let x = rng.normal_vec(128, 1.0);
        let gs_out = spmv_gs_sim(&gs, &x, MachineConfig::with_subbanks(8));
        let csr_out = spmv_csr_sim(&csr, &x, MachineConfig::with_subbanks(8), false);
        assert!(gs_out.report.cycles <= csr_out.report.cycles);
    }

    #[test]
    fn sparse_beats_dense_at_high_sparsity() {
        let p = Pattern::Gs { b: 16, k: 16 };
        let w = pruned(128, 256, p, 0.9, 10);
        let gs = GsFormat::from_dense(&w, p).unwrap();
        let mut rng = Prng::new(11);
        let x = rng.normal_vec(256, 1.0);
        let cfg = MachineConfig::with_subbanks(16);
        let dense_cycles = spmv_dense_sim(&w, &x, cfg).report.cycles;
        let gs_cycles = spmv_gs_sim(&gs, &x, cfg).report.cycles;
        assert!(
            gs_cycles * 2 < dense_cycles,
            "expected ≥2× speedup at 90%: dense {dense_cycles} vs GS {gs_cycles}"
        );
    }
}
