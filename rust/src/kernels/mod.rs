//! The paper's sparse kernels, in three guises.
//!
//! * [`native`] — plain f32 implementations (Algorithms 1–2 and the
//!   sparse convolution) used as numerics oracles and by the training
//!   orchestrator's CPU paths.
//! * [`exec`] — plan packing for the production CPU fast path: a
//!   prepacked [`exec::GsExecPlan`] (joined §V layout at f32 or the
//!   paper's f16 storage resolution, precomputed output slots, balanced
//!   chunks) that classifies its own geometry onto the specialized
//!   kernel menu at pack time. The legacy `gs_matmul*` entry points
//!   survive here as deprecated generic-pinned wrappers.
//! * [`dispatch`] — execution: [`exec::GsExecPlan::execute`] dispatches
//!   each call onto a [`dispatch::KernelVariant`] (generic,
//!   small-group-unrolled, lane-register-blocked, scatter-direct-write)
//!   picked by geometry classification, an optional time-boxed
//!   microbenchmark (`tune()`), or an artifact pin persisted in `.gsm`
//!   metadata. Every variant matches the scalar oracle bit for bit at
//!   any thread count and precision. The batched inner loops use
//!   explicit `std::simd` under the `simd` cargo feature. Backs the
//!   coordinator's native serving backend.
//! * [`profile`] — the chunk load-imbalance profiler: per-chunk wall
//!   times sampled inside `exec`'s parallel paths (on by default via the
//!   `chunk-profile` feature, compile-to-no-op without it), aggregated
//!   into per-plan time-skew and group-spread summaries for
//!   `{"op":"profile"}`.
//! * [`dense`] — the cache-blocked, feature-major batched dense layer
//!   (`relu(x@W1+b1)`) feeding the GS spMM; serial and pool-parallel,
//!   bit-identical at any thread count.
//! * [`spmv_sim`] / [`conv_sim`] — the same kernels executed on the
//!   [`crate::sim::Machine`]: they compute identical numerics while
//!   emitting micro-ops, so one run yields both the result vector and the
//!   cycle report. A cross-check test asserts sim == native == dense
//!   numerics for every pattern.

pub mod conv_sim;
pub mod dense;
pub mod dispatch;
pub mod exec;
pub mod native;
pub mod profile;
pub mod spmv_sim;

pub use conv_sim::{conv_block_sim, conv_dense_sim, conv_gs_sim, ConvOutput};
pub use dense::{dense_matmul, dense_matmul_parallel};
pub use dispatch::{DensityBand, KernelVariant, PlanGeometry};
#[allow(deprecated)] // legacy re-exports kept for downstream differential tests
pub use exec::{
    gs_matmul, gs_matmul_parallel, gs_matmul_parallel_merge, gs_matmul_scalar, gs_matvec_planned,
    GsExecPlan, PlanPrecision,
};
pub use spmv_sim::{spmv_block_sim, spmv_csr_sim, spmv_dense_sim, spmv_gs_sim, SpmvOutput};
