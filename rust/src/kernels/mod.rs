//! The paper's sparse kernels, in three guises.
//!
//! * [`native`] — plain f32 implementations (Algorithms 1–2 and the
//!   sparse convolution) used as numerics oracles and by the training
//!   orchestrator's CPU paths.
//! * [`exec`] — the production CPU fast path: a prepacked
//!   [`exec::GsExecPlan`] (joined §V layout at f32 or the paper's f16
//!   storage resolution, precomputed output slots, balanced chunks) with
//!   planned, batched, and multi-threaded kernels that match the oracle
//!   bit for bit. The batched inner loops use explicit `std::simd` under
//!   the `simd` cargo feature. Backs the coordinator's native serving
//!   backend.
//! * [`profile`] — the chunk load-imbalance profiler: per-chunk wall
//!   times sampled inside `exec`'s parallel paths (on by default via the
//!   `chunk-profile` feature, compile-to-no-op without it), aggregated
//!   into per-plan time-skew and group-spread summaries for
//!   `{"op":"profile"}`.
//! * [`dense`] — the cache-blocked, feature-major batched dense layer
//!   (`relu(x@W1+b1)`) feeding the GS spMM; serial and pool-parallel,
//!   bit-identical at any thread count.
//! * [`spmv_sim`] / [`conv_sim`] — the same kernels executed on the
//!   [`crate::sim::Machine`]: they compute identical numerics while
//!   emitting micro-ops, so one run yields both the result vector and the
//!   cycle report. A cross-check test asserts sim == native == dense
//!   numerics for every pattern.

pub mod conv_sim;
pub mod dense;
pub mod exec;
pub mod native;
pub mod profile;
pub mod spmv_sim;

pub use conv_sim::{conv_block_sim, conv_dense_sim, conv_gs_sim, ConvOutput};
pub use dense::{dense_matmul, dense_matmul_parallel};
pub use exec::{
    gs_matmul, gs_matmul_parallel, gs_matmul_parallel_merge, gs_matmul_scalar, gs_matvec_planned,
    GsExecPlan, PlanPrecision,
};
pub use spmv_sim::{spmv_block_sim, spmv_csr_sim, spmv_dense_sim, spmv_gs_sim, SpmvOutput};
