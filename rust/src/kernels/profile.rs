//! Kernel load-imbalance profiler: cheap per-chunk wall-time sampling
//! in [`gs_matmul_parallel`](super::exec::gs_matmul_parallel).
//!
//! The paper's load-balance claim is *static* — chunks carry near-equal
//! group counts — but whether they *run* balanced depends on cache
//! behavior, band raggedness, and scheduling. This module times each
//! chunk job (one `Instant` pair per chunk, amortized over the whole
//! gather-FMA sweep) and aggregates per plan geometry:
//!
//! * **time skew** = max chunk time / mean chunk time per call — 1.0 is
//!   perfect balance; aggregated as a time-weighted mean
//!   (`Σ max / Σ mean`) and a worst-case max across calls;
//! * **static spread**: group counts per chunk and per band, so an
//!   operator can tell a ragged pruning (bad input) from a scheduling
//!   problem (bad luck).
//!
//! Summaries are keyed by the plan's geometry fingerprint (shape, B/k,
//! precision, group/chunk counts, active [`KernelVariant`]) — the
//! identity of a deployed `.gsm` pruning — and drained via
//! `{"op":"profile"}`. Including the executed variant means skew
//! attributes *per kernel*: a tuned/pinned variant that runs ragged is
//! distinguishable from the generic loop on the same geometry.
//!
//! [`KernelVariant`]: super::dispatch::KernelVariant
//!
//! Compiled in by default (`chunk-profile` cargo feature, in the
//! default set) with a runtime switch ([`set_enabled`]); building with
//! `--no-default-features` compiles every hook to an empty inline
//! no-op, the same escape-hatch pattern as `coordinator::faults`.

#[cfg(feature = "chunk-profile")]
mod imp {
    use crate::kernels::dispatch::KernelVariant;
    use crate::kernels::exec::GsExecPlan;
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Mutex, OnceLock};
    use std::time::Instant;

    static ENABLED: AtomicBool = AtomicBool::new(true);

    /// Runtime switch (feature-on builds start enabled).
    pub fn set_enabled(on: bool) {
        ENABLED.store(on, Ordering::Relaxed);
    }

    pub fn enabled() -> bool {
        ENABLED.load(Ordering::Relaxed)
    }

    /// One chunk job's timer (None while disabled at `start`).
    pub struct ChunkTimer(Option<Instant>);

    pub fn start() -> ChunkTimer {
        if enabled() {
            ChunkTimer(Some(Instant::now()))
        } else {
            ChunkTimer(None)
        }
    }

    /// Elapsed seconds since `start` (0.0 while disabled).
    pub fn stop(t: ChunkTimer) -> f64 {
        t.0.map_or(0.0, |i| i.elapsed().as_secs_f64())
    }

    /// Aggregated timing + static geometry for one plan fingerprint.
    struct PlanProfile {
        /// Static group-count spread across the plan's chunks.
        chunk_groups: (usize, usize, f64),
        /// Static group-count spread across the plan's bands.
        band_groups: (usize, usize, f64),
        nbands: usize,
        nchunks: usize,
        calls: u64,
        /// Σ over calls of that call's mean chunk time.
        sum_mean: f64,
        /// Σ over calls of that call's max chunk time.
        sum_max: f64,
        /// Worst single-call skew observed.
        max_skew: f64,
    }

    impl PlanProfile {
        fn new(plan: &GsExecPlan) -> PlanProfile {
            let spread = |counts: &[usize]| -> (usize, usize, f64) {
                let min = counts.iter().copied().min().unwrap_or(0);
                let max = counts.iter().copied().max().unwrap_or(0);
                let mean = if counts.is_empty() {
                    0.0
                } else {
                    counts.iter().sum::<usize>() as f64 / counts.len() as f64
                };
                (min, max, mean)
            };
            let chunk_counts: Vec<usize> = plan.chunks().iter().map(|c| c.groups).collect();
            let band_counts = plan.band_group_counts();
            PlanProfile {
                chunk_groups: spread(&chunk_counts),
                band_groups: spread(&band_counts),
                nbands: band_counts.len(),
                nchunks: chunk_counts.len(),
                calls: 0,
                sum_mean: 0.0,
                sum_max: 0.0,
                max_skew: 0.0,
            }
        }
    }

    fn registry() -> &'static Mutex<BTreeMap<String, PlanProfile>> {
        static REGISTRY: OnceLock<Mutex<BTreeMap<String, PlanProfile>>> = OnceLock::new();
        REGISTRY.get_or_init(|| Mutex::new(BTreeMap::new()))
    }

    /// The plan's geometry fingerprint — the identity of a deployed
    /// pruning, stable across repacks of the same `.gsm` — suffixed with
    /// the kernel variant that executed, so skew attributes per-variant.
    fn fingerprint(plan: &GsExecPlan, variant: KernelVariant) -> String {
        format!(
            "{}x{} b{} k{} {} groups{} chunks{}{} kernel={}",
            plan.rows,
            plan.cols,
            plan.b,
            plan.k,
            plan.precision.name(),
            plan.ngroups(),
            plan.chunks().len(),
            if plan.scatter { " scatter" } else { "" },
            variant.name(),
        )
    }

    /// Fold one parallel call's per-chunk times into the plan's
    /// aggregate (keyed per executed `variant`). Single-chunk calls and
    /// all-zero timings (profiling raced off mid-call) carry no balance
    /// information and are skipped.
    pub fn record_call(plan: &GsExecPlan, variant: KernelVariant, chunk_secs: &[f64]) {
        if !enabled() || chunk_secs.len() < 2 {
            return;
        }
        let sum: f64 = chunk_secs.iter().sum();
        if sum <= 0.0 {
            return;
        }
        let mean = sum / chunk_secs.len() as f64;
        let max = chunk_secs.iter().copied().fold(0.0, f64::max);
        let mut reg = registry().lock().unwrap();
        let p = reg
            .entry(fingerprint(plan, variant))
            .or_insert_with(|| PlanProfile::new(plan));
        p.calls += 1;
        p.sum_mean += mean;
        p.sum_max += max;
        p.max_skew = p.max_skew.max(max / mean);
    }

    /// Every profiled plan as a JSON object keyed by fingerprint.
    pub fn snapshot_json() -> Json {
        let reg = registry().lock().unwrap();
        let plans = reg
            .iter()
            .map(|(key, p)| {
                let spread = |(min, max, mean): (usize, usize, f64)| {
                    Json::obj(vec![
                        ("min", Json::Num(min as f64)),
                        ("max", Json::Num(max as f64)),
                        ("mean", Json::Num(mean)),
                        (
                            "spread",
                            Json::Num(if mean > 0.0 { max as f64 / mean } else { 0.0 }),
                        ),
                    ])
                };
                let profile = Json::obj(vec![
                    ("bands", Json::Num(p.nbands as f64)),
                    ("chunks", Json::Num(p.nchunks as f64)),
                    ("chunk_groups", spread(p.chunk_groups)),
                    ("band_groups", spread(p.band_groups)),
                    ("calls", Json::Num(p.calls as f64)),
                    ("mean_chunk_ms", Json::Num(1e3 * p.sum_mean / p.calls.max(1) as f64)),
                    ("max_chunk_ms", Json::Num(1e3 * p.sum_max / p.calls.max(1) as f64)),
                    (
                        "time_skew",
                        Json::obj(vec![
                            (
                                "mean",
                                Json::Num(if p.sum_mean > 0.0 { p.sum_max / p.sum_mean } else { 0.0 }),
                            ),
                            ("max", Json::Num(p.max_skew)),
                        ]),
                    ),
                ]);
                (key.clone(), profile)
            })
            .collect();
        Json::Obj(plans)
    }

    /// Drop every aggregate (tests, `{"op":"profile","reset":true}`).
    pub fn reset() {
        registry().lock().unwrap().clear();
    }
}

#[cfg(not(feature = "chunk-profile"))]
mod imp {
    use crate::kernels::dispatch::KernelVariant;
    use crate::kernels::exec::GsExecPlan;
    use crate::util::json::Json;
    use std::collections::BTreeMap;

    /// Zero-sized stand-in; `start`/`stop` compile to nothing.
    pub struct ChunkTimer;

    #[inline(always)]
    pub fn set_enabled(_on: bool) {}

    #[inline(always)]
    pub fn enabled() -> bool {
        false
    }

    #[inline(always)]
    pub fn start() -> ChunkTimer {
        ChunkTimer
    }

    #[inline(always)]
    pub fn stop(_t: ChunkTimer) -> f64 {
        0.0
    }

    #[inline(always)]
    pub fn record_call(_plan: &GsExecPlan, _variant: KernelVariant, _chunk_secs: &[f64]) {}

    pub fn snapshot_json() -> Json {
        Json::Obj(BTreeMap::new())
    }

    #[inline(always)]
    pub fn reset() {}
}

pub use imp::{enabled, record_call, reset, set_enabled, snapshot_json, start, stop, ChunkTimer};

#[cfg(all(test, feature = "chunk-profile"))]
mod tests {
    use super::*;
    use crate::sparse::pattern::Pattern;
    use crate::testing::model::build_random_gs;
    use crate::util::json::Json;
    use std::sync::Mutex;

    /// The registry and enable switch are process-global (and the
    /// instrumented kernels record from any concurrently running test),
    /// so these tests serialize against each other, use distinctive
    /// plan shapes, and assert on their own fingerprint only.
    static SERIAL: Mutex<()> = Mutex::new(());

    fn plan(rows: usize, nchunks: usize) -> crate::kernels::exec::GsExecPlan {
        let (_, gs) = build_random_gs(rows, 32, Pattern::Gs { b: 8, k: 4 }, 0.75, 7).unwrap();
        crate::kernels::exec::GsExecPlan::with_chunks(&gs, nchunks).unwrap()
    }

    fn my_plan<'a>(
        plans: &'a std::collections::BTreeMap<String, Json>,
        shape: &str,
    ) -> Option<&'a Json> {
        plans.iter().find(|(k, _)| k.starts_with(shape)).map(|(_, v)| v)
    }

    #[test]
    fn record_aggregates_skew_per_plan() {
        let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        let p = plan(64, 4);
        // Two calls: balanced (skew 1.0) then one hot chunk (skew 2.5
        // = 0.005 / mean 0.002).
        record_call(&p, p.kernel_variant(), &[0.001, 0.001, 0.001, 0.001]);
        record_call(&p, p.kernel_variant(), &[0.001, 0.001, 0.001, 0.005]);
        let snap = snapshot_json();
        let Json::Obj(plans) = &snap else { panic!("object") };
        assert!(
            plans.keys().any(|k| k.starts_with("64x32") && k.contains(" kernel=")),
            "fingerprint carries the executed kernel variant"
        );
        let prof = my_plan(plans, "64x32").expect("own fingerprint present");
        assert_eq!(prof.get("calls").unwrap().as_f64().unwrap(), 2.0);
        let skew = prof.get("time_skew").unwrap();
        let max_skew = skew.get("max").unwrap().as_f64().unwrap();
        assert!((max_skew - 2.5).abs() < 1e-9, "{max_skew}");
        let mean_skew = skew.get("mean").unwrap().as_f64().unwrap();
        assert!(mean_skew > 1.0 && mean_skew <= 2.5, "{mean_skew}");
        // Static geometry rides along.
        let cg = prof.get("chunk_groups").unwrap();
        assert!(cg.get("max").unwrap().as_f64().unwrap() >= 1.0);
        reset();
    }

    #[test]
    fn disabled_and_degenerate_calls_record_nothing() {
        let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        let p = plan(48, 4);
        set_enabled(false);
        assert!(!enabled());
        let t = start();
        assert_eq!(stop(t), 0.0, "disabled timer reads zero");
        record_call(&p, p.kernel_variant(), &[0.001, 0.002]);
        set_enabled(true);
        record_call(&p, p.kernel_variant(), &[0.001]); // single chunk: no balance info
        record_call(&p, p.kernel_variant(), &[0.0, 0.0]); // raced-off timers
        let Json::Obj(plans) = snapshot_json() else { panic!("object") };
        assert!(my_plan(&plans, "48x32").is_none(), "nothing recorded for this plan");
    }
}
