//! Sparse convolution kernels on the simulated machine (paper §V,
//! Fig. 6(b)).
//!
//! Layout: NHWC activations resident in the TCM (channel-innermost, so
//! input channels interleave across sub-banks), OhwI filters flattened per
//! Definition 4.2 and streamed through the caches. For each output pixel
//! the kernel walks the filter's groups and gathers activations at
//! `pixel_base + engine_offset` — the kernel-shape-aware offsets of
//! [`GsConv::engine_offsets`]. Weight arrays are re-streamed per pixel,
//! which is where the paper's "higher speedup … due to more data reuse"
//! comes from: the streams hit in L1/L2 on every pixel after the first,
//! and each loaded weight/index group is applied to a tile of
//! `PIXEL_TILE` output pixels before the next group streams in
//! (weight-stationary inner loop), so the sparse format's LSU cost
//! amortizes and the gather engine / VPU become the bottleneck — exactly
//! why Fig. 6(b) outruns Fig. 6(a).

use crate::sim::machine::{Machine, MachineConfig, SimReport, Stream};
use crate::sparse::block::BlockSparse;
use crate::sparse::conv::{flatten_filters, ConvShape, GsConv};


/// Output pixels sharing one streamed weight group (weight-stationary tile).
pub const PIXEL_TILE: usize = 4;

/// Output feature map + cycle report.
#[derive(Clone, Debug)]
pub struct ConvOutput {
    /// NHWC output, `(act_h-h+1) × (act_w-w+1) × O`.
    pub out: Vec<f32>,
    pub out_h: usize,
    pub out_w: usize,
    pub report: SimReport,
}

fn machine_with_fmap(cfg: MachineConfig, act: &[f32]) -> Machine {
    let mut m = Machine::new(cfg);
    assert!(
        act.len() <= m.config.tcm.capacity_elems,
        "feature map does not fit the TCM; partition first (paper §X)"
    );
    m.tcm.fill(0, act);
    m.reset();
    m
}

/// Dense direct convolution baseline: per pixel × output channel, stream
/// B-wide weight vectors and sequentially load matching activations.
pub fn conv_dense_sim(
    act: &[f32],
    act_h: usize,
    act_w: usize,
    weights: &[f32],
    shape: ConvShape,
    cfg: MachineConfig,
) -> ConvOutput {
    assert_eq!(act.len(), act_h * act_w * shape.in_ch);
    let b = cfg.tcm.subbanks;
    assert_eq!(shape.in_ch % b, 0, "dense conv tiling assumes B | I");
    let mut m = machine_with_fmap(cfg, act);
    let oh = act_h - shape.h + 1;
    let ow = act_w - shape.w + 1;
    let flat = flatten_filters(weights, shape);
    let mut out = vec![0.0f32; oh * ow * shape.out_ch];
    let mut avec = vec![0.0f32; b];
    // Weight-stationary pixel tiles: one streamed weight vector serves
    // PIXEL_TILE output pixels before the next group loads.
    let pixels: Vec<(usize, usize)> =
        (0..oh).flat_map(|y| (0..ow).map(move |x| (y, x))).collect();
    for tile in pixels.chunks(PIXEL_TILE) {
        for o in 0..shape.out_ch {
            m.row_prologue();
            let mut res = vec![vec![0.0f32; b]; tile.len()];
            let wrow = flat.row(o);
            // Walk the kernel window; within a (kh,kw) position the channel
            // run is contiguous in both filter and fmap.
            for kh in 0..shape.h {
                for kw in 0..shape.w {
                    for ci in (0..shape.in_ch).step_by(b) {
                        let f0 = shape.flatten_col(kh, kw, ci);
                        m.stream_load(Stream::Weights, b * 2);
                        for (ti, &(y, x)) in tile.iter().enumerate() {
                            let arow = ((y + kh) * act_w + (x + kw)) * shape.in_ch;
                            m.tcm_load_seq(arow + ci, &mut avec);
                            m.simd_mac(&wrow[f0..f0 + b], &avec, &mut res[ti]);
                        }
                        m.loop_tick();
                    }
                }
            }
            for (ti, &(y, x)) in tile.iter().enumerate() {
                out[(y * ow + x) * shape.out_ch + o] = m.simd_reduce(&res[ti]);
                m.store_result(2);
            }
        }
    }
    ConvOutput { out, out_h: oh, out_w: ow, report: m.report() }
}

/// GS sparse convolution: per pixel, walk each band's groups and gather at
/// `pixel_base + engine_offset` (kernel-shape-aware, conflict-free because
/// `B | I` preserves residues).
pub fn conv_gs_sim(
    act: &[f32],
    act_h: usize,
    act_w: usize,
    gc: &GsConv,
    cfg: MachineConfig,
) -> ConvOutput {
    let shape = gc.shape;
    assert_eq!(act.len(), act_h * act_w * shape.in_ch);
    assert_eq!(cfg.tcm.subbanks, gc.gs.b, "machine lanes must equal B");
    let b = gc.gs.b;
    let gs = &gc.gs;
    let mut m = machine_with_fmap(cfg, act);
    let oh = act_h - shape.h + 1;
    let ow = act_w - shape.w + 1;
    let offsets = gc.engine_offsets(act_w);
    let mut out = vec![0.0f32; oh * ow * shape.out_ch];
    let mut gathered = vec![0.0f32; b];
    let pixels: Vec<(usize, usize)> =
        (0..oh).flat_map(|y| (0..ow).map(move |x| (y, x))).collect();
    // Weight-stationary: each streamed value/index group is gathered+MACed
    // for PIXEL_TILE pixels before the next group loads.
    for tile in pixels.chunks(PIXEL_TILE) {
        for band in 0..gs.nbands() {
            m.row_prologue();
            m.stream_load(Stream::Indptr, 4);
            let mut res = vec![vec![0.0f32; b]; tile.len()];
            for g in gs.indptr[band] as usize..gs.indptr[band + 1] as usize {
                let vals = &gs.value[g * b..(g + 1) * b];
                let offs = &offsets[g * b..(g + 1) * b];
                m.stream_load(Stream::Weights, b * 2);
                m.stream_load(Stream::Indices, b * 2);
                for (ti, &(y, x)) in tile.iter().enumerate() {
                    let pixel_base = (y * act_w + x) * shape.in_ch;
                    m.gather(pixel_base, offs, &mut gathered);
                    m.simd_mac(vals, &gathered, &mut res[ti]);
                }
                m.loop_tick();
            }
            for (ti, &(y, x)) in tile.iter().enumerate() {
                if gs.band_rows() == 1 {
                    let o = gs.entry_row(band, 0);
                    out[(y * ow + x) * shape.out_ch + o] = m.simd_reduce(&res[ti]);
                    m.store_result(2);
                } else {
                    if gs.k > 1 {
                        m.simd_reduce(&res[ti]);
                    }
                    let slots = gs.band_rows();
                    for j in 0..b {
                        let o = gs.entry_row(band, j);
                        out[(y * ow + x) * shape.out_ch + o] += res[ti][j];
                    }
                    m.store_result(slots * 2);
                }
            }
        }
    }
    ConvOutput { out, out_h: oh, out_w: ow, report: m.report() }
}

/// Block-sparse convolution baseline over the flattened filter matrix
/// (`Block(B,B)` = B-long channel runs; `Block(B,1)` = B output channels
/// sharing one flat position).
pub fn conv_block_sim(
    act: &[f32],
    act_h: usize,
    act_w: usize,
    bs: &BlockSparse,
    shape: ConvShape,
    cfg: MachineConfig,
) -> ConvOutput {
    assert_eq!(act.len(), act_h * act_w * shape.in_ch);
    assert_eq!(bs.rows, shape.out_ch);
    assert_eq!(bs.cols, shape.flat_cols());
    assert_eq!(cfg.tcm.subbanks, bs.b);
    // A Block(B,B) run must stay inside one (kh,kw) channel run for the
    // sequential activation load to be valid.
    assert!(
        bs.k == 1 || shape.in_ch % bs.k == 0,
        "Block(B,B) conv requires k | I"
    );
    let b = bs.b;
    let br = bs.block_rows();
    let mut m = machine_with_fmap(cfg, act);
    let oh = act_h - shape.h + 1;
    let ow = act_w - shape.w + 1;
    let mut out = vec![0.0f32; oh * ow * shape.out_ch];
    let mut avec = vec![0.0f32; bs.k];
    let pixels: Vec<(usize, usize)> =
        (0..oh).flat_map(|y| (0..ow).map(move |x| (y, x))).collect();
    for tile in pixels.chunks(PIXEL_TILE) {
        for band in 0..bs.indptr.len() - 1 {
            m.row_prologue();
            m.stream_load(Stream::Indptr, 4);
            let mut res = vec![vec![0.0f32; b]; tile.len()];
            for blk in bs.indptr[band] as usize..bs.indptr[band + 1] as usize {
                let c0 = bs.index[blk] as usize * bs.k;
                let (kh, kw, ic) = shape.unflatten_col(c0);
                m.stream_load(Stream::Weights, b * 2);
                m.stream_load(Stream::Indices, 2);
                let wv = bs.value[blk * b..(blk + 1) * b].to_vec();
                for (ti, &(y, x)) in tile.iter().enumerate() {
                    let aaddr = ((y + kh) * act_w + (x + kw)) * shape.in_ch + ic;
                    m.tcm_load_seq(aaddr, &mut avec);
                    let abroad: Vec<f32> = (0..b).map(|l| avec[l % bs.k]).collect();
                    m.simd_mac(&wv, &abroad, &mut res[ti]);
                }
                m.loop_tick();
            }
            for (ti, &(y, x)) in tile.iter().enumerate() {
                if br == 1 {
                    out[(y * ow + x) * shape.out_ch + band] = m.simd_reduce(&res[ti]);
                    m.store_result(2);
                } else {
                    if bs.k > 1 {
                        m.simd_reduce(&res[ti]);
                    }
                    for (l, &v) in res[ti].iter().enumerate() {
                        let o = band * br + l / bs.k;
                        out[(y * ow + x) * shape.out_ch + o] += v;
                    }
                    m.store_result(br * 2);
                }
            }
        }
    }
    ConvOutput { out, out_h: oh, out_w: ow, report: m.report() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::prune;
    use crate::sparse::conv::conv2d_reference;
    use crate::sparse::pattern::Pattern;
    use crate::util::prng::Prng;

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < tol, "idx {i}: {x} vs {y}");
        }
    }

    fn setup(seed: u64) -> (Vec<f32>, ConvShape, Vec<f32>) {
        let mut rng = Prng::new(seed);
        let shape = ConvShape::conv2d(16, 3, 3, 16);
        let weights = rng.normal_vec(shape.weight_len(), 0.5);
        let act = rng.normal_vec(6 * 6 * shape.in_ch, 1.0);
        (weights, shape, act)
    }

    #[test]
    fn dense_conv_matches_reference() {
        let (weights, shape, act) = setup(1);
        let out = conv_dense_sim(&act, 6, 6, &weights, shape, MachineConfig::with_subbanks(8));
        let want = conv2d_reference(&act, 6, 6, &weights, shape);
        close(&out.out, &want, 1e-3);
        assert_eq!((out.out_h, out.out_w), (4, 4));
    }

    #[test]
    fn gs_conv_matches_reference_horizontal_and_vertical() {
        let (weights, shape, act) = setup(2);
        let flat = flatten_filters(&weights, shape);
        for p in [Pattern::Gs { b: 8, k: 8 }, Pattern::Gs { b: 8, k: 1 }] {
            let mask = prune(&flat, p, 0.7).unwrap();
            let mut pruned_flat = flat.clone();
            pruned_flat.apply_mask(&mask);
            let gc = GsConv::from_weights(&pruned_flat.data, shape, p).unwrap();
            let out = conv_gs_sim(&act, 6, 6, &gc, MachineConfig::with_subbanks(8));
            let want = conv2d_reference(&act, 6, 6, &pruned_flat.data, shape);
            close(&out.out, &want, 1e-3);
            assert_eq!(out.report.conflict_slots, 0, "{} conv conflicted", p.name());
        }
    }

    #[test]
    fn block_conv_matches_reference() {
        let (weights, shape, act) = setup(3);
        let flat = flatten_filters(&weights, shape);
        for p in [Pattern::Block { b: 8, k: 8 }, Pattern::Block { b: 8, k: 1 }] {
            let mask = prune(&flat, p, 0.7).unwrap();
            let mut pruned_flat = flat.clone();
            pruned_flat.apply_mask(&mask);
            let bs = BlockSparse::from_dense(&pruned_flat, p).unwrap();
            let out = conv_block_sim(&act, 6, 6, &bs, shape, MachineConfig::with_subbanks(8));
            let want = conv2d_reference(&act, 6, 6, &pruned_flat.data, shape);
            close(&out.out, &want, 1e-3);
        }
    }

    #[test]
    fn conv_reuses_weight_stream_across_pixels() {
        // The L1 hit rate for sparse conv should be high: the same weight
        // stream is re-walked for every output pixel.
        let (weights, shape, act) = setup(4);
        let flat = flatten_filters(&weights, shape);
        let p = Pattern::Gs { b: 8, k: 8 };
        let mask = prune(&flat, p, 0.8).unwrap();
        let mut pf = flat.clone();
        pf.apply_mask(&mask);
        let gc = GsConv::from_weights(&pf.data, shape, p).unwrap();
        let out = conv_gs_sim(&act, 6, 6, &gc, MachineConfig::with_subbanks(8));
        assert!(
            out.report.l1_hit_rate > 0.8,
            "expected reuse, hit rate {}",
            out.report.l1_hit_rate
        );
    }

    #[test]
    fn sparse_conv_beats_dense_at_high_sparsity() {
        let (weights, shape, act) = setup(5);
        let flat = flatten_filters(&weights, shape);
        let p = Pattern::Gs { b: 8, k: 8 };
        let mask = prune(&flat, p, 0.9).unwrap();
        let mut pf = flat.clone();
        pf.apply_mask(&mask);
        let gc = GsConv::from_weights(&pf.data, shape, p).unwrap();
        let cfg = MachineConfig::with_subbanks(8);
        let dense = conv_dense_sim(&act, 6, 6, &weights, shape, cfg);
        let sparse = conv_gs_sim(&act, 6, 6, &gc, cfg);
        assert!(
            sparse.report.cycles * 2 < dense.report.cycles,
            "dense {} vs sparse {}",
            dense.report.cycles,
            sparse.report.cycles
        );
    }
}
