//! Cache-blocked batched dense layer: `H = act(X · W + bias)` with the
//! output in the feature-major layout the GS spMM consumes.
//!
//! The serving forward pass previously computed the dense input layer
//! row-by-row (one axpy sweep of `W` per request), so at serving batch
//! sizes `W` was re-streamed `batch` times and the dense layer — not the
//! GS spMM — became the bandwidth bottleneck. This kernel blocks over
//! [`BATCH_BLOCK`] requests × [`FEAT_BLOCK`] output features: each weight
//! load is amortized across the whole batch block (8× less `W` traffic),
//! the accumulator tile stays L1-resident, and the inner block is the
//! same [`axpy_block`] used by the GS kernels — explicit `std::simd`
//! under the `simd` feature, register-blocked scalar otherwise.
//!
//! Accumulation over the input dimension is always in ascending order for
//! every (feature, request) cell, independent of blocking and span
//! partitioning — so [`dense_matmul`] and [`dense_matmul_parallel`] are
//! bit-identical to each other and to the naive loop at any thread count.

use crate::kernels::exec::{axpy_block, OutPtr, BATCH_BLOCK};
use crate::util::threadpool::{partition_spans, ThreadPool};
use std::sync::Arc;

/// Output features per cache block. 64 features × 8 batch columns of f32
/// is a 2 KiB accumulator tile — comfortably L1-resident.
pub const FEAT_BLOCK: usize = 64;

/// Serial blocked dense layer. `w` is `[inputs, hidden]` row-major (the
/// `x @ W` layout), `xs` holds `batch` request rows of `inputs` f32.
/// Returns `out[j*batch + r] = act(bias[j] + Σ_i xs[r][i]·w[i,j])`,
/// feature-major, with `act = relu` when `relu` is set.
pub fn dense_matmul(
    w: &[f32],
    bias: &[f32],
    xs: &[Vec<f32>],
    inputs: usize,
    hidden: usize,
    relu: bool,
) -> Vec<f32> {
    assert_eq!(w.len(), inputs * hidden, "weight shape mismatch");
    assert_eq!(bias.len(), hidden, "bias length mismatch");
    let mut out = vec![0.0f32; hidden * xs.len()];
    dense_matmul_span(w, bias, xs, inputs, hidden, relu, 0, hidden, &mut out);
    out
}

/// Compute output features `j_lo..j_hi` into `out` (length
/// `(j_hi-j_lo)*batch`, feature-major with local feature 0 = `j_lo`).
/// The span building block of the parallel path; spans of the feature
/// axis are independent, so any partition reproduces [`dense_matmul`]
/// exactly.
#[allow(clippy::too_many_arguments)]
pub fn dense_matmul_span(
    w: &[f32],
    bias: &[f32],
    xs: &[Vec<f32>],
    inputs: usize,
    hidden: usize,
    relu: bool,
    j_lo: usize,
    j_hi: usize,
    out: &mut [f32],
) {
    let batch = xs.len();
    debug_assert!(j_hi <= hidden && out.len() >= (j_hi - j_lo) * batch);
    for row in xs {
        assert_eq!(row.len(), inputs, "input row width mismatch");
    }
    // Accumulator tile + broadcast buffer live on the stack.
    let mut acc = [0.0f32; FEAT_BLOCK * BATCH_BLOCK];
    let mut xv = [0.0f32; BATCH_BLOCK];
    let mut j0 = j_lo;
    while j0 < j_hi {
        let j1 = (j0 + FEAT_BLOCK).min(j_hi);
        let jn = j1 - j0;
        let mut r0 = 0usize;
        while r0 < batch {
            let r1 = (r0 + BATCH_BLOCK).min(batch);
            let rn = r1 - r0;
            for jj in 0..jn {
                for t in 0..rn {
                    acc[jj * BATCH_BLOCK + t] = bias[j0 + jj];
                }
            }
            for i in 0..inputs {
                for (t, row) in xs[r0..r1].iter().enumerate() {
                    xv[t] = row[i];
                }
                // One row-segment of W feeds a full batch block: loaded
                // once per 8 requests instead of once per request.
                let wrow = &w[i * hidden + j0..i * hidden + j1];
                if rn == BATCH_BLOCK {
                    for jj in 0..jn {
                        let tile = &mut acc[jj * BATCH_BLOCK..jj * BATCH_BLOCK + BATCH_BLOCK];
                        axpy_block(wrow[jj], &xv, tile);
                    }
                } else {
                    for jj in 0..jn {
                        let wv = wrow[jj];
                        for t in 0..rn {
                            acc[jj * BATCH_BLOCK + t] += wv * xv[t];
                        }
                    }
                }
            }
            for jj in 0..jn {
                let o0 = (j0 + jj - j_lo) * batch + r0;
                for t in 0..rn {
                    let v = acc[jj * BATCH_BLOCK + t];
                    out[o0 + t] = if relu { v.max(0.0) } else { v };
                }
            }
            r0 = r1;
        }
        j0 = j1;
    }
}

/// Parallel blocked dense layer: the feature axis is split into
/// near-equal spans (one per pool worker, at least [`FEAT_BLOCK`]-sized
/// on average), each computed independently on the [`ThreadPool`].
/// Spans are contiguous disjoint ranges of the feature-major output, so
/// each job direct-writes its slice of one preallocated buffer — no
/// private accumulators, no concatenation pass. Bit-identical to
/// [`dense_matmul`].
///
/// Weights and inputs travel to the workers as `Arc` clones (pool jobs
/// are `'static`).
pub fn dense_matmul_parallel(
    w: &Arc<Vec<f32>>,
    bias: &Arc<Vec<f32>>,
    xs: &Arc<Vec<Vec<f32>>>,
    inputs: usize,
    hidden: usize,
    relu: bool,
    pool: &ThreadPool,
) -> Vec<f32> {
    let batch = xs.len();
    let nspans = pool
        .workers()
        .min((hidden + FEAT_BLOCK - 1) / FEAT_BLOCK)
        .max(1);
    let spans = partition_spans(hidden, nspans);
    if spans.len() <= 1 {
        return dense_matmul(w, bias, xs, inputs, hidden, relu);
    }
    let mut out = vec![0.0f32; hidden * batch];
    let base = OutPtr(out.as_mut_ptr());
    let (w2, bias2, xs2) = (Arc::clone(w), Arc::clone(bias), Arc::clone(xs));
    pool.map(spans, move |(lo, hi)| {
        // SAFETY: `partition_spans` yields disjoint contiguous feature
        // ranges, so the slices `[lo*batch, hi*batch)` never overlap;
        // `out` outlives every job because `pool.map` joins before
        // returning (panics included — `join` drains the queue first).
        let span = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(lo * batch), (hi - lo) * batch)
        };
        dense_matmul_span(&w2, &bias2, &xs2, inputs, hidden, relu, lo, hi, span);
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    /// Naive reference with the same accumulation order (i ascending).
    fn naive(
        w: &[f32],
        bias: &[f32],
        xs: &[Vec<f32>],
        inputs: usize,
        hidden: usize,
        relu: bool,
    ) -> Vec<f32> {
        let batch = xs.len();
        let mut out = vec![0.0f32; hidden * batch];
        for j in 0..hidden {
            for (r, x) in xs.iter().enumerate() {
                let mut acc = bias[j];
                for i in 0..inputs {
                    acc += w[i * hidden + j] * x[i];
                }
                out[j * batch + r] = if relu { acc.max(0.0) } else { acc };
            }
        }
        out
    }

    #[test]
    fn blocked_matches_naive_bit_for_bit() {
        let mut rng = Prng::new(4);
        // Shapes straddling both block sizes and their remainders.
        for &(inputs, hidden, batch) in &[
            (1usize, 1usize, 1usize),
            (7, 63, 3),
            (16, 64, 8),
            (24, 65, 9),
            (32, 200, 13),
            (5, 128, 0),
        ] {
            for relu in [false, true] {
                let w = rng.normal_vec(inputs * hidden, 1.0);
                let bias = rng.normal_vec(hidden, 0.5);
                let xs: Vec<Vec<f32>> =
                    (0..batch).map(|_| rng.normal_vec(inputs, 1.0)).collect();
                assert_eq!(
                    dense_matmul(&w, &bias, &xs, inputs, hidden, relu),
                    naive(&w, &bias, &xs, inputs, hidden, relu),
                    "inputs={inputs} hidden={hidden} batch={batch} relu={relu}"
                );
            }
        }
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        let pool = ThreadPool::new(4);
        let mut rng = Prng::new(9);
        for &(inputs, hidden, batch) in &[(16usize, 256usize, 8usize), (10, 130, 5), (8, 64, 1)] {
            let w = Arc::new(rng.normal_vec(inputs * hidden, 1.0));
            let bias = Arc::new(rng.normal_vec(hidden, 0.5));
            let xs = Arc::new(
                (0..batch)
                    .map(|_| rng.normal_vec(inputs, 1.0))
                    .collect::<Vec<_>>(),
            );
            assert_eq!(
                dense_matmul_parallel(&w, &bias, &xs, inputs, hidden, true, &pool),
                dense_matmul(&w, &bias, &xs, inputs, hidden, true),
                "inputs={inputs} hidden={hidden} batch={batch}"
            );
        }
    }

    #[test]
    fn relu_clamps_negatives() {
        let w = vec![-1.0f32];
        let bias = vec![0.0f32];
        let xs = vec![vec![2.0f32]];
        assert_eq!(dense_matmul(&w, &bias, &xs, 1, 1, false), vec![-2.0]);
        assert_eq!(dense_matmul(&w, &bias, &xs, 1, 1, true), vec![0.0]);
    }
}
