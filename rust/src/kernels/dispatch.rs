//! Pattern-specialized kernel dispatch for [`GsExecPlan`] execution.
//!
//! SparseDNN's observation (arXiv 2101.07948) — kernels *specialized to
//! the sparsity pattern* consistently beat one generic kernel — applies
//! directly to GS plans: the whole geometry (lane count `b`, lanes per
//! row `k`, scatter vs. not, density, chunk balance) is known at pack
//! time. This module turns that knowledge into a dispatch layer so
//! kernel selection is a property of the *plan* (and, persisted through
//! `.gsm` metadata, of the deployed artifact) instead of being
//! hard-coded at every call site:
//!
//! * [`KernelVariant`] — the compiled menu of inner loops:
//!   * `Generic` — the register-blocked loop `exec.rs` always shipped;
//!     the fallback, valid for every plan.
//!   * `SmallGroupUnrolled` — `b ∈ {1,2,4,8}`, non-scatter: the lane
//!     loop is monomorphized over `const B` so it fully unrolls, and
//!     the lane→slot table becomes a fixed-size array (no bounds
//!     checks in the hot loop).
//!   * `LaneBlocked` — lane-heavy single-row groups (`k == b`),
//!     non-scatter: every lane of every group in a band accumulates
//!     into the *same* output row, so the output register block is
//!     hoisted across the band's whole gather-FMA sweep instead of
//!     being reloaded per lane.
//!   * `ScatterDirect` — scatter plans: the rowmap is a permutation,
//!     so chunks own disjoint (if interleaved) row sets; each lane
//!     writes its global row *directly* through a strided raw-pointer
//!     view, dropping the `O(rows·batch)` private-accumulate+merge
//!     pass. The merge path remains in the menu (pin `Generic`) as the
//!     differential oracle.
//! * [`KernelVariant::classify`] — deterministic geometry rules run at
//!   plan build; the result is cached on the plan.
//! * [`GsExecPlan::execute`] / [`GsExecPlan::execute_bias`] — the single
//!   entry point serving, benches and examples route through; picks
//!   serial vs. pooled exactly like the legacy call sites did
//!   (`pool == None` or a single chunk ⇒ serial).
//! * [`GsExecPlan::tune`] — optional one-shot microbenchmark: times
//!   every supported variant on deterministic synthetic activations
//!   (fixed PRNG seed, menu order, time-boxed) and caches the winner in
//!   the plan. The choice is persisted in `.gsm` metadata
//!   (`kernel_variant`) so a served artifact inherits it across
//!   export → load → swap → rollback.
//!
//! **Invariant (not an aspiration): every menu variant is bit-identical
//! to [`gs_matmul_scalar`](super::exec::gs_matmul_scalar) at any thread
//! count and precision.** All variants preserve the oracle's
//! accumulation order per output element — lane order within group,
//! group order within band, band order — and use the same
//! [`axpy_block`] arithmetic (mul then add, no FMA contraction), so
//! specialization changes instruction scheduling, never results. The
//! property sweep in `tests/native_exec.rs` enforces this across the
//! full geometry grid.

use super::exec::{
    axpy_block, axpy_block_scalar, Chunk, GsExecPlan, Joined, JoinedWord, OutPtr, BATCH_BLOCK,
};
use crate::kernels::profile;
use crate::util::prng::Prng;
use crate::util::threadpool::ThreadPool;
use anyhow::{ensure, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The compiled menu of specialized inner loops. Every variant is
/// bit-identical to the scalar oracle; they differ only in instruction
/// scheduling (unrolling, register blocking, write strategy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelVariant {
    /// The generic register-blocked loop — valid for every geometry, and
    /// the accumulate+merge strategy on scatter plans (the differential
    /// oracle for `ScatterDirect`).
    Generic,
    /// Fully-unrolled lane loop for small groups (`b ∈ {1,2,4,8}`,
    /// non-scatter): `const B` monomorphization unrolls the per-group
    /// sweep and drops its bounds checks.
    SmallGroupUnrolled,
    /// Register-blocked over the band's single output row (`k == b`,
    /// non-scatter): the output block is loaded once per band and
    /// batch-block, not once per lane.
    LaneBlocked,
    /// Strided direct write for scatter plans: rowmap rows are a
    /// permutation, so each chunk's rows are disjoint and every lane can
    /// write its global row in place — no private buffer, no
    /// `O(rows·batch)` merge.
    ScatterDirect,
}

/// Coarse density regime of a plan, from groups packed vs. the band
/// capacity (`cols / k` groups per band).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DensityBand {
    /// < 5% of band capacity: bands are nearly empty.
    Low,
    /// 5–50% of band capacity.
    Mid,
    /// ≥ 50% of band capacity: bands are nearly full.
    High,
}

/// The classified geometry of a plan — the inputs to
/// [`KernelVariant::classify`], surfaced so operators and tests can see
/// *why* a variant was picked.
#[derive(Clone, Copy, Debug)]
pub struct PlanGeometry {
    /// Lanes per group (`b`).
    pub lanes: usize,
    /// Lanes per output row within a group (`k`).
    pub k: usize,
    /// Output rows per band (`b / k`).
    pub band_rows: usize,
    /// Whether the plan carries a scatter rowmap.
    pub scatter: bool,
    /// Packed groups as a fraction of band capacity (`cols / k` groups
    /// per band).
    pub density: f64,
    pub density_band: DensityBand,
    /// Max/mean group count across the plan's balanced chunks — the
    /// profiler's static skew, ≥ 1.0 (1.0 = perfectly balanced).
    pub chunk_skew: f64,
}

impl KernelVariant {
    /// The full menu, in deterministic classification/tune order.
    pub const MENU: [KernelVariant; 4] = [
        KernelVariant::Generic,
        KernelVariant::SmallGroupUnrolled,
        KernelVariant::LaneBlocked,
        KernelVariant::ScatterDirect,
    ];

    /// Stable label used in `.gsm` metadata, `{"op":"models"}`/stats,
    /// the Prometheus exposition, and the profiler fingerprint.
    pub fn name(self) -> &'static str {
        match self {
            KernelVariant::Generic => "generic",
            KernelVariant::SmallGroupUnrolled => "unrolled",
            KernelVariant::LaneBlocked => "lane_blocked",
            KernelVariant::ScatterDirect => "scatter_direct",
        }
    }

    /// Parse a [`name`](KernelVariant::name) label back (metadata
    /// readers; unknown labels are a clean error so old readers fall
    /// back to classification).
    pub fn parse(s: &str) -> Result<KernelVariant> {
        match s {
            "generic" => Ok(KernelVariant::Generic),
            "unrolled" => Ok(KernelVariant::SmallGroupUnrolled),
            "lane_blocked" => Ok(KernelVariant::LaneBlocked),
            "scatter_direct" => Ok(KernelVariant::ScatterDirect),
            other => anyhow::bail!(
                "unknown kernel variant {other:?} (generic|unrolled|lane_blocked|scatter_direct)"
            ),
        }
    }

    /// Whether this variant can legally execute `plan`'s geometry.
    /// `Generic` supports everything; the specialized loops have the
    /// preconditions their code depends on.
    pub fn supports(self, plan: &GsExecPlan) -> bool {
        match self {
            KernelVariant::Generic => true,
            KernelVariant::SmallGroupUnrolled => {
                !plan.scatter && plan.b <= 8 && plan.b.is_power_of_two()
            }
            KernelVariant::LaneBlocked => !plan.scatter && plan.k == plan.b,
            KernelVariant::ScatterDirect => plan.scatter,
        }
    }

    /// Deterministic geometry classification, run once at plan build
    /// (and again as the fallback when a pinned/persisted variant does
    /// not fit the plan):
    ///
    /// 1. scatter plans → `ScatterDirect` (always profitable: drops the
    ///    `O(rows·batch)` merge);
    /// 2. small groups (`b ≤ 8`, power of two) → `SmallGroupUnrolled`;
    /// 3. lane-heavy single-row groups (`k == b ≥ 16`) with enough work
    ///    per band (density ≥ [`DensityBand::Mid`]) and no pathological
    ///    chunk skew (≤ 4×) → `LaneBlocked`;
    /// 4. everything else → `Generic`.
    pub fn classify(plan: &GsExecPlan) -> KernelVariant {
        let g = plan.geometry();
        if g.scatter {
            return KernelVariant::ScatterDirect;
        }
        if g.lanes <= 8 && g.lanes.is_power_of_two() {
            return KernelVariant::SmallGroupUnrolled;
        }
        if g.k == g.lanes
            && g.lanes >= 16
            && g.density_band != DensityBand::Low
            && g.chunk_skew <= 4.0
        {
            return KernelVariant::LaneBlocked;
        }
        KernelVariant::Generic
    }
}

impl GsExecPlan {
    /// The classified geometry this plan dispatches on.
    pub fn geometry(&self) -> PlanGeometry {
        let nbands = self.nbands();
        // A band holds at most `cols / k` groups (each group contributes
        // `k` of a row's ≤ `cols` nonzeros).
        let capacity = (self.cols / self.k.max(1)).max(1);
        let density = if nbands == 0 {
            0.0
        } else {
            self.ngroups() as f64 / (nbands * capacity) as f64
        };
        let density_band = if density < 0.05 {
            DensityBand::Low
        } else if density < 0.5 {
            DensityBand::Mid
        } else {
            DensityBand::High
        };
        let counts: Vec<usize> = self.chunks.iter().map(|c| c.groups).collect();
        let max = counts.iter().copied().max().unwrap_or(0) as f64;
        let mean = if counts.is_empty() {
            0.0
        } else {
            counts.iter().sum::<usize>() as f64 / counts.len() as f64
        };
        let chunk_skew = if mean > 0.0 { max / mean } else { 1.0 };
        PlanGeometry {
            lanes: self.b,
            k: self.k,
            band_rows: self.band_rows(),
            scatter: self.scatter,
            density,
            density_band,
            chunk_skew,
        }
    }

    /// The variant [`execute`](GsExecPlan::execute) dispatches to —
    /// classified at pack time, overridden by
    /// [`set_kernel_variant`](GsExecPlan::set_kernel_variant) (artifact
    /// pin) or [`tune`](GsExecPlan::tune).
    pub fn kernel_variant(&self) -> KernelVariant {
        self.variant
    }

    /// Pin the dispatch variant. Fails if the variant's preconditions
    /// don't hold for this plan's geometry (callers wanting the lenient
    /// "fall back to classification" behavior — e.g. version-tolerant
    /// artifact readers — check [`KernelVariant::supports`] first).
    pub fn set_kernel_variant(&mut self, v: KernelVariant) -> Result<()> {
        ensure!(
            v.supports(self),
            "kernel variant {} does not fit this plan's geometry ({:?})",
            v.name(),
            self.geometry()
        );
        self.variant = v;
        Ok(())
    }

    /// One-shot microbenchmark pick: time every supported menu variant
    /// on deterministic synthetic activations (fixed PRNG seed) and
    /// cache the fastest in the plan. Time-boxed to `budget` split
    /// evenly across candidates (at least one rep each, so a tiny
    /// budget still yields a decision); candidates run in
    /// [`KernelVariant::MENU`] order and ties keep the earlier entry,
    /// so the ordering is deterministic even though the timings are
    /// not. Serial timings (the per-chunk inner loop is what varies;
    /// the parallel drivers share it).
    pub fn tune(&mut self, batch: usize, budget: Duration) -> KernelVariant {
        let batch = batch.clamp(1, 64);
        let mut rng = Prng::new(0x675f74756e65); // "g_tune"
        let acts = rng.normal_vec(self.cols * batch, 1.0);
        let menu: Vec<KernelVariant> = KernelVariant::MENU
            .iter()
            .copied()
            .filter(|v| v.supports(self))
            .collect();
        if menu.len() <= 1 {
            if let Some(&v) = menu.first() {
                self.variant = v;
            }
            return self.variant;
        }
        let share = budget / menu.len() as u32;
        let mut best: Option<(f64, KernelVariant)> = None;
        for &v in &menu {
            // One warmup rep (page in the plan), then best-of until the
            // share is spent. Reps are capped so a mis-measured clock
            // can't spin forever.
            std::hint::black_box(serial_with_variant(self, v, &acts, batch, None));
            let started = Instant::now();
            let mut fastest = f64::INFINITY;
            let mut reps = 0u32;
            while reps == 0 || (started.elapsed() < share && reps < 64) {
                let t0 = Instant::now();
                std::hint::black_box(serial_with_variant(self, v, &acts, batch, None));
                fastest = fastest.min(t0.elapsed().as_secs_f64());
                reps += 1;
            }
            if best.map_or(true, |(t, _)| fastest < t) {
                best = Some((fastest, v));
            }
        }
        self.variant = best.expect("menu is non-empty").1;
        self.variant
    }

    /// Execute the plan's batched spMM through the dispatch menu:
    /// `Y = W X`, feature-major in and out (see
    /// [`gs_matmul`](super::exec::gs_matmul)). Runs on `pool` when one
    /// is given and the plan has more than one chunk, serially
    /// otherwise — the same split the legacy call sites hand-coded.
    /// Bit-identical to [`gs_matmul_scalar`](super::exec::gs_matmul_scalar)
    /// for every variant at any worker count.
    pub fn execute(
        plan: &Arc<GsExecPlan>,
        acts: &Arc<Vec<f32>>,
        batch: usize,
        pool: Option<&ThreadPool>,
    ) -> Vec<f32> {
        GsExecPlan::execute_bias(plan, acts, batch, None, pool)
    }

    /// [`execute`](GsExecPlan::execute) with the output bias fused into
    /// the accumulation (rows seeded with their bias; uncovered rows
    /// come out as exactly `bias[row]`) — the serving hot path.
    pub fn execute_bias(
        plan: &Arc<GsExecPlan>,
        acts: &Arc<Vec<f32>>,
        batch: usize,
        bias: Option<&Arc<Vec<f32>>>,
        pool: Option<&ThreadPool>,
    ) -> Vec<f32> {
        match pool {
            Some(pool) if plan.chunks.len() > 1 => {
                execute_parallel(plan, acts, batch, bias, pool, plan.variant)
            }
            _ => serial_with_variant(plan, plan.variant, acts, batch, bias.map(|b| b.as_slice())),
        }
    }

    /// Serial [`execute`](GsExecPlan::execute) on plain slices (no
    /// `Arc`s, no pool) — tests and single-threaded embedders.
    pub fn execute_serial(&self, acts: &[f32], batch: usize) -> Vec<f32> {
        serial_with_variant(self, self.variant, acts, batch, None)
    }
}

// ---------------------------------------------------------------------------
// Serial execution (moved here from exec.rs; packing stayed behind).
// ---------------------------------------------------------------------------

/// Planned single-vector spMV body (see
/// [`gs_matvec_planned`](super::exec::gs_matvec_planned)).
pub(crate) fn matvec_planned(plan: &GsExecPlan, act: &[f32]) -> Vec<f32> {
    assert_eq!(act.len(), plan.cols, "activation length mismatch");
    let mut y = vec![0.0f32; plan.rows];
    match &plan.joined {
        Joined::F32(words) => matvec_words(plan, words, act, &mut y),
        Joined::F16(words) => matvec_words(plan, words, act, &mut y),
    }
    y
}

fn matvec_words<W: JoinedWord>(plan: &GsExecPlan, joined: &[W], act: &[f32], y: &mut [f32]) {
    let b = plan.b;
    let band_rows = plan.band_rows();
    let ls = &plan.lane_slot;
    for band in 0..plan.nbands() {
        // Rows of this band's slots (identity span for non-scatter,
        // rowmap slice for scatter) — both indirections resolved at pack.
        let srow = &plan.slot_rows[band * band_rows..(band + 1) * band_rows];
        let lo = plan.band_ptr[band] as usize;
        let hi = plan.band_ptr[band + 1] as usize;
        for g in lo..hi {
            let off = g * 2 * b;
            let idx = &joined[off..off + b];
            let val = &joined[off + b..off + 2 * b];
            let mut j = 0;
            // Lanes unrolled ×4; adds stay in lane order, so rows shared
            // between lanes (k > 1) accumulate exactly like the oracle.
            while j + 4 <= b {
                y[srow[ls[j] as usize] as usize] += val[j].lane_value() * act[idx[j].lane_index()];
                y[srow[ls[j + 1] as usize] as usize] +=
                    val[j + 1].lane_value() * act[idx[j + 1].lane_index()];
                y[srow[ls[j + 2] as usize] as usize] +=
                    val[j + 2].lane_value() * act[idx[j + 2].lane_index()];
                y[srow[ls[j + 3] as usize] as usize] +=
                    val[j + 3].lane_value() * act[idx[j + 3].lane_index()];
                j += 4;
            }
            while j < b {
                y[srow[ls[j] as usize] as usize] += val[j].lane_value() * act[idx[j].lane_index()];
                j += 1;
            }
        }
    }
}

/// Execute the bands of `chunk`, accumulating into `out` where local row
/// 0 corresponds to band `chunk.band_lo`'s first slot. `acts` and `out`
/// are feature-major: `[feature][batch]`, batch contiguous.
///
/// `FORCE_SCALAR` pins the inner block to [`axpy_block_scalar`] even when
/// the `simd` feature is on (the differential baseline).
fn exec_chunk_words<W: JoinedWord, const FORCE_SCALAR: bool>(
    plan: &GsExecPlan,
    joined: &[W],
    acts: &[f32],
    batch: usize,
    chunk: Chunk,
    out: &mut [f32],
) {
    let b = plan.b;
    let band_rows = plan.band_rows();
    debug_assert!(out.len() >= (chunk.band_hi - chunk.band_lo) * band_rows * batch);
    for band in chunk.band_lo..chunk.band_hi {
        let slot_base = (band - chunk.band_lo) * band_rows;
        let lo = plan.band_ptr[band] as usize;
        let hi = plan.band_ptr[band + 1] as usize;
        for g in lo..hi {
            let off = g * 2 * b;
            let idx = &joined[off..off + b];
            let val = &joined[off + b..off + 2 * b];
            for j in 0..b {
                let col = idx[j].lane_index();
                // Widening convert (f16 plans) happens here, once per
                // gathered weight — not once per batch column.
                let w = val[j].lane_value();
                let row = slot_base + plan.lane_slot[j] as usize;
                let a0 = col * batch;
                let o0 = row * batch;
                // One gathered (index, value) pair feeds a full
                // BATCH_BLOCK-wide multiply-accumulate on contiguous
                // activations: explicit SIMD with the `simd` feature,
                // the register-blocked scalar loop otherwise.
                let mut r = 0;
                while r + BATCH_BLOCK <= batch {
                    let a = &acts[a0 + r..a0 + r + BATCH_BLOCK];
                    let o = &mut out[o0 + r..o0 + r + BATCH_BLOCK];
                    if FORCE_SCALAR {
                        axpy_block_scalar(w, a, o);
                    } else {
                        axpy_block(w, a, o);
                    }
                    r += BATCH_BLOCK;
                }
                while r < batch {
                    out[o0 + r] += w * acts[a0 + r];
                    r += 1;
                }
            }
        }
    }
}

pub(crate) fn exec_chunk_into(
    plan: &GsExecPlan,
    acts: &[f32],
    batch: usize,
    chunk: Chunk,
    out: &mut [f32],
) {
    match &plan.joined {
        Joined::F32(w) => exec_chunk_words::<u32, false>(plan, w, acts, batch, chunk, out),
        Joined::F16(w) => exec_chunk_words::<u16, false>(plan, w, acts, batch, chunk, out),
    }
}

fn exec_chunk_into_scalar(
    plan: &GsExecPlan,
    acts: &[f32],
    batch: usize,
    chunk: Chunk,
    out: &mut [f32],
) {
    match &plan.joined {
        Joined::F32(w) => exec_chunk_words::<u32, true>(plan, w, acts, batch, chunk, out),
        Joined::F16(w) => exec_chunk_words::<u16, true>(plan, w, acts, batch, chunk, out),
    }
}

// ---------------------------------------------------------------------------
// Specialized inner loops (the dispatch menu).
// ---------------------------------------------------------------------------

/// The `SmallGroupUnrolled` chunk executor: monomorphize the lane loop
/// over `const B` so it fully unrolls.
fn exec_chunk_unrolled(plan: &GsExecPlan, acts: &[f32], batch: usize, chunk: Chunk, out: &mut [f32]) {
    match &plan.joined {
        Joined::F32(w) => unrolled_by_b::<u32>(plan, w, acts, batch, chunk, out),
        Joined::F16(w) => unrolled_by_b::<u16>(plan, w, acts, batch, chunk, out),
    }
}

fn unrolled_by_b<W: JoinedWord>(
    plan: &GsExecPlan,
    joined: &[W],
    acts: &[f32],
    batch: usize,
    chunk: Chunk,
    out: &mut [f32],
) {
    match plan.b {
        1 => unrolled_words::<W, 1>(plan, joined, acts, batch, chunk, out),
        2 => unrolled_words::<W, 2>(plan, joined, acts, batch, chunk, out),
        4 => unrolled_words::<W, 4>(plan, joined, acts, batch, chunk, out),
        8 => unrolled_words::<W, 8>(plan, joined, acts, batch, chunk, out),
        // Unreachable through classification/supports; safe fallback.
        _ => exec_chunk_words::<W, false>(plan, joined, acts, batch, chunk, out),
    }
}

/// Same sweep as [`exec_chunk_words`], with the lane loop trip count a
/// compile-time constant: the `for j in 0..B` unrolls completely and the
/// `[W; B]` group views carry no bounds checks. Accumulation order per
/// output element is identical (lanes ascending, groups ascending,
/// bands ascending), so results are bit-identical.
fn unrolled_words<W: JoinedWord, const B: usize>(
    plan: &GsExecPlan,
    joined: &[W],
    acts: &[f32],
    batch: usize,
    chunk: Chunk,
    out: &mut [f32],
) {
    debug_assert_eq!(plan.b, B);
    let band_rows = plan.band_rows();
    let mut lane_slot = [0usize; B];
    for (j, s) in plan.lane_slot.iter().enumerate() {
        lane_slot[j] = *s as usize;
    }
    for band in chunk.band_lo..chunk.band_hi {
        let slot_base = (band - chunk.band_lo) * band_rows;
        let lo = plan.band_ptr[band] as usize;
        let hi = plan.band_ptr[band + 1] as usize;
        for g in lo..hi {
            let off = g * 2 * B;
            let idx: &[W; B] = joined[off..off + B].try_into().expect("group width");
            let val: &[W; B] = joined[off + B..off + 2 * B].try_into().expect("group width");
            for j in 0..B {
                let col = idx[j].lane_index();
                let w = val[j].lane_value();
                let row = slot_base + lane_slot[j];
                let a0 = col * batch;
                let o0 = row * batch;
                let mut r = 0;
                while r + BATCH_BLOCK <= batch {
                    axpy_block(
                        w,
                        &acts[a0 + r..a0 + r + BATCH_BLOCK],
                        &mut out[o0 + r..o0 + r + BATCH_BLOCK],
                    );
                    r += BATCH_BLOCK;
                }
                while r < batch {
                    out[o0 + r] += w * acts[a0 + r];
                    r += 1;
                }
            }
        }
    }
}

/// The `LaneBlocked` chunk executor (`k == b`, so every band owns
/// exactly one output row).
fn exec_chunk_lane_blocked(
    plan: &GsExecPlan,
    acts: &[f32],
    batch: usize,
    chunk: Chunk,
    out: &mut [f32],
) {
    match &plan.joined {
        Joined::F32(w) => lane_blocked_words(plan, w, acts, batch, chunk, out),
        Joined::F16(w) => lane_blocked_words(plan, w, acts, batch, chunk, out),
    }
}

/// Register-block over the band's single output row: the output block
/// loads once per (band, batch-block) and stays in registers across
/// every group and lane of the band, instead of a load+store round trip
/// per lane. Per output element the accumulation order is still groups
/// ascending, lanes ascending — bit-identical to the generic loop. The
/// joined buffer is re-streamed once per batch block; serving batches
/// are a handful of blocks, and the saved output traffic dominates for
/// lane-heavy groups.
fn lane_blocked_words<W: JoinedWord>(
    plan: &GsExecPlan,
    joined: &[W],
    acts: &[f32],
    batch: usize,
    chunk: Chunk,
    out: &mut [f32],
) {
    let b = plan.b;
    debug_assert_eq!(plan.band_rows(), 1, "LaneBlocked requires k == b");
    for band in chunk.band_lo..chunk.band_hi {
        let lo = plan.band_ptr[band] as usize;
        let hi = plan.band_ptr[band + 1] as usize;
        if lo == hi {
            continue; // empty band: row keeps its seed bit-exactly
        }
        let o0 = (band - chunk.band_lo) * batch;
        let mut r = 0;
        while r + BATCH_BLOCK <= batch {
            let mut acc = [0.0f32; BATCH_BLOCK];
            acc.copy_from_slice(&out[o0 + r..o0 + r + BATCH_BLOCK]);
            for g in lo..hi {
                let off = g * 2 * b;
                let idx = &joined[off..off + b];
                let val = &joined[off + b..off + 2 * b];
                for j in 0..b {
                    let a0 = idx[j].lane_index() * batch + r;
                    axpy_block(val[j].lane_value(), &acts[a0..a0 + BATCH_BLOCK], &mut acc);
                }
            }
            out[o0 + r..o0 + r + BATCH_BLOCK].copy_from_slice(&acc);
            r += BATCH_BLOCK;
        }
        while r < batch {
            let mut acc = out[o0 + r];
            for g in lo..hi {
                let off = g * 2 * b;
                let idx = &joined[off..off + b];
                let val = &joined[off + b..off + 2 * b];
                for j in 0..b {
                    acc += val[j].lane_value() * acts[idx[j].lane_index() * batch + r];
                }
            }
            out[o0 + r] = acc;
            r += 1;
        }
    }
}

/// The `ScatterDirect` chunk executor: write every lane's global output
/// row in place through the pack-time-resolved `slot_rows` table.
///
/// # Safety contract (upheld by the callers)
///
/// `base` points at the full `rows * batch` output buffer. The scatter
/// rowmap is a permutation (validated at pack), so each global row is
/// owned by exactly one `(band, slot)`, and chunks partition bands —
/// two chunks never touch the same row even though their row sets
/// interleave. The buffer outlives every job because the pool's `map`
/// joins before the owner resumes.
fn exec_chunk_scatter_direct(
    plan: &GsExecPlan,
    acts: &[f32],
    batch: usize,
    chunk: Chunk,
    base: OutPtr,
) {
    match &plan.joined {
        Joined::F32(w) => scatter_direct_words(plan, w, acts, batch, chunk, base),
        Joined::F16(w) => scatter_direct_words(plan, w, acts, batch, chunk, base),
    }
}

fn scatter_direct_words<W: JoinedWord>(
    plan: &GsExecPlan,
    joined: &[W],
    acts: &[f32],
    batch: usize,
    chunk: Chunk,
    base: OutPtr,
) {
    let b = plan.b;
    let band_rows = plan.band_rows();
    for band in chunk.band_lo..chunk.band_hi {
        let srow = &plan.slot_rows[band * band_rows..(band + 1) * band_rows];
        let lo = plan.band_ptr[band] as usize;
        let hi = plan.band_ptr[band + 1] as usize;
        for g in lo..hi {
            let off = g * 2 * b;
            let idx = &joined[off..off + b];
            let val = &joined[off + b..off + 2 * b];
            for j in 0..b {
                let col = idx[j].lane_index();
                let w = val[j].lane_value();
                let row = srow[plan.lane_slot[j] as usize] as usize;
                // SAFETY: `row` is owned exclusively by this chunk (the
                // rowmap is a permutation and every (band, slot) lives in
                // exactly one chunk), the view is dropped before the next
                // lane's is made, and the owner joins the pool before the
                // buffer moves — see the function-level contract.
                let o = unsafe { std::slice::from_raw_parts_mut(base.0.add(row * batch), batch) };
                let a0 = col * batch;
                let mut r = 0;
                while r + BATCH_BLOCK <= batch {
                    axpy_block(w, &acts[a0 + r..a0 + r + BATCH_BLOCK], &mut o[r..r + BATCH_BLOCK]);
                    r += BATCH_BLOCK;
                }
                while r < batch {
                    o[r] += w * acts[a0 + r];
                    r += 1;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Drivers: serial and pooled, variant-aware.
// ---------------------------------------------------------------------------

/// The output buffer every spMM path accumulates into: zeros, or — with a
/// fused bias — each row pre-seeded with its bias value, so `bias + Σ w·a`
/// accumulates in one pass with no post-sweep over the logits. Rows not
/// covered by any band (all-zero rows at the matrix tail) come out as
/// exactly `bias[row]`.
fn seeded_out(rows: usize, batch: usize, bias: Option<&[f32]>) -> Vec<f32> {
    match bias {
        None => vec![0.0f32; rows * batch],
        Some(bias) => {
            assert_eq!(bias.len(), rows, "bias length mismatch");
            let mut out = Vec::with_capacity(rows * batch);
            for &b in bias {
                out.extend(std::iter::repeat(b).take(batch));
            }
            out
        }
    }
}

/// Seed one chunk's private accumulation buffer with the bias of each
/// slot's global output row (the merge copy then carries `bias + Σ w·a`
/// to the output — identical accumulation order to the direct-write and
/// serial paths, hence bit-identical results).
fn seed_local(
    plan: &GsExecPlan,
    batch: usize,
    chunk: Chunk,
    bias: Option<&[f32]>,
    local: &mut [f32],
) {
    let Some(bias) = bias else { return };
    let band_rows = plan.band_rows();
    for band in chunk.band_lo..chunk.band_hi {
        for slot in 0..band_rows {
            let row = plan.slot_rows[band * band_rows + slot] as usize;
            let dst = ((band - chunk.band_lo) * band_rows + slot) * batch;
            local[dst..dst + batch].fill(bias[row]);
        }
    }
}

/// Copy one chunk's private accumulation into the global output through
/// the plan's slot→row table. Each global row is owned by exactly one
/// (band, slot), so this is a copy, not a reduction.
fn merge_chunk(plan: &GsExecPlan, batch: usize, chunk: Chunk, local: &[f32], out: &mut [f32]) {
    let band_rows = plan.band_rows();
    for band in chunk.band_lo..chunk.band_hi {
        for slot in 0..band_rows {
            let row = plan.slot_rows[band * band_rows + slot] as usize;
            let src = ((band - chunk.band_lo) * band_rows + slot) * batch;
            let dst = row * batch;
            out[dst..dst + batch].copy_from_slice(&local[src..src + batch]);
        }
    }
}

/// Dispatch one chunk through the non-scatter menu (`ScatterDirect` has
/// its own driver; `Generic` on a scatter plan goes through the merge
/// strategy, never here).
fn exec_chunk_variant(
    plan: &GsExecPlan,
    variant: KernelVariant,
    acts: &[f32],
    batch: usize,
    chunk: Chunk,
    out: &mut [f32],
) {
    match variant {
        KernelVariant::SmallGroupUnrolled => exec_chunk_unrolled(plan, acts, batch, chunk, out),
        KernelVariant::LaneBlocked => exec_chunk_lane_blocked(plan, acts, batch, chunk, out),
        _ => exec_chunk_into(plan, acts, batch, chunk, out),
    }
}

/// The legacy serial spMM (the eight deprecated entry points route
/// here): generic inner loop, optionally pinned to the scalar block —
/// [`gs_matmul_scalar`](super::exec::gs_matmul_scalar) is the menu's
/// differential oracle and must never itself dispatch.
pub(crate) fn matmul_generic(
    plan: &GsExecPlan,
    acts: &[f32],
    batch: usize,
    force_scalar: bool,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    assert!(batch > 0, "gs_matmul with empty batch");
    assert_eq!(acts.len(), plan.cols * batch, "activation shape mismatch");
    let mut out = seeded_out(plan.rows, batch, bias);
    let band_rows = plan.band_rows();
    let all = Chunk {
        band_lo: 0,
        band_hi: plan.nbands(),
        groups: plan.ngroups(),
    };
    if plan.scatter {
        // Accumulate band-local (bias-seeded through the rowmap), then
        // place rows through the rowmap; uncovered rows keep their seed.
        let mut local = vec![0.0f32; plan.nbands() * band_rows * batch];
        seed_local(plan, batch, all, bias, &mut local);
        if force_scalar {
            exec_chunk_into_scalar(plan, acts, batch, all, &mut local);
        } else {
            exec_chunk_into(plan, acts, batch, all, &mut local);
        }
        merge_chunk(plan, batch, all, &local, &mut out);
    } else {
        // Identity slot→row mapping: accumulate straight into `out`.
        if force_scalar {
            exec_chunk_into_scalar(plan, acts, batch, all, &mut out);
        } else {
            exec_chunk_into(plan, acts, batch, all, &mut out);
        }
    }
    out
}

/// Variant-aware serial spMM — the single-threaded arm of
/// [`GsExecPlan::execute_bias`] (and the loop body [`GsExecPlan::tune`]
/// times).
fn serial_with_variant(
    plan: &GsExecPlan,
    variant: KernelVariant,
    acts: &[f32],
    batch: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    assert!(batch > 0, "execute with empty batch");
    assert_eq!(acts.len(), plan.cols * batch, "activation shape mismatch");
    match variant {
        KernelVariant::ScatterDirect => {
            let mut out = seeded_out(plan.rows, batch, bias);
            let all = Chunk {
                band_lo: 0,
                band_hi: plan.nbands(),
                groups: plan.ngroups(),
            };
            let base = OutPtr(out.as_mut_ptr());
            // SAFETY: single-threaded use of the raw view; `out` is not
            // touched through any other path until the call returns.
            exec_chunk_scatter_direct(plan, acts, batch, all, base);
            out
        }
        KernelVariant::Generic => matmul_generic(plan, acts, batch, false, bias),
        v => {
            debug_assert!(!plan.scatter, "specialized non-scatter variant on a scatter plan");
            let mut out = seeded_out(plan.rows, batch, bias);
            let all = Chunk {
                band_lo: 0,
                band_hi: plan.nbands(),
                groups: plan.ngroups(),
            };
            exec_chunk_variant(plan, v, acts, batch, all, &mut out);
            out
        }
    }
}

/// Pooled spMM with an explicit variant — the parallel arm of
/// [`GsExecPlan::execute_bias`], and (with `Generic`) the body of the
/// deprecated `gs_matmul_parallel*` wrappers. Falls back to the serial
/// driver for single-chunk plans, exactly like the legacy entry points.
pub(crate) fn execute_parallel(
    plan: &Arc<GsExecPlan>,
    acts: &Arc<Vec<f32>>,
    batch: usize,
    bias: Option<&Arc<Vec<f32>>>,
    pool: &ThreadPool,
    variant: KernelVariant,
) -> Vec<f32> {
    assert!(batch > 0, "gs_matmul_parallel with empty batch");
    assert_eq!(acts.len(), plan.cols * batch, "activation shape mismatch");
    if plan.chunks.len() <= 1 {
        return serial_with_variant(plan, variant, acts, batch, bias.map(|b| b.as_slice()));
    }
    match variant {
        KernelVariant::ScatterDirect => parallel_scatter_direct(plan, acts, batch, bias, pool),
        _ if plan.scatter => parallel_merge(plan, acts, batch, bias, pool),
        v => parallel_direct(plan, acts, batch, bias, pool, v),
    }
}

/// Non-scatter pooled direct-write: chunk `c` owns output rows
/// `band_lo*band_rows .. band_hi*band_rows` — a contiguous span,
/// provably disjoint from every other chunk's because chunks partition
/// the band range — so each job writes its slice of the shared output
/// buffer with no private accumulator and no merge pass.
fn parallel_direct(
    plan: &Arc<GsExecPlan>,
    acts: &Arc<Vec<f32>>,
    batch: usize,
    bias: Option<&Arc<Vec<f32>>>,
    pool: &ThreadPool,
    variant: KernelVariant,
) -> Vec<f32> {
    let band_rows = plan.band_rows();
    let mut out = seeded_out(plan.rows, batch, bias.map(|b| b.as_slice()));
    let base = OutPtr(out.as_mut_ptr());
    let plan2 = Arc::clone(plan);
    let acts2 = Arc::clone(acts);
    let times = pool.map(plan.chunks.clone(), move |chunk| {
        let timer = profile::start();
        let lo = chunk.band_lo * band_rows * batch;
        let len = (chunk.band_hi - chunk.band_lo) * band_rows * batch;
        // SAFETY: chunks partition `0..nbands` contiguously and the
        // slot→row mapping is the identity (non-scatter), so the spans
        // `[lo, lo+len)` of different jobs never overlap; `out` outlives
        // every job because `pool.map` joins before returning (including
        // when a job panics — `join` drains the queue first).
        let span = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), len) };
        exec_chunk_variant(&plan2, variant, &acts2, batch, chunk, span);
        profile::stop(timer)
    });
    profile::record_call(plan, variant, &times);
    out
}

/// Scatter pooled direct-write (the `ScatterDirect` menu entry): the
/// shared output is bias-seeded once, then every chunk writes its own
/// interleaved-but-disjoint rows in place through `slot_rows` — no
/// private accumulator and no `O(rows·batch)` merge copy. Uncovered
/// rows keep their seed, exactly like the merge path.
fn parallel_scatter_direct(
    plan: &Arc<GsExecPlan>,
    acts: &Arc<Vec<f32>>,
    batch: usize,
    bias: Option<&Arc<Vec<f32>>>,
    pool: &ThreadPool,
) -> Vec<f32> {
    let mut out = seeded_out(plan.rows, batch, bias.map(|b| b.as_slice()));
    let base = OutPtr(out.as_mut_ptr());
    let plan2 = Arc::clone(plan);
    let acts2 = Arc::clone(acts);
    let times = pool.map(plan.chunks.clone(), move |chunk| {
        let timer = profile::start();
        // SAFETY: see `exec_chunk_scatter_direct` — the rowmap is a
        // permutation, so chunks own disjoint row sets, and `pool.map`
        // joins before `out` moves.
        exec_chunk_scatter_direct(&plan2, &acts2, batch, chunk, base);
        profile::stop(timer)
    });
    profile::record_call(plan, KernelVariant::ScatterDirect, &times);
    out
}

/// Pooled private-accumulate+merge for every pattern — the benchmark
/// baseline for both direct-write paths and the differential oracle for
/// `ScatterDirect` (the merge copy is `O(rows·batch)` and shows up at
/// low sparsity).
pub(crate) fn parallel_merge(
    plan: &Arc<GsExecPlan>,
    acts: &Arc<Vec<f32>>,
    batch: usize,
    bias: Option<&Arc<Vec<f32>>>,
    pool: &ThreadPool,
) -> Vec<f32> {
    assert!(batch > 0, "gs_matmul_parallel_merge with empty batch");
    assert_eq!(acts.len(), plan.cols * batch, "activation shape mismatch");
    let chunks: Vec<Chunk> = plan.chunks.clone();
    if chunks.len() <= 1 {
        return matmul_generic(plan, acts, batch, false, bias.map(|b| b.as_slice()));
    }
    let band_rows = plan.band_rows();
    let plan2 = Arc::clone(plan);
    let acts2 = Arc::clone(acts);
    let bias2 = bias.map(Arc::clone);
    let timed = pool.map(chunks.clone(), move |chunk| {
        let timer = profile::start();
        let rows = (chunk.band_hi - chunk.band_lo) * band_rows;
        let mut local = vec![0.0f32; rows * batch];
        seed_local(&plan2, batch, chunk, bias2.as_ref().map(|b| b.as_slice()), &mut local);
        exec_chunk_into(&plan2, &acts2, batch, chunk, &mut local);
        (local, profile::stop(timer))
    });
    let mut out = seeded_out(plan.rows, batch, bias.map(|b| b.as_slice()));
    let mut times = Vec::with_capacity(timed.len());
    for (chunk, (local, secs)) in chunks.iter().zip(&timed) {
        merge_chunk(plan, batch, *chunk, local, &mut out);
        times.push(*secs);
    }
    profile::record_call(plan, KernelVariant::Generic, &times);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::pattern::Pattern;
    use crate::testing::model::build_random_gs;

    fn plan_for(pattern: Pattern, sparsity: f64, seed: u64) -> GsExecPlan {
        let (_, gs) = build_random_gs(64, 128, pattern, sparsity, seed).unwrap();
        GsExecPlan::with_chunks(&gs, 4).unwrap()
    }

    #[test]
    fn classification_follows_geometry_rules() {
        // Scatter always takes the direct-write variant.
        let p = plan_for(Pattern::GsScatter { b: 8, k: 2 }, 0.7, 1);
        assert_eq!(p.kernel_variant(), KernelVariant::ScatterDirect);
        // Small power-of-two groups unroll.
        let p = plan_for(Pattern::Gs { b: 8, k: 4 }, 0.7, 2);
        assert_eq!(p.kernel_variant(), KernelVariant::SmallGroupUnrolled);
        // Lane-heavy single-row groups register-block.
        let p = plan_for(Pattern::Gs { b: 16, k: 16 }, 0.7, 3);
        assert_eq!(p.kernel_variant(), KernelVariant::LaneBlocked);
        // Multi-row wide groups have no specialization yet.
        let p = plan_for(Pattern::Gs { b: 16, k: 4 }, 0.7, 4);
        assert_eq!(p.kernel_variant(), KernelVariant::Generic);
    }

    #[test]
    fn set_kernel_variant_validates_geometry() {
        let mut p = plan_for(Pattern::Gs { b: 8, k: 4 }, 0.7, 5);
        assert!(p.set_kernel_variant(KernelVariant::Generic).is_ok());
        assert!(p.set_kernel_variant(KernelVariant::SmallGroupUnrolled).is_ok());
        // k != b: lane blocking does not apply.
        assert!(p.set_kernel_variant(KernelVariant::LaneBlocked).is_err());
        // Not a scatter plan.
        assert!(p.set_kernel_variant(KernelVariant::ScatterDirect).is_err());
        assert_eq!(p.kernel_variant(), KernelVariant::SmallGroupUnrolled);
    }

    #[test]
    fn geometry_reports_density_and_skew() {
        let p = plan_for(Pattern::Gs { b: 16, k: 16 }, 0.9, 6);
        let g = p.geometry();
        assert_eq!(g.lanes, 16);
        assert_eq!(g.band_rows, 1);
        assert!(!g.scatter);
        assert!(g.density > 0.0 && g.density <= 1.0, "{}", g.density);
        assert!(g.chunk_skew >= 1.0, "{}", g.chunk_skew);
    }

    #[test]
    fn tune_picks_a_supported_variant_and_caches_it() {
        let mut p = plan_for(Pattern::Gs { b: 8, k: 8 }, 0.8, 7);
        let v = p.tune(8, Duration::from_millis(10));
        assert_eq!(v, p.kernel_variant());
        assert!(v.supports(&p), "tuned variant must fit the plan");
        // Scatter menu: Generic (merge) vs ScatterDirect only.
        let (_, gs) = build_random_gs(64, 128, Pattern::GsScatter { b: 8, k: 2 }, 0.7, 8).unwrap();
        let mut p = GsExecPlan::with_chunks(&gs, 4).unwrap();
        let v = p.tune(8, Duration::from_millis(10));
        assert!(matches!(v, KernelVariant::Generic | KernelVariant::ScatterDirect));
    }

    #[test]
    fn variant_names_roundtrip() {
        for v in KernelVariant::MENU {
            assert_eq!(KernelVariant::parse(v.name()).unwrap(), v);
        }
        assert!(KernelVariant::parse("warp_speed").is_err());
    }
}
