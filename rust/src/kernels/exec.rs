//! Plan packing for GS-compressed matrices (and the legacy kernel entry
//! points, now thin deprecated wrappers).
//!
//! [`crate::kernels::native::gs_matvec`] is the 20-line numerics oracle:
//! it re-reads `indptr`, divides `j / k` per entry, and walks `value` and
//! `index` as two separate arrays. This module packs a [`GsExecPlan`]
//! once per weight matrix:
//!
//! * **Joined group layout** (paper §V): each group's `B` column indices
//!   sit immediately before its `B` values in one buffer, so a group is
//!   one streaming read — previously only modeled in the simulator
//!   (`spmv_gs_sim_joined`), now used for real execution.
//! * **Selectable value precision** ([`PlanPrecision`]): `F32` keeps the
//!   packed values bit-exact; `F16` stores them at the paper's storage
//!   resolution (§X) as half-floats with `u16` indices — half the packed
//!   bytes and half the memory traffic of the f32 plan, with a widening
//!   convert ([`crate::util::f16`]) in the inner loop.
//! * **Precomputed output slots**: the `entry_row` division and the
//!   scatter `rowmap` indirection are resolved at pack time into a
//!   per-(band, slot) row table plus a `b`-entry lane→slot table; the
//!   inner loop is pure loads, FMAs, stores.
//! * **Balanced chunks**: bands are partitioned into contiguous spans with
//!   near-equal *group* counts (not band counts — sparsity can be ragged
//!   across bands), the unit of parallelism for the pooled kernels. Each
//!   band's output rows are owned by exactly one chunk, so chunks never
//!   race. Results are bit-identical to the serial kernel at any thread
//!   count.
//! * **Kernel classification**: pack time is when the whole geometry is
//!   known, so the plan also classifies itself onto the specialized
//!   kernel menu ([`KernelVariant`]) — see [`crate::kernels::dispatch`].
//!
//! *Execution* lives in [`crate::kernels::dispatch`]: serving, benches
//! and examples call [`GsExecPlan::execute`] /
//! [`GsExecPlan::execute_bias`], which dispatch on the plan's classified
//! (or tuned, or artifact-pinned) [`KernelVariant`]. The historical
//! `gs_matmul*` entry points below survive as deprecated thin wrappers
//! pinned to the generic inner loop, so differential tests and benches
//! keep a stable baseline:
//!
//! * [`gs_matvec_planned`] — single activation vector, lanes unrolled ×4.
//! * [`gs_matmul`] / [`gs_matmul_bias`] — serial batched spMM, generic
//!   register-blocked inner loop ([`BATCH_BLOCK`]; `std::simd` with the
//!   `simd` cargo feature, scalar fallback otherwise, bit-identical).
//! * [`gs_matmul_scalar`] — the scalar-pinned differential oracle every
//!   dispatch-menu variant must match bit for bit.
//! * [`gs_matmul_parallel`] / [`gs_matmul_parallel_bias`] — pooled with
//!   the generic loop (direct-write non-scatter, merge on scatter).
//! * [`gs_matmul_parallel_merge`] / [`gs_matmul_parallel_merge_bias`] —
//!   pooled private-accumulate+merge for every pattern, the benchmark
//!   baseline for both direct-write strategies.
//!
//! All kernels preserve the oracle's accumulation order per output row,
//! so f32 plans match `gs_matvec` bit for bit (per batch column), and f16
//! plans match the oracle run on the f16-quantized format bit for bit.

use super::dispatch::{self, KernelVariant};
use crate::sparse::format::GsFormat;
use crate::util::f16::f16_bits_to_f32;
use crate::util::threadpool::ThreadPool;
use anyhow::{ensure, Context, Result};
use std::sync::Arc;

/// Batch columns per register block in the batched kernels. 8 f32 lanes =
/// one AVX2 vector / two NEON vectors; small enough that the block of
/// accumulating rows stays in registers.
pub const BATCH_BLOCK: usize = 8;

/// Storage resolution of a packed plan's weight values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanPrecision {
    /// Values as f32 bits; kernels are bit-exact vs the `gs_matvec` oracle.
    F32,
    /// Values as IEEE binary16 with `u16` column indices — the paper's
    /// storage resolution (§X). Halves packed bytes; kernels are bit-exact
    /// vs the oracle on the f16-quantized format.
    F16,
}

impl PlanPrecision {
    /// CLI/bench label.
    pub fn name(self) -> &'static str {
        match self {
            PlanPrecision::F32 => "f32",
            PlanPrecision::F16 => "f16",
        }
    }

    /// Parse a CLI value (`f32` | `f16`).
    pub fn parse(s: &str) -> Result<PlanPrecision> {
        match s {
            "f32" | "F32" => Ok(PlanPrecision::F32),
            "f16" | "F16" => Ok(PlanPrecision::F16),
            other => anyhow::bail!("unknown precision {other} (f32|f16)"),
        }
    }
}

/// Whether the explicit `std::simd` inner loop is compiled in.
pub fn simd_enabled() -> bool {
    cfg!(feature = "simd")
}

/// A packed word of the joined buffer: interpreted as a column index in
/// the first half of a group, as a weight value in the second half.
pub(crate) trait JoinedWord: Copy + Send + Sync + 'static {
    fn lane_index(self) -> usize;
    fn lane_value(self) -> f32;
}

impl JoinedWord for u32 {
    #[inline(always)]
    fn lane_index(self) -> usize {
        self as usize
    }
    #[inline(always)]
    fn lane_value(self) -> f32 {
        f32::from_bits(self)
    }
}

impl JoinedWord for u16 {
    #[inline(always)]
    fn lane_index(self) -> usize {
        self as usize
    }
    #[inline(always)]
    fn lane_value(self) -> f32 {
        f16_bits_to_f32(self)
    }
}

/// Precision-tagged joined buffer. Layout per group: `b` index words
/// followed by `b` value words (`2*b` words total either way).
#[derive(Clone, Debug)]
pub(crate) enum Joined {
    F32(Vec<u32>),
    F16(Vec<u16>),
}

/// A contiguous span of bands executed as one parallel work unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    pub band_lo: usize,
    pub band_hi: usize,
    /// Total groups in the span (the balance criterion).
    pub groups: usize,
}

/// Prepacked execution plan for one GS-compressed matrix.
///
/// Built once per deployed weight matrix (at model load / weight-swap
/// time), then shared read-only across requests and worker threads.
/// Execution goes through [`GsExecPlan::execute`] (see
/// [`crate::kernels::dispatch`]), which dispatches on the plan's
/// classified/tuned/pinned [`KernelVariant`].
#[derive(Clone, Debug)]
pub struct GsExecPlan {
    pub b: usize,
    pub k: usize,
    pub rows: usize,
    pub cols: usize,
    /// Whether the source format carried a scatter `rowmap`.
    pub scatter: bool,
    /// Value storage resolution of the joined buffer.
    pub precision: PlanPrecision,
    /// Joined group layout: `2*b` words per group — `b` column indices
    /// followed by the `b` weight values (f32 bits or f16 bits).
    pub(crate) joined: Joined,
    /// `nbands + 1` cumulative group counts (copy of the format's indptr).
    pub(crate) band_ptr: Vec<u32>,
    /// Global output row per (band, slot): `slot_rows[band*(b/k) + s]` —
    /// the `entry_row` division and scatter rowmap lookup resolved at
    /// pack time. Lane `j` of a band writes row
    /// `slot_rows[band*(b/k) + lane_slot[j]]`; a flat per-(band, lane)
    /// table would be `k`× larger for no extra information, and at high
    /// sparsity it would rival the joined buffer itself.
    pub(crate) slot_rows: Vec<u32>,
    /// Row slot of lane `j` within any band (`j / k`) — band-independent.
    pub(crate) lane_slot: Vec<u32>,
    /// Group-count-balanced contiguous band spans.
    pub(crate) chunks: Vec<Chunk>,
    /// The dispatch-menu variant [`GsExecPlan::execute`] runs — geometry
    /// classification at pack time, overridable by `tune()` or an
    /// artifact pin ([`GsExecPlan::set_kernel_variant`]).
    pub(crate) variant: KernelVariant,
}

impl GsExecPlan {
    /// Pack `gs` at f32 with one chunk per available CPU (capped by band
    /// count).
    pub fn from_format(gs: &GsFormat) -> Result<GsExecPlan> {
        let nchunks = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        GsExecPlan::with_chunks(gs, nchunks)
    }

    /// Pack `gs` at f32 into at most `nchunks` balanced chunks.
    pub fn with_chunks(gs: &GsFormat, nchunks: usize) -> Result<GsExecPlan> {
        GsExecPlan::with_precision(gs, nchunks, PlanPrecision::F32)
    }

    /// Pack `gs` into at most `nchunks` balanced chunks at the given
    /// value precision.
    pub fn with_precision(
        gs: &GsFormat,
        nchunks: usize,
        precision: PlanPrecision,
    ) -> Result<GsExecPlan> {
        gs.validate().context("GsExecPlan source format invalid")?;
        ensure!(
            gs.b > 0 && gs.k > 0 && gs.b % gs.k == 0,
            "bad GS geometry B={} k={}",
            gs.b,
            gs.k
        );
        let band_rows = gs.b / gs.k;
        let nbands = gs.nbands();
        ensure!(
            nbands * band_rows <= gs.rows,
            "bands cover more rows than the matrix has"
        );
        if precision == PlanPrecision::F16 {
            ensure!(
                gs.cols <= u16::MAX as usize + 1,
                "f16 plans index columns with u16: cols {} > {}",
                gs.cols,
                u16::MAX as usize + 1
            );
        }

        let mut slot_rows = Vec::with_capacity(nbands * band_rows);
        for band in 0..nbands {
            for slot in 0..band_rows {
                slot_rows.push(gs.entry_row(band, slot * gs.k) as u32);
            }
        }
        let lane_slot: Vec<u32> = (0..gs.b).map(|j| (j / gs.k) as u32).collect();

        let joined = match precision {
            PlanPrecision::F32 => Joined::F32(gs.to_joined()),
            PlanPrecision::F16 => Joined::F16(gs.to_joined_f16()),
        };
        let mut plan = GsExecPlan {
            b: gs.b,
            k: gs.k,
            rows: gs.rows,
            cols: gs.cols,
            scatter: gs.rowmap.is_some(),
            precision,
            joined,
            band_ptr: gs.indptr.clone(),
            slot_rows,
            lane_slot,
            chunks: balance_chunks(&gs.indptr, nchunks),
            variant: KernelVariant::Generic,
        };
        // Geometry is now fully known (including chunk balance): classify
        // onto the specialized kernel menu.
        plan.variant = KernelVariant::classify(&plan);
        Ok(plan)
    }

    pub fn nbands(&self) -> usize {
        self.band_ptr.len() - 1
    }

    pub fn ngroups(&self) -> usize {
        *self.band_ptr.last().unwrap() as usize
    }

    pub fn band_rows(&self) -> usize {
        self.b / self.k
    }

    /// The balanced band spans used by the parallel path.
    pub fn chunks(&self) -> &[Chunk] {
        &self.chunks
    }

    /// Groups in each band (successive differences of the packed band
    /// pointer) — the raw per-band load the chunk balancer works from,
    /// surfaced for the load-imbalance profiler.
    pub fn band_group_counts(&self) -> Vec<usize> {
        self.band_ptr.windows(2).map(|w| (w[1] - w[0]) as usize).collect()
    }

    /// Bytes resident in the packed plan (joined + tables). An f16 plan's
    /// joined buffer is half the f32 plan's (2-byte words vs 4-byte).
    pub fn packed_bytes(&self) -> usize {
        let joined = match &self.joined {
            Joined::F32(v) => 4 * v.len(),
            Joined::F16(v) => 2 * v.len(),
        };
        joined
            + 4 * (self.band_ptr.len() + self.slot_rows.len() + self.lane_slot.len())
    }
}

/// Partition bands into ≤ `nchunks` contiguous spans with near-equal
/// group counts. Every band lands in exactly one span; empty trailing
/// bands are folded into the last span.
fn balance_chunks(band_ptr: &[u32], nchunks: usize) -> Vec<Chunk> {
    let nbands = band_ptr.len() - 1;
    let total = *band_ptr.last().unwrap() as usize;
    let nchunks = nchunks.max(1);
    let mut chunks = Vec::new();
    if nbands == 0 {
        return chunks;
    }
    let mut band = 0usize;
    for c in 0..nchunks {
        if band >= nbands {
            break;
        }
        let consumed = band_ptr[band] as usize;
        let remaining_chunks = nchunks - c;
        let target = (total - consumed + remaining_chunks - 1) / remaining_chunks;
        let target = target.max(1);
        let lo = band;
        let mut acc = 0usize;
        while band < nbands && acc < target {
            acc += (band_ptr[band + 1] - band_ptr[band]) as usize;
            band += 1;
        }
        chunks.push(Chunk {
            band_lo: lo,
            band_hi: band,
            groups: acc,
        });
    }
    // Fold any leftover (necessarily empty) bands into the last span.
    if band < nbands {
        if let Some(last) = chunks.last_mut() {
            last.band_hi = nbands;
        } else {
            chunks.push(Chunk {
                band_lo: 0,
                band_hi: nbands,
                groups: total,
            });
        }
    }
    chunks
}

/// One [`BATCH_BLOCK`]-wide multiply-accumulate: `o[t] += w * a[t]`.
/// Scalar form — always compiled, and the differential baseline for the
/// `simd` path (`o + w*a` per lane, mul then add, no FMA contraction, so
/// the two are bit-identical).
#[inline(always)]
pub(crate) fn axpy_block_scalar(w: f32, a: &[f32], o: &mut [f32]) {
    for t in 0..BATCH_BLOCK {
        o[t] += w * a[t];
    }
}

/// The explicit `std::simd` form of [`axpy_block_scalar`]: the gathered
/// weight is splatted and one vector multiply+add covers the whole
/// register block of activation columns.
#[cfg(feature = "simd")]
#[inline(always)]
pub(crate) fn axpy_block(w: f32, a: &[f32], o: &mut [f32]) {
    use std::simd::Simd;
    let av = Simd::<f32, BATCH_BLOCK>::from_slice(&a[..BATCH_BLOCK]);
    let ov = Simd::<f32, BATCH_BLOCK>::from_slice(&o[..BATCH_BLOCK]);
    (ov + Simd::splat(w) * av).copy_to_slice(&mut o[..BATCH_BLOCK]);
}

#[cfg(not(feature = "simd"))]
#[inline(always)]
pub(crate) fn axpy_block(w: f32, a: &[f32], o: &mut [f32]) {
    axpy_block_scalar(w, a, o);
}

/// `Send + Sync` wrapper for the base pointer of an output buffer shared
/// by direct-write pool jobs (the dispatch layer's chunk spans, the dense
/// kernel's feature spans). Safety rests entirely on the use sites: jobs
/// write disjoint spans and the owner joins before the buffer moves.
#[derive(Clone, Copy)]
pub(crate) struct OutPtr(pub(crate) *mut f32);
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

// ---------------------------------------------------------------------------
// Legacy entry points: thin wrappers over kernels::dispatch, pinned to the
// generic inner loop so differential tests and benches keep a stable
// baseline. New call sites route through `GsExecPlan::execute`.
// ---------------------------------------------------------------------------

/// Planned single-vector spMV: `y = W x` on the packed plan. An f32 plan
/// matches [`crate::kernels::native::gs_matvec`] bit for bit; an f16 plan
/// matches the oracle on the f16-quantized format bit for bit.
#[deprecated(note = "route through `GsExecPlan::execute` with batch 1 (kernels::dispatch)")]
pub fn gs_matvec_planned(plan: &GsExecPlan, act: &[f32]) -> Vec<f32> {
    dispatch::matvec_planned(plan, act)
}

/// Batched spMM: `Y = W X` with `X` feature-major (`acts[col*batch + r]`
/// is request `r`'s activation for feature `col`). Returns `Y`
/// feature-major: `out[row*batch + r]`. For an f32 plan, column `r`
/// equals `gs_matvec(gs, x_r)` bit for bit. Always runs the generic
/// inner loop regardless of the plan's classified variant.
#[deprecated(note = "route through `GsExecPlan::execute` (kernels::dispatch)")]
pub fn gs_matmul(plan: &GsExecPlan, acts: &[f32], batch: usize) -> Vec<f32> {
    dispatch::matmul_generic(plan, acts, batch, false, None)
}

/// [`gs_matmul`] with the output bias fused into the accumulation: row
/// `row` of the result is `bias[row] + Σ w·a` computed in a single pass
/// (the row is *seeded* with its bias, then accumulated in oracle order —
/// no separate sweep over the logits). Serial, parallel direct-write, and
/// parallel merge forms are all bit-identical.
#[deprecated(note = "route through `GsExecPlan::execute_bias` (kernels::dispatch)")]
pub fn gs_matmul_bias(
    plan: &GsExecPlan,
    acts: &[f32],
    batch: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    dispatch::matmul_generic(plan, acts, batch, false, bias)
}

/// [`gs_matmul`] with the inner block pinned to the scalar loop even when
/// the `simd` feature is compiled in. **The differential oracle**: every
/// dispatch-menu variant must match it bit for bit, so it never itself
/// dispatches. Deprecated for production use only; tests keep calling it.
#[deprecated(note = "differential oracle — production call sites route through `GsExecPlan::execute`")]
pub fn gs_matmul_scalar(plan: &GsExecPlan, acts: &[f32], batch: usize) -> Vec<f32> {
    dispatch::matmul_generic(plan, acts, batch, true, None)
}

/// Parallel batched spMM with the generic inner loop: plan chunks mapped
/// over `pool`, bit-identical to [`gs_matmul`] at any worker count.
/// Non-scatter plans direct-write their disjoint contiguous output
/// spans; scatter plans take the private-accumulate+merge strategy.
///
/// `plan` and `acts` travel to the workers as `Arc` clones (the pool's
/// jobs are `'static`), so the caller keeps both afterwards.
#[deprecated(note = "route through `GsExecPlan::execute` (kernels::dispatch)")]
pub fn gs_matmul_parallel(
    plan: &Arc<GsExecPlan>,
    acts: &Arc<Vec<f32>>,
    batch: usize,
    pool: &ThreadPool,
) -> Vec<f32> {
    dispatch::execute_parallel(plan, acts, batch, None, pool, KernelVariant::Generic)
}

/// [`gs_matmul_parallel`] with the output bias fused ([`gs_matmul_bias`]):
/// the shared output buffer is bias-seeded before the chunk jobs
/// accumulate into their disjoint spans (merge-path chunks seed their
/// private buffers instead), so no pass over the logits follows the spMM.
/// Bit-identical to the serial fused kernel at any worker count.
#[deprecated(note = "route through `GsExecPlan::execute_bias` (kernels::dispatch)")]
pub fn gs_matmul_parallel_bias(
    plan: &Arc<GsExecPlan>,
    acts: &Arc<Vec<f32>>,
    batch: usize,
    bias: Option<&Arc<Vec<f32>>>,
    pool: &ThreadPool,
) -> Vec<f32> {
    dispatch::execute_parallel(plan, acts, batch, bias, pool, KernelVariant::Generic)
}

/// Parallel batched spMM with the private-accumulate+merge strategy for
/// every pattern — the baseline the direct-write paths are benchmarked
/// against (the merge copy is `O(rows·batch)` and shows up at low
/// sparsity). Output is bit-identical to [`gs_matmul`] and to
/// [`gs_matmul_parallel`].
#[deprecated(note = "merge baseline — production call sites route through `GsExecPlan::execute`")]
pub fn gs_matmul_parallel_merge(
    plan: &Arc<GsExecPlan>,
    acts: &Arc<Vec<f32>>,
    batch: usize,
    pool: &ThreadPool,
) -> Vec<f32> {
    dispatch::parallel_merge(plan, acts, batch, None, pool)
}

/// [`gs_matmul_parallel_merge`] with the output bias fused: each chunk
/// seeds its private accumulator with the bias of the rows it owns
/// (through `slot_rows`), so the merge copy carries `bias + Σ w·a` and
/// rows no chunk owns keep their seed in the shared buffer. Bit-identical
/// to the serial and direct-write fused kernels.
#[deprecated(note = "merge baseline — production call sites route through `GsExecPlan::execute_bias`")]
pub fn gs_matmul_parallel_merge_bias(
    plan: &Arc<GsExecPlan>,
    acts: &Arc<Vec<f32>>,
    batch: usize,
    bias: Option<&Arc<Vec<f32>>>,
    pool: &ThreadPool,
) -> Vec<f32> {
    dispatch::parallel_merge(plan, acts, batch, bias, pool)
}

/// Transpose request-major rows (`rows[r][c]`) into the feature-major
/// layout the batched kernels consume (`out[c*batch + r]`).
pub fn to_feature_major(rows: &[Vec<f32>], width: usize) -> Vec<f32> {
    let batch = rows.len();
    let mut out = vec![0.0f32; width * batch];
    for (r, row) in rows.iter().enumerate() {
        assert_eq!(row.len(), width, "row width mismatch");
        for (c, &v) in row.iter().enumerate() {
            out[c * batch + r] = v;
        }
    }
    out
}

#[cfg(test)]
#[allow(deprecated)] // differential tests exercise the legacy wrappers on purpose
mod tests {
    use super::*;
    use crate::kernels::native::gs_matvec;
    use crate::sparse::pattern::Pattern;
    use crate::testing::model::build_random_gs;
    use crate::util::prng::Prng;

    #[test]
    fn planned_matvec_is_bit_exact_vs_oracle() {
        let patterns = [
            Pattern::Gs { b: 8, k: 8 },
            Pattern::Gs { b: 8, k: 2 },
            Pattern::Gs { b: 8, k: 1 },
            Pattern::GsScatter { b: 8, k: 1 },
        ];
        for (i, p) in patterns.into_iter().enumerate() {
            let (_, gs) = build_random_gs(32, 64, p, 0.75, 40 + i as u64).unwrap();
            let plan = GsExecPlan::from_format(&gs).unwrap();
            let mut rng = Prng::new(99);
            let x = rng.normal_vec(64, 1.0);
            assert_eq!(gs_matvec_planned(&plan, &x), gs_matvec(&gs, &x), "{}", p.name());
        }
    }

    #[test]
    fn matmul_columns_match_matvec() {
        let (_, gs) = build_random_gs(16, 64, Pattern::Gs { b: 8, k: 4 }, 0.6, 7).unwrap();
        let plan = GsExecPlan::from_format(&gs).unwrap();
        let mut rng = Prng::new(3);
        for batch in [1usize, 3, 8, 11] {
            let rows: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(64, 1.0)).collect();
            let acts = to_feature_major(&rows, 64);
            let out = gs_matmul(&plan, &acts, batch);
            for (r, x) in rows.iter().enumerate() {
                let want = gs_matvec(&gs, x);
                for row in 0..gs.rows {
                    assert_eq!(out[row * batch + r], want[row], "batch {batch} col {r} row {row}");
                }
            }
        }
    }

    #[test]
    fn f16_plan_matches_oracle_on_quantized_format() {
        // The f16 kernels load half-floats and widen before accumulating
        // in f32, in oracle order — so they are *bit-exact* against the
        // oracle run on the f16-quantized format.
        for p in [
            Pattern::Gs { b: 8, k: 8 },
            Pattern::Gs { b: 8, k: 2 },
            Pattern::GsScatter { b: 8, k: 1 },
        ] {
            let (_, gs) = build_random_gs(32, 64, p, 0.7, 60).unwrap();
            let gs16 = gs.quantize_f16();
            let plan = GsExecPlan::with_precision(&gs, 1, PlanPrecision::F16).unwrap();
            assert_eq!(plan.precision, PlanPrecision::F16);
            let mut rng = Prng::new(61);
            let x = rng.normal_vec(64, 1.0);
            assert_eq!(gs_matvec_planned(&plan, &x), gs_matvec(&gs16, &x), "{}", p.name());
            let rows: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(64, 1.0)).collect();
            let out = gs_matmul(&plan, &to_feature_major(&rows, 64), 5);
            for (r, xr) in rows.iter().enumerate() {
                let want = gs_matvec(&gs16, xr);
                for row in 0..gs.rows {
                    assert_eq!(out[row * 5 + r], want[row], "{} col {r} row {row}", p.name());
                }
            }
        }
    }

    #[test]
    fn f16_plan_halves_joined_bytes() {
        let (_, gs) = build_random_gs(64, 128, Pattern::Gs { b: 16, k: 16 }, 0.7, 77).unwrap();
        let p32 = GsExecPlan::with_chunks(&gs, 4).unwrap();
        let p16 = GsExecPlan::with_precision(&gs, 4, PlanPrecision::F16).unwrap();
        let (b32, b16) = (p32.packed_bytes(), p16.packed_bytes());
        assert!(
            (b16 as f64) <= 0.60 * b32 as f64,
            "f16 plan {b16}B not <= 60% of f32 plan {b32}B"
        );
    }

    #[test]
    fn scalar_forced_matmul_matches_default_path() {
        // Trivially equal without the `simd` feature; the real assertion
        // when the explicit SIMD block is compiled in.
        for precision in [PlanPrecision::F32, PlanPrecision::F16] {
            let (_, gs) = build_random_gs(32, 64, Pattern::Gs { b: 8, k: 4 }, 0.7, 13).unwrap();
            let plan = GsExecPlan::with_precision(&gs, 1, precision).unwrap();
            let mut rng = Prng::new(14);
            for batch in [1usize, 8, 11] {
                let rows: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(64, 1.0)).collect();
                let acts = to_feature_major(&rows, 64);
                assert_eq!(
                    gs_matmul(&plan, &acts, batch),
                    gs_matmul_scalar(&plan, &acts, batch),
                    "{} batch {batch}",
                    precision.name()
                );
            }
        }
    }

    #[test]
    fn chunks_cover_all_bands_and_balance_groups() {
        let (_, gs) = build_random_gs(64, 128, Pattern::Gs { b: 8, k: 8 }, 0.8, 5).unwrap();
        for nchunks in [1usize, 2, 3, 7, 64, 1000] {
            let plan = GsExecPlan::with_chunks(&gs, nchunks).unwrap();
            let chunks = plan.chunks();
            assert!(!chunks.is_empty());
            assert!(chunks.len() <= nchunks.max(1));
            assert_eq!(chunks[0].band_lo, 0);
            assert_eq!(chunks.last().unwrap().band_hi, plan.nbands());
            for w in chunks.windows(2) {
                assert_eq!(w[0].band_hi, w[1].band_lo, "chunks not contiguous");
            }
            let total: usize = chunks.iter().map(|c| c.groups).sum();
            assert_eq!(total, plan.ngroups());
        }
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        let pool = ThreadPool::new(4);
        for p in [Pattern::Gs { b: 8, k: 8 }, Pattern::GsScatter { b: 8, k: 2 }] {
            for precision in [PlanPrecision::F32, PlanPrecision::F16] {
                let (_, gs) = build_random_gs(64, 128, p, 0.7, 21).unwrap();
                let plan = Arc::new(GsExecPlan::with_precision(&gs, 4, precision).unwrap());
                let mut rng = Prng::new(8);
                let rows: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(128, 1.0)).collect();
                let acts = Arc::new(to_feature_major(&rows, 128));
                let serial = gs_matmul(&plan, &acts, 6);
                let direct = gs_matmul_parallel(&plan, &acts, 6, &pool);
                let merged = gs_matmul_parallel_merge(&plan, &acts, 6, &pool);
                assert_eq!(serial, direct, "{} {} direct", p.name(), precision.name());
                assert_eq!(serial, merged, "{} {} merge", p.name(), precision.name());
            }
        }
    }

    #[test]
    fn fused_bias_paths_bit_identical() {
        let pool = ThreadPool::new(4);
        for p in [Pattern::Gs { b: 8, k: 8 }, Pattern::GsScatter { b: 8, k: 2 }] {
            for precision in [PlanPrecision::F32, PlanPrecision::F16] {
                let (_, gs) = build_random_gs(64, 128, p, 0.7, 33).unwrap();
                let plan = Arc::new(GsExecPlan::with_precision(&gs, 4, precision).unwrap());
                let mut rng = Prng::new(34);
                let bias = Arc::new(rng.normal_vec(64, 0.5));
                let rows: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(128, 1.0)).collect();
                let acts = Arc::new(to_feature_major(&rows, 128));
                let serial = gs_matmul_bias(&plan, &acts, 6, Some(&bias));
                let direct = gs_matmul_parallel_bias(&plan, &acts, 6, Some(&bias), &pool);
                let merged = gs_matmul_parallel_merge_bias(&plan, &acts, 6, Some(&bias), &pool);
                assert_eq!(serial, direct, "{} {} direct", p.name(), precision.name());
                assert_eq!(serial, merged, "{} {} merge", p.name(), precision.name());
                // Mathematically bias + Σw·a; only the rounding order
                // differs from the unfused post-add.
                let unfused = gs_matmul(&plan, &acts, 6);
                for row in 0..64 {
                    for r in 0..6 {
                        let want = unfused[row * 6 + r] + bias[row];
                        let got = serial[row * 6 + r];
                        assert!(
                            (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                            "{} {} row {row} col {r}: {got} vs {want}",
                            p.name(),
                            precision.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fused_bias_seeds_untouched_rows() {
        use crate::sparse::dense::Dense;
        // All-zero matrix: no groups at all, so every output row must be
        // exactly its bias seed, in every path.
        let d = Dense::zeros(8, 16);
        let gs = GsFormat::from_dense(&d, Pattern::Gs { b: 8, k: 8 }).unwrap();
        let plan = Arc::new(GsExecPlan::with_chunks(&gs, 2).unwrap());
        let bias = Arc::new((0..8).map(|i| i as f32 - 3.5).collect::<Vec<f32>>());
        let acts = Arc::new(to_feature_major(&[vec![1.0f32; 16], vec![2.0f32; 16]], 16));
        let want: Vec<f32> = bias.iter().flat_map(|&b| [b, b]).collect();
        assert_eq!(gs_matmul_bias(&plan, &acts, 2, Some(&bias)), want);
        let pool = ThreadPool::new(2);
        assert_eq!(gs_matmul_parallel_bias(&plan, &acts, 2, Some(&bias), &pool), want);
        assert_eq!(
            gs_matmul_parallel_merge_bias(&plan, &acts, 2, Some(&bias), &pool),
            want
        );
    }

    #[test]
    fn empty_format_executes() {
        use crate::sparse::dense::Dense;
        let d = Dense::zeros(8, 16);
        let gs = GsFormat::from_dense(&d, Pattern::Gs { b: 8, k: 8 }).unwrap();
        assert_eq!(gs.ngroups(), 0);
        for precision in [PlanPrecision::F32, PlanPrecision::F16] {
            let plan = GsExecPlan::with_precision(&gs, 1, precision).unwrap();
            let x = vec![1.0f32; 16];
            assert_eq!(gs_matvec_planned(&plan, &x), vec![0.0; 8]);
            let out = gs_matmul(&plan, &to_feature_major(&[x], 16), 1);
            assert_eq!(out, vec![0.0; 8]);
        }
    }

    #[test]
    fn f16_plan_rejects_wide_matrices() {
        // u16 indices cap the column count at 65536.
        let d = crate::sparse::dense::Dense::zeros(8, (u16::MAX as usize + 1) * 2);
        let gs = GsFormat::from_dense(&d, Pattern::Gs { b: 8, k: 8 }).unwrap();
        assert!(GsExecPlan::with_precision(&gs, 1, PlanPrecision::F16).is_err());
        assert!(GsExecPlan::with_precision(&gs, 1, PlanPrecision::F32).is_ok());
    }
}
