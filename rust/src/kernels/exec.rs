//! Native CPU execution engine for GS-compressed matrices.
//!
//! [`crate::kernels::native::gs_matvec`] is the 20-line numerics oracle:
//! it re-reads `indptr`, divides `j / k` per entry, and walks `value` and
//! `index` as two separate arrays. This module is the fast path built on a
//! [`GsExecPlan`] prepacked once per weight matrix:
//!
//! * **Joined group layout** (paper §V): each group's `B` column indices
//!   sit immediately before its `B` values in one buffer, so a group is
//!   one streaming read — previously only modeled in the simulator
//!   (`spmv_gs_sim_joined`), now used for real execution.
//! * **Precomputed output slots**: the `entry_row` division and the
//!   scatter `rowmap` indirection are resolved at pack time into flat
//!   per-lane row tables; the inner loop is pure loads, FMAs, stores.
//! * **Balanced chunks**: bands are partitioned into contiguous spans with
//!   near-equal *group* counts (not band counts — sparsity can be ragged
//!   across bands), the unit of parallelism for
//!   [`gs_matmul_parallel`]. Each band's output rows are owned by exactly
//!   one chunk (non-scatter rows are contiguous; scatter rows are a
//!   permutation slice), so chunks accumulate privately and the merge is
//!   a copy, never a reduction — results are bit-identical to the serial
//!   kernel at any thread count.
//!
//! On top of the plan:
//!
//! * [`gs_matvec_planned`] — single activation vector, lanes unrolled ×4.
//! * [`gs_matmul`] — batched spMM over feature-major activations; each
//!   index load is amortized across the whole batch and the per-lane
//!   inner loop register-blocks over [`BATCH_BLOCK`] activation columns.
//! * [`gs_matmul_parallel`] — maps plan chunks over a
//!   [`ThreadPool`]; lock-free by construction (disjoint outputs).
//!
//! All three preserve the oracle's accumulation order per output row, so
//! outputs match `gs_matvec` bit for bit (per batch column).

use crate::sparse::format::GsFormat;
use crate::util::threadpool::ThreadPool;
use anyhow::{ensure, Context, Result};
use std::sync::Arc;

/// Batch columns per register block in the batched kernels. 8 f32 lanes =
/// one AVX2 vector / two NEON vectors; small enough that the block of
/// accumulating rows stays in registers.
pub const BATCH_BLOCK: usize = 8;

/// A contiguous span of bands executed as one parallel work unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    pub band_lo: usize,
    pub band_hi: usize,
    /// Total groups in the span (the balance criterion).
    pub groups: usize,
}

/// Prepacked execution plan for one GS-compressed matrix.
///
/// Built once per deployed weight matrix (at model load / weight-swap
/// time), then shared read-only across requests and worker threads.
#[derive(Clone, Debug)]
pub struct GsExecPlan {
    pub b: usize,
    pub k: usize,
    pub rows: usize,
    pub cols: usize,
    /// Whether the source format carried a scatter `rowmap`.
    pub scatter: bool,
    /// Joined group layout: `2*b` words per group — `b` column indices
    /// followed by the `b` weight values as `f32::to_bits` words.
    joined: Vec<u32>,
    /// `nbands + 1` cumulative group counts (copy of the format's indptr).
    band_ptr: Vec<u32>,
    /// Global output row per (band, lane): `out_row[band*b + j]`; the
    /// `entry_row` division and rowmap lookup, done once at pack time.
    out_row: Vec<u32>,
    /// Global output row per (band, slot): `slot_rows[band*(b/k) + s]`.
    /// Drives the chunk merge (each band slot is one output row).
    slot_rows: Vec<u32>,
    /// Row slot of lane `j` within any band (`j / k`) — band-independent.
    lane_slot: Vec<u32>,
    /// Group-count-balanced contiguous band spans.
    chunks: Vec<Chunk>,
}

impl GsExecPlan {
    /// Pack `gs` with one chunk per available CPU (capped by band count).
    pub fn from_format(gs: &GsFormat) -> Result<GsExecPlan> {
        let nchunks = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        GsExecPlan::with_chunks(gs, nchunks)
    }

    /// Pack `gs` into at most `nchunks` balanced chunks.
    pub fn with_chunks(gs: &GsFormat, nchunks: usize) -> Result<GsExecPlan> {
        gs.validate().context("GsExecPlan source format invalid")?;
        ensure!(
            gs.b > 0 && gs.k > 0 && gs.b % gs.k == 0,
            "bad GS geometry B={} k={}",
            gs.b,
            gs.k
        );
        let band_rows = gs.b / gs.k;
        let nbands = gs.nbands();
        ensure!(
            nbands * band_rows <= gs.rows,
            "bands cover more rows than the matrix has"
        );

        let mut out_row = Vec::with_capacity(nbands * gs.b);
        let mut slot_rows = Vec::with_capacity(nbands * band_rows);
        for band in 0..nbands {
            for j in 0..gs.b {
                out_row.push(gs.entry_row(band, j) as u32);
            }
            for slot in 0..band_rows {
                slot_rows.push(gs.entry_row(band, slot * gs.k) as u32);
            }
        }
        let lane_slot: Vec<u32> = (0..gs.b).map(|j| (j / gs.k) as u32).collect();

        let plan = GsExecPlan {
            b: gs.b,
            k: gs.k,
            rows: gs.rows,
            cols: gs.cols,
            scatter: gs.rowmap.is_some(),
            joined: gs.to_joined(),
            band_ptr: gs.indptr.clone(),
            out_row,
            slot_rows,
            lane_slot,
            chunks: balance_chunks(&gs.indptr, nchunks),
        };
        Ok(plan)
    }

    pub fn nbands(&self) -> usize {
        self.band_ptr.len() - 1
    }

    pub fn ngroups(&self) -> usize {
        *self.band_ptr.last().unwrap() as usize
    }

    pub fn band_rows(&self) -> usize {
        self.b / self.k
    }

    /// The balanced band spans used by the parallel path.
    pub fn chunks(&self) -> &[Chunk] {
        &self.chunks
    }

    /// Bytes resident in the packed plan (joined + tables).
    pub fn packed_bytes(&self) -> usize {
        4 * (self.joined.len()
            + self.band_ptr.len()
            + self.out_row.len()
            + self.slot_rows.len()
            + self.lane_slot.len())
    }
}

/// Partition bands into ≤ `nchunks` contiguous spans with near-equal
/// group counts. Every band lands in exactly one span; empty trailing
/// bands are folded into the last span.
fn balance_chunks(band_ptr: &[u32], nchunks: usize) -> Vec<Chunk> {
    let nbands = band_ptr.len() - 1;
    let total = *band_ptr.last().unwrap() as usize;
    let nchunks = nchunks.max(1);
    let mut chunks = Vec::new();
    if nbands == 0 {
        return chunks;
    }
    let mut band = 0usize;
    for c in 0..nchunks {
        if band >= nbands {
            break;
        }
        let consumed = band_ptr[band] as usize;
        let remaining_chunks = nchunks - c;
        let target = (total - consumed + remaining_chunks - 1) / remaining_chunks;
        let target = target.max(1);
        let lo = band;
        let mut acc = 0usize;
        while band < nbands && acc < target {
            acc += (band_ptr[band + 1] - band_ptr[band]) as usize;
            band += 1;
        }
        chunks.push(Chunk {
            band_lo: lo,
            band_hi: band,
            groups: acc,
        });
    }
    // Fold any leftover (necessarily empty) bands into the last span.
    if band < nbands {
        if let Some(last) = chunks.last_mut() {
            last.band_hi = nbands;
        } else {
            chunks.push(Chunk {
                band_lo: 0,
                band_hi: nbands,
                groups: total,
            });
        }
    }
    chunks
}

/// Planned single-vector spMV: `y = W x` on the packed plan. Matches
/// [`crate::kernels::native::gs_matvec`] bit for bit.
pub fn gs_matvec_planned(plan: &GsExecPlan, act: &[f32]) -> Vec<f32> {
    assert_eq!(act.len(), plan.cols, "activation length mismatch");
    let b = plan.b;
    let mut y = vec![0.0f32; plan.rows];
    for band in 0..plan.nbands() {
        let rows = &plan.out_row[band * b..(band + 1) * b];
        let lo = plan.band_ptr[band] as usize;
        let hi = plan.band_ptr[band + 1] as usize;
        for g in lo..hi {
            let off = g * 2 * b;
            let idx = &plan.joined[off..off + b];
            let val = &plan.joined[off + b..off + 2 * b];
            let mut j = 0;
            // Lanes unrolled ×4; adds stay in lane order, so rows shared
            // between lanes (k > 1) accumulate exactly like the oracle.
            while j + 4 <= b {
                y[rows[j] as usize] += f32::from_bits(val[j]) * act[idx[j] as usize];
                y[rows[j + 1] as usize] += f32::from_bits(val[j + 1]) * act[idx[j + 1] as usize];
                y[rows[j + 2] as usize] += f32::from_bits(val[j + 2]) * act[idx[j + 2] as usize];
                y[rows[j + 3] as usize] += f32::from_bits(val[j + 3]) * act[idx[j + 3] as usize];
                j += 4;
            }
            while j < b {
                y[rows[j] as usize] += f32::from_bits(val[j]) * act[idx[j] as usize];
                j += 1;
            }
        }
    }
    y
}

/// Execute the bands of `chunk`, accumulating into `out` where local row
/// 0 corresponds to band `chunk.band_lo`'s first slot. `acts` and `out`
/// are feature-major: `[feature][batch]`, batch contiguous.
fn exec_chunk_into(plan: &GsExecPlan, acts: &[f32], batch: usize, chunk: Chunk, out: &mut [f32]) {
    let b = plan.b;
    let band_rows = plan.band_rows();
    debug_assert!(out.len() >= (chunk.band_hi - chunk.band_lo) * band_rows * batch);
    for band in chunk.band_lo..chunk.band_hi {
        let slot_base = (band - chunk.band_lo) * band_rows;
        let lo = plan.band_ptr[band] as usize;
        let hi = plan.band_ptr[band + 1] as usize;
        for g in lo..hi {
            let off = g * 2 * b;
            let idx = &plan.joined[off..off + b];
            let val = &plan.joined[off + b..off + 2 * b];
            for j in 0..b {
                let col = idx[j] as usize;
                let w = f32::from_bits(val[j]);
                let row = slot_base + plan.lane_slot[j] as usize;
                let a0 = col * batch;
                let o0 = row * batch;
                // Register block over the batch: one (index, value) load
                // feeds BATCH_BLOCK FMAs on contiguous activations.
                let mut r = 0;
                while r + BATCH_BLOCK <= batch {
                    let a = &acts[a0 + r..a0 + r + BATCH_BLOCK];
                    let o = &mut out[o0 + r..o0 + r + BATCH_BLOCK];
                    for t in 0..BATCH_BLOCK {
                        o[t] += w * a[t];
                    }
                    r += BATCH_BLOCK;
                }
                while r < batch {
                    out[o0 + r] += w * acts[a0 + r];
                    r += 1;
                }
            }
        }
    }
}

/// Batched spMM: `Y = W X` with `X` feature-major (`acts[col*batch + r]`
/// is request `r`'s activation for feature `col`). Returns `Y`
/// feature-major: `out[row*batch + r]`. Column `r` equals
/// `gs_matvec(gs, x_r)` bit for bit.
pub fn gs_matmul(plan: &GsExecPlan, acts: &[f32], batch: usize) -> Vec<f32> {
    assert!(batch > 0, "gs_matmul with empty batch");
    assert_eq!(acts.len(), plan.cols * batch, "activation shape mismatch");
    let mut out = vec![0.0f32; plan.rows * batch];
    let band_rows = plan.band_rows();
    let all = Chunk {
        band_lo: 0,
        band_hi: plan.nbands(),
        groups: plan.ngroups(),
    };
    if plan.scatter {
        // Accumulate band-local, then place rows through the rowmap.
        let mut local = vec![0.0f32; plan.nbands() * band_rows * batch];
        exec_chunk_into(plan, acts, batch, all, &mut local);
        merge_chunk(plan, batch, all, &local, &mut out);
    } else {
        // Identity slot→row mapping: accumulate straight into `out`.
        exec_chunk_into(plan, acts, batch, all, &mut out);
    }
    out
}

/// Copy one chunk's private accumulation into the global output through
/// the plan's slot→row table. Each global row is owned by exactly one
/// (band, slot), so this is a copy, not a reduction.
fn merge_chunk(plan: &GsExecPlan, batch: usize, chunk: Chunk, local: &[f32], out: &mut [f32]) {
    let band_rows = plan.band_rows();
    for band in chunk.band_lo..chunk.band_hi {
        for slot in 0..band_rows {
            let row = plan.slot_rows[band * band_rows + slot] as usize;
            let src = ((band - chunk.band_lo) * band_rows + slot) * batch;
            let dst = row * batch;
            out[dst..dst + batch].copy_from_slice(&local[src..src + batch]);
        }
    }
}

/// Parallel batched spMM: plan chunks mapped over `pool`. Non-scatter
/// chunks write disjoint contiguous row spans; scatter chunks own
/// disjoint rowmap slices — either way each chunk accumulates privately
/// and the merge is a race-free copy. Output is bit-identical to
/// [`gs_matmul`] at any worker count.
///
/// `plan` and `acts` travel to the workers as `Arc` clones (the pool's
/// jobs are `'static`), so the caller keeps both afterwards.
pub fn gs_matmul_parallel(
    plan: &Arc<GsExecPlan>,
    acts: &Arc<Vec<f32>>,
    batch: usize,
    pool: &ThreadPool,
) -> Vec<f32> {
    assert!(batch > 0, "gs_matmul_parallel with empty batch");
    assert_eq!(acts.len(), plan.cols * batch, "activation shape mismatch");
    let chunks: Vec<Chunk> = plan.chunks.clone();
    if chunks.len() <= 1 {
        return gs_matmul(plan, acts, batch);
    }
    let band_rows = plan.band_rows();
    let plan2 = Arc::clone(plan);
    let acts2 = Arc::clone(acts);
    let locals = pool.map(chunks.clone(), move |chunk| {
        let rows = (chunk.band_hi - chunk.band_lo) * band_rows;
        let mut local = vec![0.0f32; rows * batch];
        exec_chunk_into(&plan2, &acts2, batch, chunk, &mut local);
        local
    });
    let mut out = vec![0.0f32; plan.rows * batch];
    for (chunk, local) in chunks.iter().zip(&locals) {
        merge_chunk(plan, batch, *chunk, local, &mut out);
    }
    out
}

/// Transpose request-major rows (`rows[r][c]`) into the feature-major
/// layout the batched kernels consume (`out[c*batch + r]`).
pub fn to_feature_major(rows: &[Vec<f32>], width: usize) -> Vec<f32> {
    let batch = rows.len();
    let mut out = vec![0.0f32; width * batch];
    for (r, row) in rows.iter().enumerate() {
        assert_eq!(row.len(), width, "row width mismatch");
        for (c, &v) in row.iter().enumerate() {
            out[c * batch + r] = v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::native::gs_matvec;
    use crate::pruning::prune;
    use crate::sparse::dense::Dense;
    use crate::sparse::pattern::Pattern;
    use crate::util::prng::Prng;

    fn packed(pattern: Pattern, rows: usize, cols: usize, sparsity: f64, seed: u64) -> (Dense, GsFormat) {
        let mut rng = Prng::new(seed);
        let mut w = Dense::random(rows, cols, 1.0, &mut rng);
        let mask = prune(&w, pattern, sparsity).unwrap();
        w.apply_mask(&mask);
        let gs = GsFormat::from_dense(&w, pattern).unwrap();
        (w, gs)
    }

    #[test]
    fn planned_matvec_is_bit_exact_vs_oracle() {
        let patterns = [
            Pattern::Gs { b: 8, k: 8 },
            Pattern::Gs { b: 8, k: 2 },
            Pattern::Gs { b: 8, k: 1 },
            Pattern::GsScatter { b: 8, k: 1 },
        ];
        for (i, p) in patterns.into_iter().enumerate() {
            let (_, gs) = packed(p, 32, 64, 0.75, 40 + i as u64);
            let plan = GsExecPlan::from_format(&gs).unwrap();
            let mut rng = Prng::new(99);
            let x = rng.normal_vec(64, 1.0);
            assert_eq!(gs_matvec_planned(&plan, &x), gs_matvec(&gs, &x), "{}", p.name());
        }
    }

    #[test]
    fn matmul_columns_match_matvec() {
        let (_, gs) = packed(Pattern::Gs { b: 8, k: 4 }, 16, 64, 0.6, 7);
        let plan = GsExecPlan::from_format(&gs).unwrap();
        let mut rng = Prng::new(3);
        for batch in [1usize, 3, 8, 11] {
            let rows: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(64, 1.0)).collect();
            let acts = to_feature_major(&rows, 64);
            let out = gs_matmul(&plan, &acts, batch);
            for (r, x) in rows.iter().enumerate() {
                let want = gs_matvec(&gs, x);
                for row in 0..gs.rows {
                    assert_eq!(out[row * batch + r], want[row], "batch {batch} col {r} row {row}");
                }
            }
        }
    }

    #[test]
    fn chunks_cover_all_bands_and_balance_groups() {
        let (_, gs) = packed(Pattern::Gs { b: 8, k: 8 }, 64, 128, 0.8, 5);
        for nchunks in [1usize, 2, 3, 7, 64, 1000] {
            let plan = GsExecPlan::with_chunks(&gs, nchunks).unwrap();
            let chunks = plan.chunks();
            assert!(!chunks.is_empty());
            assert!(chunks.len() <= nchunks.max(1));
            assert_eq!(chunks[0].band_lo, 0);
            assert_eq!(chunks.last().unwrap().band_hi, plan.nbands());
            for w in chunks.windows(2) {
                assert_eq!(w[0].band_hi, w[1].band_lo, "chunks not contiguous");
            }
            let total: usize = chunks.iter().map(|c| c.groups).sum();
            assert_eq!(total, plan.ngroups());
        }
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        let pool = ThreadPool::new(4);
        for p in [Pattern::Gs { b: 8, k: 8 }, Pattern::GsScatter { b: 8, k: 2 }] {
            let (_, gs) = packed(p, 64, 128, 0.7, 21);
            let plan = Arc::new(GsExecPlan::with_chunks(&gs, 4).unwrap());
            let mut rng = Prng::new(8);
            let rows: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(128, 1.0)).collect();
            let acts = Arc::new(to_feature_major(&rows, 128));
            let serial = gs_matmul(&plan, &acts, 6);
            let parallel = gs_matmul_parallel(&plan, &acts, 6, &pool);
            assert_eq!(serial, parallel, "{}", p.name());
        }
    }

    #[test]
    fn empty_format_executes() {
        let d = Dense::zeros(8, 16);
        let gs = GsFormat::from_dense(&d, Pattern::Gs { b: 8, k: 8 }).unwrap();
        assert_eq!(gs.ngroups(), 0);
        let plan = GsExecPlan::from_format(&gs).unwrap();
        let x = vec![1.0f32; 16];
        assert_eq!(gs_matvec_planned(&plan, &x), vec![0.0; 8]);
        let out = gs_matmul(&plan, &to_feature_major(&[x], 16), 1);
        assert_eq!(out, vec![0.0; 8]);
    }
}
