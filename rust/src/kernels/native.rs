//! Pure-f32 oracle kernels (no timing).

use crate::sparse::format::GsFormat;

/// spMV on the compact GS format — the reference semantics of
/// Algorithms 1 (horizontal) and 2 (vertical), valid for every `GS(B,k)`
/// including scatter (via `entry_row`).
pub fn gs_matvec(gs: &GsFormat, act: &[f32]) -> Vec<f32> {
    assert_eq!(act.len(), gs.cols, "activation length mismatch");
    let mut y = vec![0.0f32; gs.rows];
    for band in 0..gs.nbands() {
        for g in gs.indptr[band] as usize..gs.indptr[band + 1] as usize {
            for j in 0..gs.b {
                let col = gs.index[g * gs.b + j] as usize;
                let row = gs.entry_row(band, j);
                y[row] += gs.value[g * gs.b + j] * act[col];
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::prune;
    use crate::sparse::dense::Dense;
    use crate::sparse::pattern::Pattern;
    use crate::util::prng::Prng;

    #[test]
    fn gs_matvec_matches_dense_all_patterns() {
        let mut rng = Prng::new(11);
        let patterns = [
            Pattern::Gs { b: 8, k: 8 },
            Pattern::Gs { b: 8, k: 1 },
            Pattern::Gs { b: 8, k: 2 },
            Pattern::Gs { b: 8, k: 4 },
            Pattern::GsScatter { b: 8, k: 1 },
        ];
        for p in patterns {
            let mut w = Dense::random(32, 64, 1.0, &mut rng);
            let mask = prune(&w, p, 0.7).unwrap();
            w.apply_mask(&mask);
            let gs = GsFormat::from_dense(&w, p).unwrap();
            let x = rng.normal_vec(64, 1.0);
            let want = w.matvec(&x);
            let got = gs_matvec(&gs, &x);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{} row {i}: {a} vs {b}",
                    p.name()
                );
            }
        }
    }
}
