//! Set-associative cache hierarchy with prefetchers (paper §X).
//!
//! Weights (and index arrays) stream from DRAM through L2 and L1; the
//! activations live in the TCM and never touch this hierarchy. The paper's
//! setup: 64KB L1 (2-cycle) with a tag prefetcher that fetches the next
//! four lines on access; 1MB L2 (20-cycle) with block prefetch; DDR3
//! behind it. We model LRU set-associative arrays, the two prefetchers,
//! and a DRAM bandwidth floor.

/// One cache level's geometry.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub line_bytes: usize,
    pub ways: usize,
    /// Hit latency in cycles.
    pub latency: u64,
    /// Lines prefetched ahead on a demand access (0 = no prefetcher).
    pub prefetch_lines: usize,
}

impl CacheConfig {
    /// Paper L1: 64KB, 2-cycle, next-4-line tag prefetcher.
    pub fn l1_default() -> CacheConfig {
        CacheConfig {
            size_bytes: 64 * 1024,
            line_bytes: 64,
            ways: 4,
            latency: 2,
            prefetch_lines: 4,
        }
    }

    /// Paper L2: 1MB, 20-cycle, block prefetcher (modeled as a deeper
    /// next-N prefetch since our kernels issue explicit block prefetches).
    pub fn l2_default() -> CacheConfig {
        CacheConfig {
            size_bytes: 1024 * 1024,
            line_bytes: 64,
            ways: 8,
            latency: 20,
            prefetch_lines: 16,
        }
    }

    fn sets(&self) -> usize {
        self.size_bytes / self.line_bytes / self.ways
    }
}

/// LRU set-associative cache over line addresses.
#[derive(Clone, Debug)]
pub struct Cache {
    pub config: CacheConfig,
    /// `sets × ways` tags; u64::MAX = invalid. Per-set LRU order: index 0
    /// is most recently used.
    tags: Vec<Vec<u64>>,
    pub hits: u64,
    pub misses: u64,
    pub prefetches: u64,
}

impl Cache {
    pub fn new(config: CacheConfig) -> Cache {
        Cache {
            config,
            tags: vec![Vec::new(); config.sets()],
            hits: 0,
            misses: 0,
            prefetches: 0,
        }
    }

    fn set_and_tag(&self, line: u64) -> (usize, u64) {
        ((line as usize) % self.config.sets(), line)
    }

    /// Probe-and-fill for a demand access to `line`; true on hit.
    pub fn access_line(&mut self, line: u64) -> bool {
        let (set, tag) = self.set_and_tag(line);
        let ways = self.config.ways;
        let entry = &mut self.tags[set];
        if let Some(pos) = entry.iter().position(|&t| t == tag) {
            entry.remove(pos);
            entry.insert(0, tag); // MRU
            self.hits += 1;
            true
        } else {
            entry.insert(0, tag);
            entry.truncate(ways);
            self.misses += 1;
            false
        }
    }

    /// Install a line without a demand access (prefetch fill).
    pub fn prefetch_line(&mut self, line: u64) {
        let (set, tag) = self.set_and_tag(line);
        let ways = self.config.ways;
        let entry = &mut self.tags[set];
        if !entry.contains(&tag) {
            entry.insert(0, tag);
            entry.truncate(ways);
            self.prefetches += 1;
        }
    }

    pub fn contains(&self, line: u64) -> bool {
        let (set, tag) = self.set_and_tag(line);
        self.tags[set].contains(&tag)
    }
}

/// Where an access was served.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServedBy {
    L1,
    L2,
    Dram,
}

/// L1 → L2 → DRAM hierarchy with per-level prefetchers and a DRAM
/// bandwidth floor.
#[derive(Clone, Debug)]
pub struct MemoryHierarchy {
    pub l1: Cache,
    pub l2: Cache,
    /// DRAM access latency in cycles (paper's DDR3; tCAS + controller).
    pub dram_latency: u64,
    /// DRAM sustained bandwidth in bytes per core cycle.
    pub dram_bytes_per_cycle: f64,
    /// Total bytes that had to come from DRAM (for the bandwidth floor).
    pub dram_bytes: u64,
    /// Sum of unhidden miss latencies (latency-bound component).
    pub stall_cycles: u64,
}

impl MemoryHierarchy {
    pub fn new(l1: CacheConfig, l2: CacheConfig) -> MemoryHierarchy {
        MemoryHierarchy {
            l1: Cache::new(l1),
            l2: Cache::new(l2),
            // DDR3-1600 ≈ 12.8 GB/s at a 1 GHz DSP core ⇒ 12.8 B/cycle.
            dram_latency: 100,
            dram_bytes_per_cycle: 12.8,
            dram_bytes: 0,
            stall_cycles: 0,
        }
    }

    pub fn default_paper() -> MemoryHierarchy {
        MemoryHierarchy::new(CacheConfig::l1_default(), CacheConfig::l2_default())
    }

    /// A demand read of `bytes` at `addr`. Returns where the *first* line
    /// was served and charges stall cycles for unprefetched misses; runs
    /// both prefetchers. Sequential streams therefore mostly hit after
    /// warm-up, which is exactly the behaviour the paper's kernels rely
    /// on ("the weights flow through the L1/L2 caches" with prefetch).
    pub fn read(&mut self, addr: u64, bytes: usize) -> ServedBy {
        let line_bytes = self.l1.config.line_bytes as u64;
        let first_line = addr / line_bytes;
        let last_line = (addr + bytes.max(1) as u64 - 1) / line_bytes;
        let mut worst = ServedBy::L1;
        for line in first_line..=last_line {
            let served = self.read_line(line);
            if served == ServedBy::Dram
                || (served == ServedBy::L2 && worst == ServedBy::L1)
            {
                worst = served;
            }
        }
        worst
    }

    fn read_line(&mut self, line: u64) -> ServedBy {
        // L1 prefetcher: next-N lines on every demand access.
        for p in 1..=self.l1.config.prefetch_lines as u64 {
            // Prefetch into L1 only if L2 already has it (tag prefetcher);
            // otherwise enqueue into L2 (block prefetch behaviour).
            let pl = line + p;
            if self.l2.contains(pl) {
                self.l1.prefetch_line(pl);
            } else {
                self.l2.prefetch_line(pl);
                self.dram_bytes += self.l2.config.line_bytes as u64;
            }
        }
        if self.l1.access_line(line) {
            return ServedBy::L1;
        }
        if self.l2.access_line(line) {
            self.stall_cycles += self.l2.config.latency;
            return ServedBy::L2;
        }
        self.stall_cycles += self.dram_latency;
        self.dram_bytes += self.l2.config.line_bytes as u64;
        ServedBy::Dram
    }

    /// Bandwidth floor in cycles for all DRAM traffic so far.
    pub fn bandwidth_cycles(&self) -> u64 {
        (self.dram_bytes as f64 / self.dram_bytes_per_cycle).ceil() as u64
    }

    pub fn reset_counters(&mut self) {
        self.l1.hits = 0;
        self.l1.misses = 0;
        self.l1.prefetches = 0;
        self.l2.hits = 0;
        self.l2.misses = 0;
        self.l2.prefetches = 0;
        self.dram_bytes = 0;
        self.stall_cycles = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cache(lines: usize, ways: usize) -> Cache {
        Cache::new(CacheConfig {
            size_bytes: lines * 64,
            line_bytes: 64,
            ways,
            latency: 2,
            prefetch_lines: 0,
        })
    }

    #[test]
    fn hit_after_fill() {
        let mut c = tiny_cache(8, 2);
        assert!(!c.access_line(3));
        assert!(c.access_line(3));
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn lru_eviction() {
        // 4 sets × 2 ways; lines 0,4,8 map to set 0. Access 0,4 then 8:
        // 0 is LRU and must be evicted.
        let mut c = tiny_cache(8, 2);
        c.access_line(0);
        c.access_line(4);
        c.access_line(8);
        assert!(!c.contains(0));
        assert!(c.contains(4) && c.contains(8));
        // Touch 4 (now MRU), insert 12 → 8 evicted.
        c.access_line(4);
        c.access_line(12);
        assert!(c.contains(4) && !c.contains(8));
    }

    #[test]
    fn sequential_stream_mostly_hits_with_prefetch() {
        let mut h = MemoryHierarchy::default_paper();
        // Stream 64KB sequentially in 32B reads.
        for i in 0..2048u64 {
            h.read(i * 32, 32);
        }
        let total = h.l1.hits + h.l1.misses;
        let hit_rate = h.l1.hits as f64 / total as f64;
        assert!(
            hit_rate > 0.45,
            "prefetchers ineffective: L1 hit rate {hit_rate}"
        );
        assert!(h.dram_bytes >= 64 * 1024, "traffic accounting lost bytes");
    }

    #[test]
    fn random_reads_miss() {
        let mut h = MemoryHierarchy::default_paper();
        // Touch addresses 1MB apart — no reuse, no useful prefetch.
        let mut dram = 0;
        for i in 0..64u64 {
            if h.read(i * (1 << 21), 2) == ServedBy::Dram {
                dram += 1;
            }
        }
        assert!(dram >= 60, "expected cold misses, got {dram} DRAM hits");
        assert!(h.stall_cycles >= 60 * h.dram_latency);
    }

    #[test]
    fn bandwidth_floor_scales_with_traffic() {
        let mut h = MemoryHierarchy::default_paper();
        for i in 0..1024u64 {
            h.read(i * 64, 64);
        }
        let floor = h.bandwidth_cycles();
        assert!(
            floor >= (1024 * 64) as u64 / 13,
            "bandwidth floor {floor} too low"
        );
    }

    #[test]
    fn multi_line_read_touches_all_lines() {
        let mut h = MemoryHierarchy::default_paper();
        h.read(0, 256); // 4 lines
        assert!(h.l1.contains(0) && h.l1.contains(1) && h.l1.contains(2) && h.l1.contains(3));
    }
}
