//! Tightly-coupled memory with sub-banks and a gather/scatter engine.
//!
//! Data elements are interleaved across sub-banks at low-order bits
//! (element `i` lives in sub-bank `i mod B`, paper §III / Fig. 2). A
//! gather or scatter of up to `B` offsets completes in one engine slot if
//! no two offsets share a sub-bank; otherwise accesses to the same bank
//! serialize, costing `max_occupancy` slots total.

/// TCM geometry and latencies (defaults follow the paper's §X setup).
#[derive(Clone, Copy, Debug)]
pub struct TcmConfig {
    /// Number of individually addressable sub-banks (= max gather width).
    pub subbanks: usize,
    /// Capacity in elements (64KB of fp16 = 32768 elements).
    pub capacity_elems: usize,
    /// Access latency in cycles when conflict-free (paper: 3).
    pub base_latency: u64,
    /// Extra cycles per non-resolving bank conflict (paper: 1).
    pub conflict_penalty: u64,
}

impl Default for TcmConfig {
    fn default() -> Self {
        TcmConfig {
            subbanks: 16,
            capacity_elems: 32 * 1024,
            base_latency: 3,
            conflict_penalty: 1,
        }
    }
}

/// Sub-banked TCM storing f32 elements (numerics are kept in f32; the
/// paper's fp16-storage/fp32-compute convention is a width bookkeeping
/// concern handled by the machine's byte counters).
#[derive(Clone, Debug)]
pub struct Tcm {
    pub config: TcmConfig,
    data: Vec<f32>,
    /// Cumulative engine-busy slots (1 per conflict-free access).
    pub engine_slots: u64,
    /// Cumulative extra slots lost to bank conflicts.
    pub conflict_slots: u64,
    /// Number of gather/scatter operations issued.
    pub accesses: u64,
}

impl Tcm {
    pub fn new(config: TcmConfig) -> Tcm {
        Tcm {
            config,
            data: vec![0.0; config.capacity_elems],
            engine_slots: 0,
            conflict_slots: 0,
            accesses: 0,
        }
    }

    /// Load a dense vector starting at element offset `base` (sequential
    /// interleave, matching "a[i] stored in the (i mod B)-th sub-bank").
    pub fn fill(&mut self, base: usize, values: &[f32]) {
        assert!(
            base + values.len() <= self.data.len(),
            "TCM overflow: {} + {} > {}",
            base,
            values.len(),
            self.data.len()
        );
        self.data[base..base + values.len()].copy_from_slice(values);
    }

    /// Maximum bank occupancy of an offset set — 1 means conflict-free.
    pub fn occupancy(&self, offsets: &[u32]) -> u64 {
        let mut occ = vec![0u64; self.config.subbanks];
        for &o in offsets {
            occ[o as usize % self.config.subbanks] += 1;
        }
        occ.into_iter().max().unwrap_or(0)
    }

    /// Gather elements at `base + offsets[j]`; returns the values and
    /// charges the engine `max_occupancy` slots.
    pub fn gather(&mut self, base: usize, offsets: &[u32], out: &mut [f32]) -> u64 {
        debug_assert_eq!(offsets.len(), out.len());
        for (o, dst) in offsets.iter().zip(out.iter_mut()) {
            *dst = self.data[base + *o as usize];
        }
        self.account(offsets)
    }

    /// Scatter `values` to `base + offsets[j]`; same conflict accounting.
    pub fn scatter(&mut self, base: usize, offsets: &[u32], values: &[f32]) -> u64 {
        debug_assert_eq!(offsets.len(), values.len());
        for (o, v) in offsets.iter().zip(values) {
            self.data[base + *o as usize] = *v;
        }
        self.account(offsets)
    }

    /// Sequential vector load of `width` elements from `base` — always
    /// conflict-free (consecutive residues) and charged one slot.
    pub fn load_seq(&mut self, base: usize, out: &mut [f32]) -> u64 {
        out.copy_from_slice(&self.data[base..base + out.len()]);
        self.accesses += 1;
        self.engine_slots += 1;
        1
    }

    /// Read one element (scalar path, tests/debug).
    pub fn read(&self, idx: usize) -> f32 {
        self.data[idx]
    }

    fn account(&mut self, offsets: &[u32]) -> u64 {
        let occ = self.occupancy(offsets).max(1);
        self.accesses += 1;
        self.engine_slots += occ;
        self.conflict_slots += (occ - 1) * self.config.conflict_penalty;
        occ
    }

    /// Latency of a single access with `occ` occupancy (for latency-bound
    /// paths): `base_latency + (occ-1)·conflict_penalty`.
    pub fn access_latency(&self, occ: u64) -> u64 {
        self.config.base_latency + (occ.max(1) - 1) * self.config.conflict_penalty
    }

    pub fn reset_counters(&mut self) {
        self.engine_slots = 0;
        self.conflict_slots = 0;
        self.accesses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tcm4() -> Tcm {
        Tcm::new(TcmConfig {
            subbanks: 4,
            capacity_elems: 64,
            base_latency: 3,
            conflict_penalty: 1,
        })
    }

    #[test]
    fn conflict_free_gather_is_one_slot() {
        let mut t = tcm4();
        t.fill(0, &(0..16).map(|i| i as f32).collect::<Vec<_>>());
        let mut out = [0.0; 4];
        // Paper's example: idx = {4,7,13,14} ≡ {0,3,1,2} mod 4.
        let slots = t.gather(0, &[4, 7, 13, 14], &mut out);
        assert_eq!(slots, 1);
        assert_eq!(out, [4.0, 7.0, 13.0, 14.0]);
        assert_eq!(t.conflict_slots, 0);
    }

    #[test]
    fn conflicts_serialize_by_occupancy() {
        let mut t = tcm4();
        t.fill(0, &(0..16).map(|i| i as f32).collect::<Vec<_>>());
        let mut out = [0.0; 4];
        // All offsets ≡ 0 mod 4 → occupancy 4.
        let slots = t.gather(0, &[0, 4, 8, 12], &mut out);
        assert_eq!(slots, 4);
        assert_eq!(t.conflict_slots, 3);
        // Two pairs → occupancy 2.
        let slots = t.gather(0, &[0, 4, 1, 5], &mut out);
        assert_eq!(slots, 2);
    }

    #[test]
    fn scatter_roundtrip() {
        let mut t = tcm4();
        let slots = t.scatter(8, &[0, 1, 2, 3], &[9.0, 8.0, 7.0, 6.0]);
        assert_eq!(slots, 1);
        assert_eq!(t.read(8), 9.0);
        assert_eq!(t.read(11), 6.0);
    }

    #[test]
    fn seq_load_one_slot() {
        let mut t = tcm4();
        t.fill(4, &[1.0, 2.0, 3.0, 4.0]);
        let mut out = [0.0; 4];
        assert_eq!(t.load_seq(4, &mut out), 1);
        assert_eq!(out, [1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn latency_formula() {
        let t = tcm4();
        assert_eq!(t.access_latency(1), 3);
        assert_eq!(t.access_latency(4), 6);
    }

    #[test]
    #[should_panic(expected = "TCM overflow")]
    fn fill_bounds_checked() {
        let mut t = tcm4();
        t.fill(60, &[0.0; 8]);
    }
}
