//! The unit-stream timing model (Gem5 O3CPU substitute).
//!
//! An eight-issue out-of-order core overlaps independent work across its
//! functional units; for throughput-bound kernels the elapsed time is set
//! by the busiest unit. We therefore clock five streams independently and
//! report `cycles = max(streams)`:
//!
//! * **LSU** — one load/store per cycle for cacheable traffic (weights,
//!   indices, indptr, results).
//! * **Engine** — the TCM gather/scatter engine: one access per cycle,
//!   serialized by bank-conflict occupancy (tracked in [`Tcm`]).
//! * **VPU** — SIMD multiply-accumulate, reductions, format converts.
//! * **Scalar** — loop/branch bookkeeping and per-row prologues (the
//!   dependency work an OoO core cannot overlap away).
//! * **Memory** — `max(DRAM bandwidth floor, unhidden miss stalls / MLP)`
//!   from the cache hierarchy.
//!
//! Kernels (in `crate::kernels`) call these micro-op methods while
//! computing real numerics, so the simulator simultaneously yields correct
//! results and cycle estimates — a sim-vs-native numerics test keeps it
//! honest.

use super::cache::MemoryHierarchy;
use super::tcm::{Tcm, TcmConfig};

/// Streamed-array identifiers; each gets a disjoint address region so the
/// cache sees realistic interleaving without kernels managing pointers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stream {
    Weights = 0,
    Indices = 1,
    Indptr = 2,
    Output = 3,
}

/// Core model parameters (defaults follow paper §X where specified).
#[derive(Clone, Copy, Debug)]
pub struct MachineConfig {
    /// Nominal issue width (documentation; streams are per-unit).
    pub issue_width: u64,
    /// TCM geometry.
    pub tcm: TcmConfig,
    /// VPU cost of one SIMD MAC (fp16→fp32 convert folded in).
    pub mac_cost: u64,
    /// VPU cost of a cross-lane reduction (≈ log2(B)).
    pub reduce_cost: u64,
    /// Scalar cost per inner-loop iteration (index increment + branch).
    pub loop_cost: u64,
    /// Scalar cost per row/band prologue (indptr fetch use, pointer setup,
    /// loop-carried dependency the OoO core cannot hide).
    pub row_overhead: u64,
    /// Memory-level parallelism: outstanding misses the OoO core overlaps.
    pub mlp: u64,
    /// DRAM bandwidth in bytes per *core* cycle. Default 51.2: a DSP-class
    /// core at ~400 MHz in front of dual-channel DDR3-1600 (25.6 GB/s).
    /// At this ratio the paper's kernels are issue-bound, not DRAM-bound —
    /// which is what makes GS ≈ block despite GS's per-element index
    /// traffic (Fig. 6's observed equality).
    pub dram_bytes_per_cycle: f64,
}

impl Default for MachineConfig {
    fn default() -> Self {
        let tcm = TcmConfig::default();
        MachineConfig {
            issue_width: 8,
            tcm,
            mac_cost: 1,
            reduce_cost: (tcm.subbanks as f64).log2().ceil() as u64,
            loop_cost: 1,
            row_overhead: 4,
            mlp: 8,
            dram_bytes_per_cycle: 51.2,
        }
    }
}

impl MachineConfig {
    pub fn with_subbanks(subbanks: usize) -> MachineConfig {
        let mut c = MachineConfig::default();
        c.tcm.subbanks = subbanks;
        c.reduce_cost = (subbanks as f64).log2().ceil() as u64;
        c
    }
}

/// Simulation outcome for one kernel run.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub cycles: u64,
    pub lsu_slots: u64,
    pub engine_slots: u64,
    pub conflict_slots: u64,
    pub vpu_slots: u64,
    pub scalar_slots: u64,
    pub mem_cycles: u64,
    pub dram_bytes: u64,
    pub l1_hit_rate: f64,
    pub gathers: u64,
    pub instructions: u64,
}

impl SimReport {
    /// The unit that set the critical path.
    pub fn bottleneck(&self) -> &'static str {
        let streams = [
            (self.lsu_slots, "lsu"),
            (self.engine_slots, "engine"),
            (self.vpu_slots, "vpu"),
            (self.scalar_slots, "scalar"),
            (self.mem_cycles, "memory"),
        ];
        streams.iter().max_by_key(|(v, _)| *v).unwrap().1
    }
}

/// The simulated machine: unit-stream clocks + TCM + cache hierarchy.
pub struct Machine {
    pub config: MachineConfig,
    pub tcm: Tcm,
    pub mem: MemoryHierarchy,
    lsu_slots: u64,
    vpu_slots: u64,
    scalar_slots: u64,
    instructions: u64,
    cursors: [u64; 4],
}

/// Disjoint 256MB address regions per stream.
const REGION_SHIFT: u64 = 28;

impl Machine {
    pub fn new(config: MachineConfig) -> Machine {
        let mut mem = MemoryHierarchy::default_paper();
        mem.dram_bytes_per_cycle = config.dram_bytes_per_cycle;
        Machine {
            config,
            tcm: Tcm::new(config.tcm),
            mem,
            lsu_slots: 0,
            vpu_slots: 0,
            scalar_slots: 0,
            instructions: 0,
            cursors: [0; 4],
        }
    }

    /// SIMD lane count (= TCM sub-banks, as in the paper's 16-bit SVE
    /// gather setup).
    pub fn lanes(&self) -> usize {
        self.config.tcm.subbanks
    }

    // ---- micro-ops -------------------------------------------------------

    /// Streaming load of `bytes` from `stream` (weights/indices/indptr):
    /// one LSU slot, advances that stream's cursor through the cache.
    pub fn stream_load(&mut self, stream: Stream, bytes: usize) {
        let base = (stream as u64 + 1) << REGION_SHIFT;
        let addr = base + self.cursors[stream as usize];
        self.cursors[stream as usize] += bytes as u64;
        self.mem.read(addr, bytes);
        self.lsu_slots += 1;
        self.instructions += 1;
    }

    /// Gather `offsets.len()` activations from the TCM.
    pub fn gather(&mut self, base: usize, offsets: &[u32], out: &mut [f32]) {
        self.tcm.gather(base, offsets, out);
        self.instructions += 1;
    }

    /// Scatter values into the TCM.
    pub fn scatter(&mut self, base: usize, offsets: &[u32], values: &[f32]) {
        self.tcm.scatter(base, offsets, values);
        self.instructions += 1;
    }

    /// Sequential vector load from the TCM (dense/block activations).
    pub fn tcm_load_seq(&mut self, base: usize, out: &mut [f32]) {
        self.tcm.load_seq(base, out);
        self.instructions += 1;
    }

    /// SIMD multiply-accumulate: `acc[i] += a[i] * b[i]`.
    pub fn simd_mac(&mut self, a: &[f32], b: &[f32], acc: &mut [f32]) {
        for ((&x, &y), dst) in a.iter().zip(b).zip(acc.iter_mut()) {
            *dst += x * y;
        }
        self.vpu_slots += self.config.mac_cost;
        self.instructions += 1;
    }

    /// Cross-lane reduction of a SIMD register to one scalar.
    pub fn simd_reduce(&mut self, acc: &[f32]) -> f32 {
        self.vpu_slots += self.config.reduce_cost;
        self.instructions += 1;
        acc.iter().sum()
    }

    /// Inner-loop bookkeeping for one iteration.
    pub fn loop_tick(&mut self) {
        self.scalar_slots += self.config.loop_cost;
        self.instructions += 1;
    }

    /// Per-row/band prologue (indptr dereference, pointer setup).
    pub fn row_prologue(&mut self) {
        self.scalar_slots += self.config.row_overhead;
        self.instructions += 1;
    }

    /// Store a result vector/scalar of `bytes`.
    pub fn store_result(&mut self, bytes: usize) {
        let base = (Stream::Output as u64 + 1) << REGION_SHIFT;
        let addr = base + self.cursors[Stream::Output as usize];
        self.cursors[Stream::Output as usize] += bytes as u64;
        self.mem.read(addr, bytes); // write-allocate modeled as a read
        self.lsu_slots += 1;
        self.instructions += 1;
    }

    /// Explicit scalar work (e.g. CSR pointer chasing).
    pub fn scalar_work(&mut self, slots: u64) {
        self.scalar_slots += slots;
        self.instructions += 1;
    }

    // ---- reporting -------------------------------------------------------

    /// Memory stream cycles: bandwidth floor vs MLP-overlapped stalls.
    fn mem_cycles(&self) -> u64 {
        let stalls = self.mem.stall_cycles / self.config.mlp.max(1);
        self.mem.bandwidth_cycles().max(stalls)
    }

    pub fn report(&self) -> SimReport {
        let mem_cycles = self.mem_cycles();
        let cycles = self
            .lsu_slots
            .max(self.tcm.engine_slots)
            .max(self.vpu_slots)
            .max(self.scalar_slots)
            .max(mem_cycles)
            // Pipeline fill: one TCM access latency tail.
            + self.tcm.access_latency(1);
        let l1_total = self.mem.l1.hits + self.mem.l1.misses;
        SimReport {
            cycles,
            lsu_slots: self.lsu_slots,
            engine_slots: self.tcm.engine_slots,
            conflict_slots: self.tcm.conflict_slots,
            vpu_slots: self.vpu_slots,
            scalar_slots: self.scalar_slots,
            mem_cycles,
            dram_bytes: self.mem.dram_bytes,
            l1_hit_rate: if l1_total == 0 {
                1.0
            } else {
                self.mem.l1.hits as f64 / l1_total as f64
            },
            gathers: self.tcm.accesses,
            instructions: self.instructions,
        }
    }

    /// Reset all counters (keep TCM contents, e.g. resident activations).
    pub fn reset(&mut self) {
        self.tcm.reset_counters();
        self.mem.reset_counters();
        self.lsu_slots = 0;
        self.vpu_slots = 0;
        self.scalar_slots = 0;
        self.instructions = 0;
        self.cursors = [0; 4];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_accumulate_independently() {
        let mut m = Machine::new(MachineConfig::with_subbanks(4));
        m.tcm.fill(0, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let mut out = [0.0f32; 4];
        m.gather(0, &[0, 1, 2, 3], &mut out);
        assert_eq!(out, [1.0, 2.0, 3.0, 4.0]);
        m.stream_load(Stream::Weights, 8);
        let mut acc = [0.0f32; 4];
        m.simd_mac(&out, &[1.0; 4], &mut acc);
        m.loop_tick();
        let r = m.report();
        assert_eq!(r.lsu_slots, 1);
        assert_eq!(r.engine_slots, 1);
        assert_eq!(r.vpu_slots, 1);
        assert_eq!(r.scalar_slots, 1);
        assert_eq!(r.gathers, 1);
        assert!(r.cycles >= 1);
    }

    #[test]
    fn cycles_are_max_of_streams_plus_tail() {
        let mut m = Machine::new(MachineConfig::with_subbanks(4));
        for _ in 0..100 {
            m.loop_tick();
        }
        let r = m.report();
        // scalar=100 dominates; tail = TCM base latency (3).
        assert_eq!(r.cycles, 100 + 3);
        assert_eq!(r.bottleneck(), "scalar");
    }

    #[test]
    fn conflicts_inflate_engine_stream() {
        let mut m = Machine::new(MachineConfig::with_subbanks(4));
        m.tcm.fill(0, &[0.0; 16]);
        let mut out = [0.0f32; 4];
        for _ in 0..10 {
            m.gather(0, &[0, 4, 8, 12], &mut out); // occupancy 4
        }
        let r = m.report();
        assert_eq!(r.engine_slots, 40);
        assert_eq!(r.conflict_slots, 30);
    }

    #[test]
    fn stream_loads_advance_addresses() {
        let mut m = Machine::new(MachineConfig::default());
        // 1000 sequential 32-byte weight loads: prefetchers keep the L1
        // hit rate reasonable.
        for _ in 0..1000 {
            m.stream_load(Stream::Weights, 32);
        }
        let r = m.report();
        assert_eq!(r.lsu_slots, 1000);
        assert!(r.l1_hit_rate > 0.4, "hit rate {}", r.l1_hit_rate);
        assert!(r.dram_bytes >= 32_000);
    }

    #[test]
    fn reset_clears_counters_but_keeps_tcm_data() {
        let mut m = Machine::new(MachineConfig::with_subbanks(4));
        m.tcm.fill(0, &[7.0; 8]);
        let mut out = [0.0f32; 4];
        m.gather(0, &[0, 1, 2, 3], &mut out);
        m.reset();
        assert_eq!(m.report().gathers, 0);
        assert_eq!(m.tcm.read(0), 7.0);
    }

    #[test]
    fn scatter_writes_tcm() {
        let mut m = Machine::new(MachineConfig::with_subbanks(4));
        m.scatter(0, &[1, 2, 3, 0], &[10.0, 20.0, 30.0, 40.0]);
        assert_eq!(m.tcm.read(1), 10.0);
        assert_eq!(m.tcm.read(0), 40.0);
    }
}
