//! Cycle-level simulator of the paper's evaluation platform (§X).
//!
//! The paper measures kernels on Gem5 (O3CPU, ARM SVE, custom 16-bit
//! gather/scatter, 64KB TCM, 64KB L1 / 1MB L2 with prefetchers, DDR3). We
//! reproduce the *mechanisms that drive its relative results* with an
//! in-tree simulator:
//!
//! * [`tcm`] — banked scratchpad: a gather/scatter over `B` sub-banks
//!   costs one engine slot when the offsets' residues are distinct and
//!   serializes by the maximum bank occupancy otherwise (paper §III: "an
//!   extra cycle for every non-resolving bank conflict").
//! * [`cache`] — set-associative L1/L2 with next-N-line (L1) and block
//!   (L2) prefetchers plus a DRAM bandwidth floor, for the streamed
//!   weights.
//! * [`machine`] — the timing model: an eight-issue out-of-order core is
//!   approximated as a set of independently-clocked *unit streams*
//!   (load/store unit, gather engine, vector unit, scalar unit, memory).
//!   Kernels emit micro-ops as they compute real numerics; the elapsed
//!   cycle count is the maximum stream occupancy — the bottleneck-resource
//!   abstraction of an OoO core that successfully overlaps independent
//!   work. Dependency stalls the OoO core cannot hide (per-row reductions,
//!   loop prologues) are charged to the scalar stream explicitly.
//!
//! This "max of unit streams" model is deliberately simpler than Gem5 but
//! preserves what Fig. 6 measures: who is bottlenecked where. Dense spMV
//! is LSU/memory bound; sparse kernels trade memory traffic for per-group
//! index handling and per-row overheads; GS and block differ only in
//! gather-vs-vector-load and index width; CSR-on-engine serializes on
//! bank conflicts. See DESIGN.md §2 for the substitution argument.

pub mod cache;
pub mod machine;
pub mod tcm;

pub use cache::{Cache, CacheConfig, MemoryHierarchy};
pub use machine::{Machine, MachineConfig, SimReport};
pub use tcm::{Tcm, TcmConfig};
