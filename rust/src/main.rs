//! `gs-sparse` — leader binary: serve, export, train, simulate, inspect.
//!
//! ```text
//! gs-sparse serve    [--backend native|pjrt] [--bind 127.0.0.1:7070] [--workers 1]
//!                    [--window-ms 2] [--queue-depth 0 (unbounded; N = shed
//!                     over-limit requests with retry_after_ms)]
//!                    [--deadline-ms 0 (default queue-wait budget; expired
//!                     requests fail with waited_ms instead of executing)]
//!                    [--max-conns 0 (cap on open connections)]
//!                    [--idle-timeout-ms 0 (close stalled connections)]
//!                    [--max-frame-bytes 1048576 (largest request frame,
//!                     either framing)]
//!                    [--no-binary-wire (decline HELLO; JSON framing only)]
//!                    [--max-inflight 0 (per-connection pipelining depth cap)]
//!                    [--retain-versions 2 (previous generations kept for
//!                     rollback/canary; 0 disables both)]
//!                    [--quarantine-after 0 (failed requests within the
//!                     window that quarantine a model; 0 = off)]
//!                    [--quarantine-window-ms 10000] [--quarantine-cooldown-ms 2000]
//!                    [--store-dir DIR (crash-recoverable registry manifest,
//!                     rewritten on every deploy op and replayed on startup)]
//!                    [--trace-capacity 4096 (flight-recorder ring slots,
//!                     drained via {"op":"trace"}) | --no-trace]
//!                    [--slow-request-ms 0 (log the full lifecycle trace of
//!                     requests slower than this; 0 = off)]
//!                    [--log-json (operational logs as JSON lines)]
//!                    native: [--models a=a.gsm,b=b.gsm] [--max-models N]
//!                            [--default-model a]   (multi-model routed serving)
//!                            or [--model model.gsm]  (serve one .gsm artifact)
//!                            or a random model from:
//!                            [--inputs 64] [--hidden 256] [--outputs 64] [--batch 16]
//!                            [--b 16] [--k 16] [--sparsity 0.9] [--seed 42]
//!                            plus [--threads 0 (auto)] [--precision f32|f16]
//!                    pjrt:   [--artifacts DIR]   (requires --features pjrt)
//! gs-sparse export   --out model.gsm [--pattern GS|scatter] [--inputs 64]
//!                    [--hidden 256] [--outputs 64] [--batch 16] [--b 16] [--k 16]
//!                    [--sparsity 0.9] [--precision f32|f16] [--seed 42]
//!                    [--tune (one-shot microbenchmark; pins the fastest
//!                     dispatch kernel variant in the artifact metadata)]
//!                    [--tune-ms 50 (tune time budget)]
//! gs-sparse train    --model gnmt|resnet|jasper [--pattern GS|Block|Irregular]
//!                    [--b 8] [--k 8] [--sparsity 0.8] [--seed 42]   (pjrt only)
//! gs-sparse simulate [--rows 1024] [--cols 1024] [--banks 16] [--sparsity 0.9]
//! gs-sparse info     [--artifacts DIR]
//! ```
//!
//! The default `serve` backend is the native execution engine
//! (`kernels::exec`): it needs no XLA runtime. It serves through a
//! registry of versioned model slots: requests route by an optional
//! `"model"` field, `{"op":"swap"|"load","path":"new.gsm"}` hot-deploys
//! `.gsm` artifacts with zero downtime, and `--max-models` bounds
//! residency with LRU eviction of cold models (the default is pinned).
//! `export` writes such artifacts (deterministic random pruned models —
//! the same pipeline `serve` uses in-process). Build with
//! `--features pjrt` (and the real `xla` crate) to serve through the
//! Pallas AOT artifact instead.

use anyhow::{anyhow, ensure, Result};
use gs_sparse::coordinator::{serve, serve_store, server::ServeConfig, Engine, SparseModel};
use gs_sparse::model_store::{ModelArtifact, ModelSlot, ModelStore, SlotConfig};
use gs_sparse::pruning::prune;
use gs_sparse::sparse::{Dense, GsFormat, Pattern};
use gs_sparse::testing::{build_random_artifact, build_random_model, spec_from_args, ModelSpec};
use gs_sparse::util::{Args, Prng};

fn main() -> Result<()> {
    let args = Args::parse();
    match args.command.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("export") => cmd_export(&args),
        Some("train") => cmd_train(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!("usage: gs-sparse <serve|export|train|simulate|info> [options]");
            Ok(())
        }
    }
}

fn parse_pattern(args: &Args) -> Result<Option<Pattern>> {
    let b = args.usize("b", 8);
    let k = args.usize("k", b);
    Ok(match args.get("pattern", "GS") {
        "GS" | "gs" => Some(Pattern::Gs { b, k }),
        "GSscatter" | "scatter" => Some(Pattern::GsScatter { b, k }),
        "Block" | "block" => Some(Pattern::Block { b, k }),
        "Irregular" | "irregular" => Some(Pattern::Irregular),
        "Dense" | "dense" => None,
        other => return Err(anyhow!("unknown pattern {other}")),
    })
}

/// The random-model spec shared by `serve --backend native` (without
/// `--model`) and `export`: `ModelSpec::default()` with `--threads 0`
/// (auto-detect) as the serving default, overridden by the shared CLI
/// flags.
fn native_spec(args: &Args) -> Result<ModelSpec> {
    spec_from_args(
        args,
        ModelSpec {
            threads: 0,
            ..ModelSpec::default()
        },
    )
}

fn cmd_serve(args: &Args) -> Result<()> {
    let backend = args.get("backend", "native").to_string();
    let workers = args.usize("workers", 1);
    // The banner reports what actually runs (0 = auto-detect).
    let shown_workers = gs_sparse::util::threadpool::resolve_threads(workers);
    let bind = args.get("bind", "127.0.0.1:7070").to_string();
    let window_ms = args.usize("window-ms", 2) as u64;
    // 0 = unbounded (no shedding). With a bound, over-limit requests are
    // rejected immediately with retry_after_ms instead of queueing.
    let queue_depth = args.usize("queue-depth", 0);
    // Resilience knobs (0 = off for the first three; see ServeConfig).
    let deadline_ms = args.usize("deadline-ms", 0) as u64;
    let max_conns = args.usize("max-conns", 0);
    let idle_timeout_ms = args.usize("idle-timeout-ms", 0) as u64;
    let max_frame_bytes = args.usize("max-frame-bytes", ServeConfig::default().max_frame_bytes);
    // Wire protocol knobs: binary framing is on by default (clients
    // still opt in per connection via HELLO); --max-inflight bounds
    // per-connection pipelining depth (0 = unbounded).
    let binary_wire = !args.has("no-binary-wire");
    let max_inflight = args.usize("max-inflight", 0);
    // Observability knobs: the flight recorder behind {"op":"trace"},
    // structured logging, and the slow-request tracer.
    let trace_capacity = if args.has("no-trace") {
        0
    } else {
        args.usize("trace-capacity", ServeConfig::default().trace_capacity)
    };
    let log_json = args.has("log-json");
    let slow_request_ms = args.usize("slow-request-ms", 0) as u64;

    if backend == "native" {
        // Store-backed routed serving: named hot-swappable model slots,
        // {"op":"infer","model":...} routes, {"op":"swap"|"load"|"unload"}
        // deploy with zero downtime, --max-models LRU-evicts cold slots.
        let threads = args.usize("threads", 0);
        let slot_cfg = slot_config(args);
        let store_dir = args.options.get("store-dir").map(std::path::PathBuf::from);
        // Replay policy: a usable manifest IS the registry (the durable
        // record of every deploy accepted before the crash/restart); the
        // CLI model flags only seed a fresh store.
        let engine = match &store_dir {
            Some(dir) => match engine_from_manifest(dir, threads, slot_cfg)? {
                Some(engine) => {
                    let flagged = ["models", "model"].iter().any(|k| args.options.contains_key(*k));
                    if flagged {
                        println!(
                            "store manifest: ignoring --model/--models (the persisted registry \
                             wins)"
                        );
                    }
                    engine
                }
                None => cli_engine(args, threads, slot_cfg)?,
            },
            None => cli_engine(args, threads, slot_cfg)?,
        };
        // Admission is per-routed-slot; the config records the default
        // model's width and the widest batch capacity as the global cap.
        let default_slot = engine.default_slot();
        let inputs = default_slot.input_width();
        let max_batch = engine
            .store
            .names()
            .iter()
            .filter_map(|n| engine.store.get(n))
            .map(|s| s.batch_capacity())
            .max()
            .unwrap_or(default_slot.batch_capacity());
        let n_models = engine.store.len();
        let default_name = engine.default_model.clone();
        let handle = serve_store(
            &engine,
            ServeConfig {
                bind,
                workers,
                input_width: inputs,
                max_batch,
                window_ms,
                queue_depth,
                deadline_ms,
                max_conns,
                idle_timeout_ms,
                max_frame_bytes,
                binary_wire,
                max_inflight,
                slot: slot_cfg,
                store_dir,
                trace_capacity,
                log_json,
                slow_request_ms,
            },
        )?;
        let admission = if queue_depth == 0 {
            "unbounded queue".to_string()
        } else {
            format!("queue depth {queue_depth}, over-limit requests shed")
        };
        println!(
            "serving GS-sparse MLP on {} ({shown_workers} workers, batch cap {max_batch}, \
             {admission}, {n_models} model(s), default \"{default_name}\")",
            handle.addr
        );
        println!(
            "protocol: JSON lines — {{\"op\":\"infer\",\"id\":1,\"model\":\"name\",\
             \"input\":[...]}}, {{\"op\":\"swap\"|\"load\",\"model\":\"name\",\
             \"path\":\"model.gsm\"}} (swap takes an optional \
             {{\"canary\":{{\"requests\":N,\"max_error_rate\":F}}}}), \
             {{\"op\":\"rollback\",\"model\":\"name\"}}, \
             {{\"op\":\"unload\",\"model\":\"name\"}}, \
             {{\"op\":\"models\"}}, {{\"op\":\"stats\"}}, {{\"op\":\"trace\"}}, \
             {{\"op\":\"metrics\"}}, {{\"op\":\"profile\"}}"
        );
        if binary_wire {
            println!(
                "binary wire framing: enabled (opt-in per connection via HELLO; infer \
                 payloads as raw little-endian f32; control plane stays JSON)"
            );
        }
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    if backend != "pjrt" {
        return Err(anyhow!("unknown backend {backend} (native|pjrt)"));
    }
    let (factory, inputs, outputs, max_batch, banner) = pjrt_factory(args)?;
    let handle = serve(
        move || factory(),
        ServeConfig {
            bind,
            workers,
            input_width: inputs,
            max_batch,
            window_ms,
            queue_depth,
            deadline_ms,
            max_conns,
            idle_timeout_ms,
            max_frame_bytes,
            binary_wire,
            max_inflight,
            trace_capacity,
            log_json,
            slow_request_ms,
            ..ServeConfig::default()
        },
    )?;
    println!(
        "serving GS-sparse MLP on {} ({shown_workers} workers, batch {max_batch}, {banner})",
        handle.addr
    );
    println!("protocol: JSON lines — {{\"op\":\"infer\",\"id\":1,\"input\":[...{inputs} floats]}}");
    if binary_wire {
        println!(
            "binary wire framing: enabled (opt-in per connection via HELLO; infer payloads \
             as raw little-endian f32; control plane stays JSON)"
        );
    }
    let _ = outputs;
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// The deployment-safety contract from the serve flags, applied to every
/// slot the server creates: CLI-registered, `load`-registered at
/// runtime, and manifest-restored.
fn slot_config(args: &Args) -> SlotConfig {
    let base = SlotConfig::default();
    SlotConfig {
        retain: args.usize("retain-versions", base.retain),
        quarantine_after: args.usize("quarantine-after", base.quarantine_after),
        quarantine_window_ms: args.usize("quarantine-window-ms", base.quarantine_window_ms as usize)
            as u64,
        quarantine_cooldown_ms: args
            .usize("quarantine-cooldown-ms", base.quarantine_cooldown_ms as usize)
            as u64,
        ..base
    }
}

/// The CLI-flag registry: `--models name=path,...`, a single `--model`,
/// or an inline random model.
fn cli_engine(args: &Args, threads: usize, slot_cfg: SlotConfig) -> Result<Engine> {
    if let Some(spec) = args.options.get("models") {
        return multi_model_engine(args, spec, threads, slot_cfg);
    }
    let (model, source, banner) = match args.options.get("model") {
        Some(path) => {
            let artifact = ModelArtifact::load(path)?;
            let banner = format!("artifact {path}: {}", artifact.describe());
            (artifact.instantiate(threads)?, path.clone(), banner)
        }
        None => {
            let spec = native_spec(args)?;
            let banner = format!(
                "native {} engine @ {:.0}% sparse output layer, {} plan",
                spec.pattern.name(),
                spec.sparsity * 100.0,
                spec.precision.name(),
            );
            let model = build_random_model(&spec)?.model;
            (model, "inline-random".to_string(), banner)
        }
    };
    println!("model \"default\": {banner}");
    let store = std::sync::Arc::new(ModelStore::new());
    store.register(
        "default",
        std::sync::Arc::new(ModelSlot::with_config(model, &source, threads, slot_cfg)),
    )?;
    Engine::from_store(store, "default", threads)
}

/// Replay a persisted registry from `--store-dir`. `Ok(None)` means no
/// usable manifest — missing, unreadable, or its default model failed to
/// restore — and the caller falls back to the CLI registry (the reason
/// is logged). Non-default entries that fail to restore are skipped with
/// a logged reason, never fatal: serving degrades to the slots that
/// restored.
fn engine_from_manifest(
    dir: &std::path::Path,
    threads: usize,
    slot_cfg: SlotConfig,
) -> Result<Option<Engine>> {
    use gs_sparse::model_store::manifest;
    let loaded = match manifest::Manifest::load_dir(dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!(
                "store manifest in {}: unreadable ({e:#}); starting from the CLI model flags",
                dir.display()
            );
            return Ok(None);
        }
    };
    let Some(m) = loaded else { return Ok(None) };
    let report = manifest::restore(&m, threads, slot_cfg);
    for (name, why) in &report.skipped {
        eprintln!("store manifest: skipping model \"{name}\": {why}");
    }
    if !report.restored.iter().any(|(n, _)| *n == m.default) {
        eprintln!(
            "store manifest: default model \"{}\" did not restore; starting from the CLI \
             model flags",
            m.default
        );
        return Ok(None);
    }
    let store = std::sync::Arc::new(ModelStore::with_capacity(m.max_models, &m.default));
    for (name, slot) in report.restored {
        println!(
            "model \"{name}\": restored v{} from {} (manifest)",
            slot.version(),
            slot.current().source
        );
        store.register(&name, slot)?;
    }
    Ok(Some(Engine::from_store(store, &m.default, threads)?))
}

/// `serve --models name=path.gsm,...`: load every named artifact into a
/// capacity-bounded [`ModelStore`] (`--max-models`, 0 = unbounded) and
/// pin the default (`--default-model`, else the first listed).
fn multi_model_engine(
    args: &Args,
    spec: &str,
    threads: usize,
    slot_cfg: SlotConfig,
) -> Result<Engine> {
    let mut entries: Vec<(String, String)> = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (name, path) = part
            .split_once('=')
            .ok_or_else(|| anyhow!("--models expects name=path.gsm entries, got \"{part}\""))?;
        ensure!(!name.trim().is_empty(), "--models entry \"{part}\" has an empty name");
        let name = name.trim().to_string();
        ensure!(
            !entries.iter().any(|(n, _)| *n == name),
            "--models names model \"{name}\" twice (a later entry would silently replace the \
             earlier one)"
        );
        entries.push((name, path.trim().to_string()));
    }
    ensure!(!entries.is_empty(), "--models is empty");
    let default_name = args.get("default-model", &entries[0].0).to_string();
    ensure!(
        entries.iter().any(|(n, _)| *n == default_name),
        "--default-model \"{default_name}\" is not among the --models entries"
    );
    let max_models = args.usize("max-models", 0);
    ensure!(
        max_models == 0 || entries.len() <= max_models,
        "--max-models {max_models} < {} initial models (refusing to evict at startup)",
        entries.len()
    );
    let store = std::sync::Arc::new(ModelStore::with_capacity(max_models, &default_name));
    for (name, path) in &entries {
        let artifact = ModelArtifact::load(path)?;
        println!("model \"{name}\": artifact {path}: {}", artifact.describe());
        let model = artifact.instantiate(threads)?;
        store.register(
            name,
            std::sync::Arc::new(ModelSlot::with_config(model, path, threads, slot_cfg)),
        )?;
    }
    Engine::from_store(store, &default_name, threads)
}

/// Build the deterministic random pruned model for the given spec and
/// write it as a `.gsm` artifact — the deployable counterpart of
/// `serve`'s in-process model (same seed ⇒ bit-identical logits).
fn cmd_export(args: &Args) -> Result<()> {
    let out = args.require("out")?;
    // Export only needs the weights; keep the throwaway in-process model
    // serial instead of auto-detecting a kernel pool.
    let spec = ModelSpec {
        threads: 1,
        ..native_spec(args)?
    };
    let (mut artifact, bm) = build_random_artifact(&spec)?;
    if args.has("tune") {
        // One-shot microbenchmark over the supported dispatch variants;
        // the winner is pinned in the artifact metadata so every server
        // that loads this .gsm inherits it (swap, restore, rollback).
        use gs_sparse::kernels::exec::GsExecPlan;
        let budget = std::time::Duration::from_millis(args.usize("tune-ms", 50) as u64);
        let mut plan = GsExecPlan::with_precision(&bm.gs, 1, spec.precision)?;
        let picked = plan.tune(spec.max_batch, budget);
        artifact.set_kernel_variant(picked);
        println!("tuned kernel variant: {} (budget {budget:?})", picked.name());
    }
    artifact.save(out)?;
    let bytes = std::fs::metadata(out)?.len();
    println!("exported {out} ({bytes} bytes): {}", artifact.describe());
    Ok(())
}

#[cfg(feature = "pjrt")]
#[allow(clippy::type_complexity)]
fn pjrt_factory(
    args: &Args,
) -> Result<(
    Box<dyn Fn() -> Result<SparseModel> + Send + Sync>,
    usize,
    usize,
    usize,
    String,
)> {
    use gs_sparse::coordinator::UniformGs;
    use gs_sparse::runtime::{Manifest, Runtime};
    use std::sync::Arc;

    let dir = args.get("artifacts", "artifacts").to_string();
    let manifest = Arc::new(Manifest::load(&dir)?);
    let cfg = manifest.mlp.clone();
    let (inputs, hidden, outputs) = (cfg.cfg("inputs")?, cfg.cfg("hidden")?, cfg.cfg("outputs")?);
    let (b, groups, max_batch) = (cfg.cfg("gs_b")?, cfg.cfg("gs_groups")?, cfg.cfg("batch")?);
    let seed = args.usize("seed", 42) as u64;
    let banner = format!(
        "pjrt GS({b},{b}) artifact @ {:.0}% sparse output layer",
        (1.0 - (groups * b) as f64 / hidden as f64) * 100.0
    );
    let factory = move || {
        let rt = Runtime::cpu()?;
        let mut rng = Prng::new(seed);
        let proj = Dense::random(outputs, hidden, 0.3, &mut rng);
        let uniform = UniformGs::compress_for(&proj, b, groups)?;
        let mut wrng = Prng::new(seed ^ 1);
        SparseModel::load(
            &rt,
            &manifest,
            wrng.normal_vec(inputs * hidden, 0.1),
            vec![0.0; hidden],
            &uniform,
            wrng.normal_vec(outputs, 0.1),
        )
    };
    Ok((Box::new(factory), inputs, outputs, max_batch, banner))
}

#[cfg(not(feature = "pjrt"))]
#[allow(clippy::type_complexity)]
fn pjrt_factory(
    _args: &Args,
) -> Result<(
    Box<dyn Fn() -> Result<SparseModel> + Send + Sync>,
    usize,
    usize,
    usize,
    String,
)> {
    Err(anyhow!(
        "the pjrt backend requires building with --features pjrt (and the real xla crate); \
         the native backend needs neither: gs-sparse serve --backend native"
    ))
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> Result<()> {
    use gs_sparse::runtime::{Manifest, Runtime};
    use gs_sparse::train::{experiments::Schedule, run_quality};

    let dir = args.get("artifacts", "artifacts").to_string();
    let manifest = Manifest::load(&dir)?;
    let model = args.get("model", "resnet");
    let mm = manifest
        .models
        .get(model)
        .ok_or_else(|| anyhow!("unknown model {model}"))?;
    let pattern = parse_pattern(args)?;
    let sparsity = args.f64("sparsity", 0.8);
    let rt = Runtime::cpu()?;
    let r = run_quality(
        &rt,
        mm,
        pattern,
        sparsity,
        Schedule::default(),
        args.usize("seed", 42) as u64,
    )?;
    println!(
        "{} {} target={:.0}% achieved={:.1}% metric={:.4} (dense {:.4}) loss={:.4}",
        r.model,
        r.pattern,
        r.target_sparsity * 100.0,
        r.achieved_sparsity * 100.0,
        r.metric,
        r.dense_metric,
        r.loss
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(args: &Args) -> Result<()> {
    let _ = parse_pattern(args)?; // validate flags even when unavailable
    Err(anyhow!(
        "train drives the AOT artifacts through PJRT; rebuild with --features pjrt"
    ))
}

fn cmd_simulate(args: &Args) -> Result<()> {
    use gs_sparse::bench::Table;
    use gs_sparse::kernels::{spmv_block_sim, spmv_csr_sim, spmv_dense_sim, spmv_gs_sim};
    use gs_sparse::sim::MachineConfig;
    use gs_sparse::sparse::{BlockSparse, Csr};

    let rows = args.usize("rows", 1024);
    let cols = args.usize("cols", 1024);
    let b = args.usize("banks", 16);
    let sparsity = args.f64("sparsity", 0.9);
    let mut rng = Prng::new(args.usize("seed", 42) as u64);
    let w = Dense::random(rows, cols, 1.0, &mut rng);
    let x = rng.normal_vec(cols, 1.0);
    let cfg = MachineConfig::with_subbanks(b);
    let dense = spmv_dense_sim(&w, &x, cfg);
    let mut table = Table::new(
        &format!("simulate spMV {rows}x{cols} @ {:.0}%, B={b}", sparsity * 100.0),
        &["pattern", "cycles", "speedup", "bottleneck"],
    );
    table.row(&[
        "Dense".into(),
        dense.report.cycles.to_string(),
        "1.00".into(),
        dense.report.bottleneck().into(),
    ]);
    let mut run = |name: &str, p: Pattern| -> Result<()> {
        let mask = prune(&w, p, sparsity)?;
        let mut pw = w.clone();
        pw.apply_mask(&mask);
        let out = match p {
            Pattern::Block { .. } => spmv_block_sim(&BlockSparse::from_dense(&pw, p)?, &x, cfg),
            Pattern::Irregular => spmv_csr_sim(&Csr::from_dense(&pw), &x, cfg, false),
            _ => spmv_gs_sim(&GsFormat::from_dense(&pw, p)?, &x, cfg),
        };
        table.row(&[
            name.into(),
            out.report.cycles.to_string(),
            format!("{:.2}", dense.report.cycles as f64 / out.report.cycles as f64),
            out.report.bottleneck().into(),
        ]);
        Ok(())
    };
    run("Block-h", Pattern::Block { b, k: b })?;
    run("Block-v", Pattern::Block { b, k: 1 })?;
    run("GS-h", Pattern::Gs { b, k: b })?;
    run("GS-v", Pattern::Gs { b, k: 1 })?;
    run("CSR", Pattern::Irregular)?;
    table.print();
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    use gs_sparse::runtime::Manifest;

    let dir = args.get("artifacts", "artifacts").to_string();
    let manifest = Manifest::load(&dir)?;
    println!("artifacts: {}", manifest.dir.display());
    for (name, m) in &manifest.models {
        let total: usize = m.params.iter().map(|p| p.shape.iter().product::<usize>()).sum();
        let prunable: usize = m
            .params
            .iter()
            .filter(|p| p.prunable)
            .map(|p| p.shape.iter().product::<usize>())
            .sum();
        println!(
            "  {name}: {total} params ({} tensors, {prunable} prunable weights), lr={}",
            m.params.len(),
            m.lr
        );
    }
    println!(
        "  mlp_forward: Pallas GS({},{}) output layer, batch {}",
        manifest.mlp.cfg("gs_b")?,
        manifest.mlp.cfg("gs_b")?,
        manifest.mlp.cfg("batch")?
    );
    Ok(())
}
