//! CRC-32 (IEEE 802.3, the zlib/PNG polynomial) — the integrity check of
//! the `.gsm` model artifact format ([`crate::model_store`]).
//!
//! Table-driven, one 256-entry table built at first use. Matches
//! `zlib.crc32` exactly (validated against it by the Python port used to
//! develop this module): reflected polynomial `0xEDB88320`, initial value
//! `0xFFFF_FFFF`, final xor `0xFFFF_FFFF`.

use std::sync::OnceLock;

fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (n, slot) in t.iter_mut().enumerate() {
            let mut c = n as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 {
                    0xEDB8_8320 ^ (c >> 1)
                } else {
                    c >> 1
                };
            }
            *slot = c;
        }
        t
    })
}

/// Streaming CRC-32 state.
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Absorb `bytes` into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let t = table();
        for &b in bytes {
            self.state = t[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
    }

    /// Finish and return the checksum (the state is not consumed; further
    /// updates continue the stream).
    pub fn value(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.value()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard check values (same as zlib.crc32).
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
        assert_eq!(crc32(&[0u8; 32]), 0x190A_55AD);
    }

    #[test]
    fn streaming_equals_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut c = Crc32::new();
        for chunk in data.chunks(97) {
            c.update(chunk);
        }
        assert_eq!(c.value(), crc32(&data));
    }

    #[test]
    fn sensitive_to_single_bit_flips() {
        let mut data = vec![7u8; 1024];
        let base = crc32(&data);
        data[512] ^= 0x01;
        assert_ne!(crc32(&data), base);
    }
}
