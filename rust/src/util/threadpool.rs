//! Fixed-size thread pool over std primitives (no external deps).
//!
//! Used by the coordinator's worker pool, the native execution engine's
//! parallel band kernels, and the bench harness's parallel sweeps. Jobs
//! are boxed closures; `join` blocks until the queue drains.
//!
//! Panic safety: a panicking job must still decrement the outstanding
//! counter (otherwise `join` deadlocks forever), so the decrement lives in
//! a drop guard that runs during unwinding. The panic itself is not
//! swallowed: the first payload is recorded and re-raised from the next
//! `join()` on the submitting thread.

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    /// Number of jobs submitted but not yet finished.
    outstanding: Mutex<usize>,
    idle: Condvar,
    /// First panic payload observed in a worker, surfaced by `join`.
    panicked: Mutex<Option<String>>,
}

/// Decrements `outstanding` when dropped — including during a panic
/// unwind — so `join` can never be left waiting on a job that died.
struct DoneGuard {
    shared: Arc<Shared>,
}

impl Drop for DoneGuard {
    fn drop(&mut self) {
        let mut out = self.shared.outstanding.lock().unwrap();
        *out -= 1;
        if *out == 0 {
            self.shared.idle.notify_all();
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// A fixed-size pool of worker threads.
pub struct ThreadPool {
    /// Mutex-wrapped so the pool is `Sync` on every toolchain (std's
    /// `mpsc::Sender` only became `Sync` in recent releases).
    tx: Option<Mutex<mpsc::Sender<Job>>>,
    workers: Vec<thread::JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0, "ThreadPool::new(0)");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            outstanding: Mutex::new(0),
            idle: Condvar::new(),
            panicked: Mutex::new(None),
        });
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("gs-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                let _done = DoneGuard {
                                    shared: Arc::clone(&shared),
                                };
                                let result = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                                if let Err(payload) = result {
                                    let mut slot = shared.panicked.lock().unwrap();
                                    if slot.is_none() {
                                        *slot = Some(panic_message(payload.as_ref()));
                                    }
                                }
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(Mutex::new(tx)),
            workers,
            shared,
        }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let mut out = self.shared.outstanding.lock().unwrap();
            *out += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .lock()
            .unwrap()
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has completed. If any job panicked
    /// since the last `join`, the first panic is re-raised here.
    pub fn join(&self) {
        let mut out = self.shared.outstanding.lock().unwrap();
        while *out > 0 {
            out = self.shared.idle.wait(out).unwrap();
        }
        drop(out);
        if let Some(msg) = self.shared.panicked.lock().unwrap().take() {
            panic!("ThreadPool job panicked: {msg}");
        }
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            self.execute(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        self.join();
        Arc::try_unwrap(results)
            .ok()
            .expect("results still shared")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("job did not run"))
            .collect()
    }
}

/// Resolve a requested thread/worker count: `0` means "auto-detect" and
/// maps to [`std::thread::available_parallelism`] (1 if unknown); any
/// other value is taken literally. Used by `SparseModel::native` kernel
/// threads and the serving coordinator's worker count, so `--threads 0` /
/// `workers: 0` size themselves to the machine instead of silently
/// running serial.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    }
}

/// Partition `0..total` into at most `njobs` contiguous, near-equal,
/// non-empty spans — the work-split helper behind the pool-parallel
/// stages (dense feature spans, bias batch spans).
pub fn partition_spans(total: usize, njobs: usize) -> Vec<(usize, usize)> {
    let n = njobs.max(1);
    (0..n)
        .map(|s| (s * total / n, (s + 1) * total / n))
        .filter(|&(lo, hi)| hi > lo)
        .collect()
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers exit their loops
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&count);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(count.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<usize>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn join_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.join(); // must not hang
    }

    #[test]
    fn reusable_after_join() {
        let pool = ThreadPool::new(2);
        let a = pool.map(vec![1, 2, 3], |x| x + 1);
        let b = pool.map(vec![10, 20], |x| x + 1);
        assert_eq!(a, vec![2, 3, 4]);
        assert_eq!(b, vec![11, 21]);
    }

    #[test]
    fn panicking_job_does_not_deadlock_join() {
        let pool = ThreadPool::new(2);
        let count = Arc::new(AtomicUsize::new(0));
        pool.execute(|| panic!("boom in worker"));
        for _ in 0..10 {
            let c = Arc::clone(&count);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // join must return (not hang) and surface the panic.
        let joined = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.join()));
        let err = joined.expect_err("join should re-raise the worker panic");
        let msg = if let Some(s) = err.downcast_ref::<String>() {
            s.clone()
        } else {
            String::new()
        };
        assert!(msg.contains("boom in worker"), "unexpected panic: {msg}");
        // All non-panicking jobs still ran and the pool stays usable.
        assert_eq!(count.load(Ordering::SeqCst), 10);
        let out = pool.map(vec![1, 2], |x| x + 1);
        assert_eq!(out, vec![2, 3]);
    }

    #[test]
    fn pool_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ThreadPool>();
    }

    #[test]
    fn resolve_threads_auto_detects_zero() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(7), 7);
    }

    #[test]
    fn partition_spans_covers_contiguously() {
        for &(total, njobs) in &[(0usize, 4usize), (1, 4), (7, 3), (16, 4), (5, 9), (100, 1)] {
            let spans = partition_spans(total, njobs);
            assert!(spans.len() <= njobs.max(1));
            assert!(spans.iter().all(|&(lo, hi)| hi > lo));
            let covered: usize = spans.iter().map(|&(lo, hi)| hi - lo).sum();
            assert_eq!(covered, total, "total={total} njobs={njobs}");
            for w in spans.windows(2) {
                assert_eq!(w[0].1, w[1].0, "not contiguous");
            }
            if total > 0 {
                assert_eq!(spans[0].0, 0);
                assert_eq!(spans.last().unwrap().1, total);
            }
        }
    }
}
