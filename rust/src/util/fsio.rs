//! Durable atomic file replacement.
//!
//! The artifact writer and the store manifest both need the same
//! guarantee: after a crash at *any* point, a reader sees either the old
//! complete file or the new complete file — never a torn hybrid, and
//! never a new file whose bytes are still in the page cache when the
//! rename already survived. [`write_atomic`] provides it:
//!
//! 1. remove a stale `<name>.tmp` left by a previously crashed writer,
//! 2. write the new bytes to `<name>.tmp` and **fsync the file** (the
//!    rename must never be more durable than the data it points to),
//! 3. rename over the destination (atomic on POSIX),
//! 4. fsync the parent directory so the rename itself is durable.

use anyhow::{Context, Result};
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

/// The sibling temp path a [`write_atomic`] of `path` stages through.
pub fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Durably replace `path` with `bytes` (see module docs for the crash
/// contract).
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = tmp_path(path);
    match std::fs::remove_file(&tmp) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
        Err(e) => {
            return Err(e).with_context(|| format!("remove stale temp file {}", tmp.display()))
        }
    }
    let mut f =
        File::create(&tmp).with_context(|| format!("create temp file {}", tmp.display()))?;
    f.write_all(bytes)
        .with_context(|| format!("write temp file {}", tmp.display()))?;
    f.sync_all()
        .with_context(|| format!("sync temp file {}", tmp.display()))?;
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} into place at {}", tmp.display(), path.display()))?;
    sync_parent_dir(path);
    Ok(())
}

/// Fsync the directory containing `path` so a completed rename survives
/// power loss. Best-effort: some filesystems/platforms refuse directory
/// handles, and a failure here only weakens durability, never
/// correctness of what a reader observes.
fn sync_parent_dir(path: &Path) {
    #[cfg(unix)]
    if let Some(dir) = path.parent() {
        let dir = if dir.as_os_str().is_empty() {
            Path::new(".")
        } else {
            dir
        };
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    #[cfg(not(unix))]
    let _ = path;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("gs-fsio-{}-{name}", std::process::id()))
    }

    #[test]
    fn writes_and_replaces() {
        let path = scratch("replace.bin");
        write_atomic(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        write_atomic(&path, b"second, longer payload").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second, longer payload");
        assert!(!tmp_path(&path).exists(), "temp file must not linger");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cleans_stale_tmp_from_crashed_writer() {
        let path = scratch("stale.bin");
        std::fs::write(tmp_path(&path), b"torn half-write").unwrap();
        write_atomic(&path, b"complete").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"complete");
        assert!(!tmp_path(&path).exists(), "stale temp file must be gone");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tmp_path_is_a_sibling() {
        assert_eq!(
            tmp_path(Path::new("/a/b/model.gsm")),
            PathBuf::from("/a/b/model.gsm.tmp")
        );
        assert_eq!(
            tmp_path(Path::new("manifest.json")),
            PathBuf::from("manifest.json.tmp")
        );
    }
}
