//! Hand-rolled IEEE 754 binary16 (f16) bit conversions.
//!
//! The packed execution plan stores weight values at the paper's storage
//! resolution (§X): half precision, which halves plan bytes and memory
//! bandwidth on the gather+FMA path. The offline registry has no `half`
//! crate, so the two conversions live here: a narrowing
//! [`f32_to_f16_bits`] with round-to-nearest-even (used once at pack
//! time) and a widening [`f16_bits_to_f32`] (used in the kernel inner
//! loops — branch-light, exact).
//!
//! Both directions were fuzzed exhaustively against numpy's binary16:
//! widening matches for all 65536 bit patterns, the widen→narrow
//! roundtrip is the identity for all 65536 patterns (including NaNs),
//! and narrowing matches RNE on an all-exponent edge sweep plus 200k
//! random f32 bit patterns.
//!
//! Error contract used by the f16-plan property tests: for finite `x`,
//! `|f16(x) - x| <= max(2^-11 * |x|, 2^-25)` — half an ulp in the normal
//! f16 range, half the subnormal step below it.

/// Narrow an `f32` to f16 bits with round-to-nearest-even.
///
/// Overflow goes to ±inf, underflow to ±0; NaNs stay NaNs (payload
/// truncated, quiet bit forced if the truncation would yield inf).
#[inline]
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN: keep NaN-ness through the 23→10 bit truncation.
        if man == 0 {
            return sign | 0x7c00;
        }
        let m = (man >> 13) as u16;
        return sign | 0x7c00 | if m == 0 { 0x0200 } else { m };
    }
    let unbiased = exp - 127;
    if unbiased >= 16 {
        return sign | 0x7c00; // overflow → inf
    }
    if unbiased >= -14 {
        // Normal f16: re-bias and round the mantissa 23→10 bits (RNE).
        // A rounding carry propagates into the exponent field, which is
        // exactly the IEEE behaviour (up to and including → inf).
        let mut out = (((unbiased + 15) as u32) << 10) | (man >> 13);
        let round = man & 0x1fff;
        if round > 0x1000 || (round == 0x1000 && (out & 1) == 1) {
            out += 1;
        }
        return sign | out as u16;
    }
    if unbiased >= -25 {
        // Subnormal f16: shift the (implicit-1) mantissa into place, RNE.
        let mant = man | 0x0080_0000;
        let shift = (13 + (-14 - unbiased)) as u32;
        let mut out = mant >> shift;
        let round = mant & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if round > half || (round == half && (out & 1) == 1) {
            out += 1;
        }
        return sign | out as u16;
    }
    sign // underflow → signed zero
}

/// Widen f16 bits to an `f32`. Exact for every bit pattern.
#[inline]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13) // inf / NaN (payload widened)
    } else if exp == 0 {
        if man == 0 {
            sign // signed zero
        } else {
            // Subnormal: normalize the 10-bit mantissa. `man * 2^-24`
            // always fits a normal f32.
            let mut m = man;
            let mut sh = 0u32;
            while m & 0x400 == 0 {
                m <<= 1;
                sh += 1;
            }
            sign | ((113 - sh) << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Round an `f32` through f16 storage and back — the value a packed
/// f16 plan actually multiplies with.
#[inline]
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        for &(x, h) in &[
            (0.0f32, 0x0000u16),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (0.5, 0x3800),
            (65504.0, 0x7bff),         // f16 max finite
            (65520.0, 0x7c00),         // rounds to inf
            (f32::INFINITY, 0x7c00),
            (f32::NEG_INFINITY, 0xfc00),
            (6.103_515_6e-5, 0x0400),  // 2^-14, min normal
            (5.960_464_5e-8, 0x0001),  // 2^-24, min subnormal
            (1e-30, 0x0000),           // deep underflow
        ] {
            assert_eq!(f32_to_f16_bits(x), h, "narrow {x}");
        }
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f16_bits_to_f32(0x3c00), 1.0);
        assert_eq!(f16_bits_to_f32(0xc000), -2.0);
        assert_eq!(f16_bits_to_f32(0x0001), 5.960_464_5e-8);
        assert_eq!(f16_bits_to_f32(0x8000).to_bits(), (-0.0f32).to_bits());
        assert!(f16_bits_to_f32(0x7e00).is_nan());
        assert!(f32_to_f16_bits(f32::NAN) & 0x7c00 == 0x7c00);
        assert!(f32_to_f16_bits(f32::NAN) & 0x03ff != 0);
    }

    #[test]
    fn round_to_nearest_even_ties() {
        // 1 + 2^-11 is exactly halfway between 0x3c00 and 0x3c01 → even.
        assert_eq!(f32_to_f16_bits(1.0 + 2f32.powi(-11)), 0x3c00);
        // 1 + 3*2^-11 is halfway between 0x3c01 and 0x3c02 → even (0x3c02).
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 2f32.powi(-11)), 0x3c02);
        // Just above the tie rounds up.
        assert_eq!(f32_to_f16_bits(1.0 + 2f32.powi(-11) + 2f32.powi(-20)), 0x3c01);
        // Subnormal tie: 2^-25 is halfway between 0 and 2^-24 → even (0).
        assert_eq!(f32_to_f16_bits(2f32.powi(-25)), 0x0000);
        assert_eq!(f32_to_f16_bits(2f32.powi(-25) * 1.5), 0x0001);
    }

    #[test]
    fn roundtrip_is_identity_for_every_f16() {
        // Exhaustive: widening then narrowing must reproduce all 65536
        // bit patterns, NaN payloads included.
        for h in 0..=u16::MAX {
            let rt = f32_to_f16_bits(f16_bits_to_f32(h));
            assert_eq!(rt, h, "roundtrip {h:#06x} -> {rt:#06x}");
        }
    }

    #[test]
    fn error_bound_on_normals() {
        let mut rng = crate::util::prng::Prng::new(99);
        for _ in 0..10_000 {
            let x = rng.gaussian_f32();
            let back = f16_round(x);
            let err = (back - x).abs();
            assert!(
                err <= (2f32.powi(-11) * x.abs()).max(2f32.powi(-25)),
                "|f16({x}) - {x}| = {err}"
            );
        }
    }

    #[test]
    fn rounding_carry_into_exponent() {
        // Largest f32 below 2.0 rounds up across the exponent boundary.
        let x = f32::from_bits(0x3fff_ffff); // 1.9999999
        assert_eq!(f32_to_f16_bits(x), 0x4000); // exactly 2.0
        // Largest finite f16 neighbourhood: 65519.996 → 65504, 65520 → inf.
        assert_eq!(f32_to_f16_bits(65519.0), 0x7bff);
    }
}
