//! Fixed log-scale bucket histogram for serving latencies and batch
//! occupancy.
//!
//! Replaces the old drop-half latency `Reservoir`, whose bulk
//! `drain(..50_000)` discarded the oldest half wholesale — summaries
//! right after a drain reflected only recent traffic with no indication
//! of the window. A [`Histogram`] is **cumulative over the process
//! lifetime**: `n` counts every recorded sample since startup, memory
//! is a fixed array of atomic counters regardless of traffic, and the
//! record path is lock-free (one atomic increment per bucket plus
//! sum/min/max updates — safe on the hottest serving paths).
//!
//! Buckets grow geometrically by `2^(1/8)` (~9.05% per bucket), so a
//! reported percentile is the *upper bound* of the bucket holding the
//! rank — never below the true order statistic at that rank and at most
//! one bucket factor above it (see [`Histogram::summary`]). Exactness:
//! `n`, `sum` (hence `mean`), `min`, and `max` are exact (to the
//! histogram's fixed-point resolution); percentiles and `std` are
//! bucket-bounded approximations.

use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};

/// Buckets per factor-of-two of the value range.
const BUCKETS_PER_OCTAVE: usize = 8;

/// The geometric growth factor between adjacent bucket bounds,
/// `2^(1/8)`: the worst-case relative error of a reported percentile.
pub const BUCKET_FACTOR: f64 = 1.090_507_732_665_257_7;

/// Lock-free log-scale histogram with exact count/sum/min/max.
pub struct Histogram {
    /// Lower edge of the first regular bucket; values below land in the
    /// underflow bucket (reported as `lo`).
    lo: f64,
    /// Fixed-point scale for the exact sum/min/max accumulators
    /// (e.g. 1e9 = nanosecond resolution for values in seconds).
    scale: f64,
    /// Upper bound of regular bucket `i` (exclusive); bucket `i` covers
    /// `[lo * F^i, lo * F^(i+1))`.
    bounds: Vec<f64>,
    /// `[underflow, regular buckets ..., overflow]`.
    counts: Vec<AtomicU64>,
    /// Exact sample count (matches the sum of `counts`).
    count: AtomicU64,
    /// Exact sum in `scale` fixed-point units.
    sum: AtomicU64,
    /// Exact min/max in `scale` units (`u64::MAX` / 0 until a sample).
    min: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    /// A histogram covering `[lo, lo * 2^octaves)` with 8 buckets per
    /// octave; `scale` is the fixed-point resolution of the exact
    /// sum/min/max accumulators.
    pub fn new(lo: f64, octaves: usize, scale: f64) -> Histogram {
        assert!(lo > 0.0 && octaves > 0);
        let n = octaves * BUCKETS_PER_OCTAVE;
        let bounds: Vec<f64> = (0..n)
            .map(|i| lo * 2f64.powf((i + 1) as f64 / BUCKETS_PER_OCTAVE as f64))
            .collect();
        let counts = (0..n + 2).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            lo,
            scale,
            bounds,
            counts,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Serving-latency configuration: 1 µs to ~67 s at nanosecond
    /// accumulator resolution. Sub-microsecond samples fold into the
    /// underflow bucket (reported as 1 µs), >67 s into overflow
    /// (reported as the exact max).
    pub fn latency() -> Histogram {
        Histogram::new(1e-6, 26, 1e9)
    }

    /// Batch-occupancy configuration: 1 to 16384 rows at unit
    /// resolution (integer row counts are exact in the accumulators).
    pub fn occupancy() -> Histogram {
        Histogram::new(1.0, 14, 1.0)
    }

    /// Record one sample (non-finite or negative samples are dropped).
    pub fn record(&self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            return;
        }
        let fixed = (v * self.scale).round() as u64;
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(fixed, Ordering::Relaxed);
        self.min.fetch_min(fixed, Ordering::Relaxed);
        self.max.fetch_max(fixed, Ordering::Relaxed);
        let idx = if v < self.lo {
            0
        } else if v >= self.bounds[self.bounds.len() - 1] {
            self.counts.len() - 1
        } else {
            // First bound strictly above v; +1 skips the underflow slot.
            1 + self.bounds.partition_point(|&b| b <= v)
        };
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Exact number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact sum of recorded samples (in natural units).
    pub fn sum(&self) -> f64 {
        self.sum.load(Ordering::Relaxed) as f64 / self.scale
    }

    /// Upper percentile-reporting bound of bucket `idx` in the counts
    /// array; the overflow bucket reports the exact recorded max.
    fn upper(&self, idx: usize, max: f64) -> f64 {
        if idx == 0 {
            self.lo
        } else if idx == self.counts.len() - 1 {
            max
        } else {
            self.bounds[idx - 1]
        }
    }

    /// Summary over everything recorded so far (None while empty).
    ///
    /// Guarantees, for samples within `[lo, lo * 2^octaves)`: each
    /// percentile is ≥ the true order statistic at its rank and ≤ that
    /// statistic × [`BUCKET_FACTOR`] (the rank is `ceil(q * (n-1))`,
    /// matching [`Summary::of`]'s index before interpolation), clamped
    /// to the exact recorded max. `n`, `mean`, `min`, `max` are exact
    /// at the fixed-point resolution; `std` is approximated from bucket
    /// representative points.
    pub fn summary(&self) -> Option<Summary> {
        let counts: Vec<u64> = self
            .counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let n: u64 = counts.iter().sum();
        if n == 0 {
            return None;
        }
        // Concurrent recorders may have bumped `sum` before/after their
        // bucket landed; use the bucket total for ranks (internally
        // consistent) and the exact accumulators for moments.
        let min = self.min.load(Ordering::Relaxed) as f64 / self.scale;
        let max = self.max.load(Ordering::Relaxed) as f64 / self.scale;
        let mean = self.sum.load(Ordering::Relaxed) as f64 / self.scale / n as f64;
        let pct = |q: f64| -> f64 {
            let rank = ((q * (n - 1) as f64).ceil() as u64 + 1).clamp(1, n);
            let mut cum = 0u64;
            for (idx, c) in counts.iter().enumerate() {
                cum += c;
                if cum >= rank {
                    return self.upper(idx, max).min(max);
                }
            }
            max
        };
        // Approximate spread from per-bucket representatives (geometric
        // bucket midpoint, clamped to the observed range).
        let mut var = 0.0;
        for (idx, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let hi = self.upper(idx, max);
            let lo = if idx <= 1 { self.lo } else { self.bounds[idx - 2] };
            let rep = (lo * hi).sqrt().clamp(min, max);
            var += c as f64 * (rep - mean) * (rep - mean);
        }
        Some(Summary {
            n: n as usize,
            mean,
            std: (var / n as f64).sqrt(),
            min,
            max,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_has_no_summary() {
        assert!(Histogram::latency().summary().is_none());
    }

    #[test]
    fn exact_count_mean_min_max() {
        let h = Histogram::latency();
        h.record(0.001);
        h.record(0.003);
        let s = h.summary().unwrap();
        assert_eq!(s.n, 2);
        assert!((s.mean - 0.002).abs() < 1e-9, "{}", s.mean);
        assert!((s.min - 0.001).abs() < 1e-9);
        assert!((s.max - 0.003).abs() < 1e-9);
    }

    #[test]
    fn percentile_is_bucket_bounded_and_max_clamped() {
        let h = Histogram::latency();
        h.record(0.05);
        h.record(0.05);
        // Both samples share the max: the bucket upper bound is clamped
        // to the exact recorded max, so the p50 is exact.
        let s = h.summary().unwrap();
        assert!((s.p50 - 0.05).abs() < 1e-9, "{}", s.p50);
        assert!((s.p99 - 0.05).abs() < 1e-9);
    }

    #[test]
    fn percentile_brackets_the_order_statistic() {
        let h = Histogram::latency();
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 * 1e-3).collect();
        for &s in &samples {
            h.record(s);
        }
        let s = h.summary().unwrap();
        // Rank for q over n=100: ceil(q * 99) zero-indexed.
        let oracle_p95 = samples[(0.95f64 * 99.0).ceil() as usize];
        assert!(s.p95 >= oracle_p95 - 1e-9, "{} < {}", s.p95, oracle_p95);
        assert!(s.p95 <= oracle_p95 * BUCKET_FACTOR + 1e-9);
    }

    #[test]
    fn underflow_and_overflow_are_absorbed() {
        let h = Histogram::latency();
        h.record(1e-9); // below lo: underflow, reported as lo
        h.record(1e5); // above range: overflow, reported as exact max
        h.record(f64::NAN); // dropped
        h.record(-1.0); // dropped
        let s = h.summary().unwrap();
        assert_eq!(s.n, 2);
        assert!((s.p50 - 1e-6).abs() < 1e-12, "{}", s.p50);
        assert!((s.max - 1e5).abs() < 1e-6);
        assert!((s.p99 - 1e5).abs() < 1e-6, "overflow reports exact max");
    }

    #[test]
    fn occupancy_keeps_small_integers_distinct() {
        let h = Histogram::occupancy();
        for v in [1.0, 2.0, 3.0, 7.0, 64.0, 1024.0] {
            h.record(v);
        }
        let s = h.summary().unwrap();
        assert_eq!(s.n, 6);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 1024.0);
        // 1024 is inside the 14-octave range, not overflow.
        assert!(s.p99 <= 1024.0 * BUCKET_FACTOR);
    }

    #[test]
    fn concurrent_recording_is_exact_on_counts() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::latency());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..1000 {
                        h.record(1e-4 * (1 + (t * 1000 + i) % 50) as f64);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
        assert_eq!(h.summary().unwrap().n, 4000);
    }
}
