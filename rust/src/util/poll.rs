//! Minimal readiness-notification shim over the platform poller.
//!
//! The serving front end multiplexes every client socket onto one
//! event-loop thread instead of spawning a thread per connection, which
//! needs a level-triggered "which fds are readable" primitive. The
//! crate is deliberately zero-dep, so this module declares the handful
//! of syscall wrappers it needs (`extern "C"` — std already links
//! libc) instead of pulling in a polling crate:
//!
//! * Linux: `epoll` (`epoll_create1`/`epoll_ctl`/`epoll_wait`).
//! * macOS/iOS: `kqueue`/`kevent` (the only BSD layout we commit to —
//!   FreeBSD changed `struct kevent` in 12 and NetBSD differs again).
//! * Other unix: a `poll(2)` fallback over the registered-fd table.
//! * Non-unix: [`Poller::new`] fails with `Unsupported` (the serving
//!   front end is unix-only; everything else in the crate still
//!   compiles and runs).
//!
//! Tokens are caller-chosen `u64`s (the server uses connection ids, so
//! fd reuse after close can never alias a stale entry). All interest is
//! read-only and level-triggered: the event loop drains each readable
//! socket to `WouldBlock`, so a level-triggered wakeup that races a
//! concurrent drain is harmless. Writers use the single-fd
//! [`wait_writable`] helper instead of registering write interest —
//! write stalls are rare and per-connection, not loop-global.

use std::io;
use std::time::Duration;

/// Raw file descriptor (matches `std::os::unix::io::RawFd` on unix).
pub type RawFd = i32;

/// The raw fd of a socket (listener or stream). On non-unix targets
/// this returns -1; [`Poller::new`] fails there first.
#[cfg(unix)]
pub fn raw_fd<T: std::os::unix::io::AsRawFd>(sock: &T) -> RawFd {
    sock.as_raw_fd()
}

#[cfg(not(unix))]
pub fn raw_fd<T>(_sock: &T) -> RawFd {
    -1
}

/// One readiness event from [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token the fd was registered with.
    pub token: u64,
    /// Readable, hung up, or errored — in every case the owner should
    /// read (a read reports the EOF/error precisely).
    pub readable: bool,
}

/// Level-triggered read-readiness poller over the platform facility.
pub struct Poller {
    inner: imp::Poller,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        Ok(Poller { inner: imp::Poller::new()? })
    }

    /// Watch `fd` for read readiness, reporting it as `token`.
    pub fn register_read(&self, fd: RawFd, token: u64) -> io::Result<()> {
        self.inner.register_read(fd, token)
    }

    /// Stop watching `fd`. Must be called before the fd is closed when
    /// other duplicates of it remain open (epoll keys on the open file
    /// description, not the descriptor).
    pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
        self.inner.deregister(fd)
    }

    /// Block until at least one registered fd is readable or `timeout`
    /// elapses (`None` = wait forever), filling `out` with the ready
    /// set. `EINTR` retries internally.
    pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        self.inner.wait(out, timeout)
    }
}

fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        // Round up so a 0 < t < 1ms budget never busy-spins at 0.
        Some(t) => t.as_millis().clamp(1, i32::MAX as u128) as i32,
    }
}

/// Block until `fd` is writable (or errored — the next write reports
/// it), up to `timeout_ms` milliseconds. Returns whether the fd became
/// ready. Used by connection writers to park on a full send buffer
/// without registering write interest in the main poller.
#[cfg(unix)]
pub fn wait_writable(fd: RawFd, timeout_ms: i32) -> io::Result<bool> {
    use std::os::raw::c_int;

    #[repr(C)]
    struct PollFd {
        fd: c_int,
        events: i16,
        revents: i16,
    }

    #[cfg(target_os = "linux")]
    type NFds = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    type NFds = std::os::raw::c_uint;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NFds, timeout: c_int) -> c_int;
    }

    const POLLOUT: i16 = 0x004;

    let mut pfd = PollFd { fd, events: POLLOUT, revents: 0 };
    loop {
        let n = unsafe { poll(&mut pfd, 1, timeout_ms) };
        if n < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                continue;
            }
            return Err(err);
        }
        return Ok(n > 0);
    }
}

#[cfg(not(unix))]
pub fn wait_writable(_fd: RawFd, _timeout_ms: i32) -> io::Result<bool> {
    Err(io::Error::new(
        io::ErrorKind::Unsupported,
        "readiness polling is unix-only",
    ))
}

#[cfg(target_os = "linux")]
mod imp {
    use super::{timeout_ms, Event};
    use std::io;
    use std::os::raw::c_int;
    use std::time::Duration;

    // Kernel UAPI: packed on x86_64 only.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    const EPOLL_CLOEXEC: c_int = 0o2000000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLLIN: u32 = 0x001;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    const MAX_EVENTS: usize = 64;

    pub struct Poller {
        epfd: c_int,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        pub fn register_read(&self, fd: i32, token: u64) -> io::Result<()> {
            let mut ev = EpollEvent { events: EPOLLIN | EPOLLRDHUP, data: token };
            let rc = unsafe { epoll_ctl(self.epfd, EPOLL_CTL_ADD, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn deregister(&self, fd: i32) -> io::Result<()> {
            let mut ev = EpollEvent { events: 0, data: 0 };
            let rc = unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            let mut events = [EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
            loop {
                let n = unsafe {
                    epoll_wait(
                        self.epfd,
                        events.as_mut_ptr(),
                        MAX_EVENTS as c_int,
                        timeout_ms(timeout),
                    )
                };
                if n < 0 {
                    let err = io::Error::last_os_error();
                    if err.kind() == io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(err);
                }
                for ev in events.iter().take(n as usize) {
                    // Copy out of the (possibly packed) struct before use.
                    let bits = ev.events;
                    let token = ev.data;
                    out.push(Event {
                        token,
                        readable: bits & (EPOLLIN | EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                    });
                }
                return Ok(());
            }
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

#[cfg(any(target_os = "macos", target_os = "ios"))]
mod imp {
    use super::Event;
    use std::io;
    use std::os::raw::{c_int, c_long, c_void};
    use std::time::Duration;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct Kevent {
        ident: usize,
        filter: i16,
        flags: u16,
        fflags: u32,
        data: isize,
        udata: *mut c_void,
    }

    #[repr(C)]
    struct Timespec {
        tv_sec: c_long,
        tv_nsec: c_long,
    }

    extern "C" {
        fn kqueue() -> c_int;
        fn kevent(
            kq: c_int,
            changelist: *const Kevent,
            nchanges: c_int,
            eventlist: *mut Kevent,
            nevents: c_int,
            timeout: *const Timespec,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    const EVFILT_READ: i16 = -1;
    const EV_ADD: u16 = 0x0001;
    const EV_DELETE: u16 = 0x0002;

    const MAX_EVENTS: usize = 64;

    pub struct Poller {
        kq: c_int,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let kq = unsafe { kqueue() };
            if kq < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { kq })
        }

        fn change(&self, fd: i32, flags: u16, token: u64) -> io::Result<()> {
            let change = Kevent {
                ident: fd as usize,
                filter: EVFILT_READ,
                flags,
                fflags: 0,
                data: 0,
                udata: token as usize as *mut c_void,
            };
            loop {
                let rc = unsafe {
                    kevent(self.kq, &change, 1, std::ptr::null_mut(), 0, std::ptr::null())
                };
                if rc < 0 {
                    let err = io::Error::last_os_error();
                    if err.kind() == io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(err);
                }
                return Ok(());
            }
        }

        pub fn register_read(&self, fd: i32, token: u64) -> io::Result<()> {
            self.change(fd, EV_ADD, token)
        }

        pub fn deregister(&self, fd: i32) -> io::Result<()> {
            self.change(fd, EV_DELETE, 0)
        }

        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            let ts = timeout.map(|t| Timespec {
                tv_sec: t.as_secs() as c_long,
                tv_nsec: t.subsec_nanos() as c_long,
            });
            let ts_ptr = ts.as_ref().map_or(std::ptr::null(), |t| t as *const Timespec);
            let mut events = [Kevent {
                ident: 0,
                filter: 0,
                flags: 0,
                fflags: 0,
                data: 0,
                udata: std::ptr::null_mut(),
            }; MAX_EVENTS];
            loop {
                let n = unsafe {
                    kevent(
                        self.kq,
                        std::ptr::null(),
                        0,
                        events.as_mut_ptr(),
                        MAX_EVENTS as c_int,
                        ts_ptr,
                    )
                };
                if n < 0 {
                    let err = io::Error::last_os_error();
                    if err.kind() == io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(err);
                }
                for ev in events.iter().take(n as usize) {
                    out.push(Event { token: ev.udata as usize as u64, readable: true });
                }
                return Ok(());
            }
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.kq);
            }
        }
    }
}

#[cfg(all(unix, not(any(target_os = "linux", target_os = "macos", target_os = "ios"))))]
mod imp {
    use super::{timeout_ms, Event};
    use std::io;
    use std::os::raw::c_int;
    use std::sync::Mutex;
    use std::time::Duration;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: c_int,
        events: i16,
        revents: i16,
    }

    type NFds = std::os::raw::c_uint;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NFds, timeout: c_int) -> c_int;
    }

    const POLLIN: i16 = 0x001;

    /// `poll(2)` fallback: the registered table is rebuilt into a
    /// pollfd array every wait. O(n) per call, fine for the connection
    /// counts this path will ever see on a non-Linux, non-mac unix.
    pub struct Poller {
        registered: Mutex<Vec<(c_int, u64)>>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { registered: Mutex::new(Vec::new()) })
        }

        pub fn register_read(&self, fd: i32, token: u64) -> io::Result<()> {
            self.registered.lock().unwrap().push((fd, token));
            Ok(())
        }

        pub fn deregister(&self, fd: i32) -> io::Result<()> {
            self.registered.lock().unwrap().retain(|(f, _)| *f != fd);
            Ok(())
        }

        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            let table: Vec<(c_int, u64)> = self.registered.lock().unwrap().clone();
            let mut fds: Vec<PollFd> = table
                .iter()
                .map(|(fd, _)| PollFd { fd: *fd, events: POLLIN, revents: 0 })
                .collect();
            loop {
                let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as NFds, timeout_ms(timeout)) };
                if n < 0 {
                    let err = io::Error::last_os_error();
                    if err.kind() == io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(err);
                }
                for (pfd, (_, token)) in fds.iter().zip(&table) {
                    if pfd.revents != 0 {
                        out.push(Event { token: *token, readable: true });
                    }
                }
                return Ok(());
            }
        }
    }
}

#[cfg(not(unix))]
mod imp {
    use super::Event;
    use std::io;
    use std::time::Duration;

    pub struct Poller;

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "readiness polling is unix-only; the serving front end cannot start here",
            ))
        }

        pub fn register_read(&self, _fd: i32, _token: u64) -> io::Result<()> {
            unreachable!("Poller::new never succeeds on non-unix")
        }

        pub fn deregister(&self, _fd: i32) -> io::Result<()> {
            unreachable!("Poller::new never succeeds on non-unix")
        }

        pub fn wait(&self, _out: &mut Vec<Event>, _timeout: Option<Duration>) -> io::Result<()> {
            unreachable!("Poller::new never succeeds on non-unix")
        }
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::time::Duration;

    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = listener.accept().unwrap();
        (a, b)
    }

    #[test]
    fn readable_event_carries_token() {
        let (mut a, b) = pair();
        let poller = Poller::new().unwrap();
        poller.register_read(raw_fd(&b), 7).unwrap();
        let mut events = Vec::new();
        // Nothing written yet: a short wait times out empty.
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.is_empty(), "{events:?}");
        a.write_all(b"x").unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);
    }

    #[test]
    fn deregistered_fd_stops_reporting() {
        let (mut a, b) = pair();
        let poller = Poller::new().unwrap();
        poller.register_read(raw_fd(&b), 1).unwrap();
        a.write_all(b"x").unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(events.len(), 1);
        poller.deregister(raw_fd(&b)).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.is_empty(), "{events:?}");
    }

    #[test]
    fn eof_reports_readable() {
        let (a, b) = pair();
        let poller = Poller::new().unwrap();
        poller.register_read(raw_fd(&b), 3).unwrap();
        drop(a);
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(events.len(), 1);
        assert!(events[0].readable);
        let mut buf = [0u8; 8];
        let mut r = &b;
        assert_eq!(r.read(&mut buf).unwrap(), 0, "read must observe the EOF");
    }

    #[test]
    fn fresh_socket_is_writable() {
        let (a, _b) = pair();
        assert!(wait_writable(raw_fd(&a), 1000).unwrap());
    }

    #[test]
    fn two_fds_distinct_tokens() {
        let (mut a1, b1) = pair();
        let (mut a2, b2) = pair();
        let poller = Poller::new().unwrap();
        poller.register_read(raw_fd(&b1), 10).unwrap();
        poller.register_read(raw_fd(&b2), 20).unwrap();
        a1.write_all(b"x").unwrap();
        a2.write_all(b"y").unwrap();
        let mut tokens = Vec::new();
        let mut events = Vec::new();
        // Events may arrive across waits; collect until both are seen.
        for _ in 0..10 {
            poller.wait(&mut events, Some(Duration::from_millis(200))).unwrap();
            tokens.extend(events.iter().map(|e| e.token));
            tokens.sort_unstable();
            tokens.dedup();
            if tokens == [10, 20] {
                return;
            }
        }
        panic!("never saw both tokens: {tokens:?}");
    }
}
