//! Minimal JSON parser/writer.
//!
//! Used by the coordinator's TCP protocol (JSON-lines requests/responses),
//! the config system, and the bench harness's machine-readable output.
//! Implements RFC 8259 minus `\u` surrogate-pair edge handling beyond the
//! BMP (sufficient for this crate's ASCII-dominated payloads).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is canonical
/// and diffs in EXPERIMENTS.md stay stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers from f32 values.
    pub fn nums_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Extract an array of f32 values.
    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|n| n as f32))
            .collect()
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("non-utf8 \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.pos;
                    let b0 = self.bytes[start];
                    let len = match b0 {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"n":-3,"obj":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("quote\" slash\\ nl\n tab\t".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn f32_vec_helpers() {
        let j = Json::nums_f32(&[1.0, 2.5, -3.0]);
        assert_eq!(j.to_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }
}
