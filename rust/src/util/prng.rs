//! Deterministic pseudo-random number generation.
//!
//! A splitmix64-seeded xoshiro256++ generator: fast, high-quality, and —
//! crucially for the experiment harness — fully deterministic across runs
//! and platforms, so every table/figure regeneration is reproducible from a
//! seed recorded in `EXPERIMENTS.md`.

/// xoshiro256++ PRNG with a splitmix64 seeding routine.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, n)` (Lemire rejection; unbiased).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal sample (Box–Muller; one value per call, the twin is
    /// discarded for simplicity — this is not a hot path).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Standard normal sample as f32.
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// A vector of iid standard-normal f32 values scaled by `scale`.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.gaussian_f32() * scale).collect()
    }

    /// Split off an independent generator (for per-worker streams).
    pub fn split(&mut self) -> Prng {
        Prng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Prng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            let x = r.below(10);
            counts[x] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "bucket count {c} out of range");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Prng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Prng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffle left identity (astronomically unlikely)");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Prng::new(9);
        let mut a = root.split();
        let mut b = root.split();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
