//! Tiny declarative CLI argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands. The binary (`rust/src/main.rs`) and examples use it.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First positional argument, conventionally the subcommand.
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable); skips argv[0].
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().skip(1).peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    out.options
                        .insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let val = it.next().unwrap();
                    out.options.insert(rest.to_string(), val);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process's real argv.
    pub fn parse() -> Args {
        Args::parse_from(std::env::args())
    }

    /// String option with default.
    pub fn get<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.options.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    /// Required string option.
    pub fn require(&self, key: &str) -> anyhow::Result<&str> {
        self.options
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing required option --{key}"))
    }

    /// usize option with default.
    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.options
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// f64 option with default.
    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.options
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Whether `--flag` was given.
    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse_from(
            std::iter::once("prog".to_string()).chain(s.split_whitespace().map(String::from)),
        )
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = args("serve model.hlo extra");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.positional, vec!["model.hlo", "extra"]);
    }

    #[test]
    fn options_space_and_equals() {
        let a = args("run --banks 16 --sparsity=0.9");
        assert_eq!(a.usize("banks", 0), 16);
        assert_eq!(a.f64("sparsity", 0.0), 0.9);
    }

    #[test]
    fn flags() {
        let a = args("run --verbose --banks 8");
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
        assert_eq!(a.usize("banks", 0), 8);
    }

    #[test]
    fn trailing_flag_not_eating_nothing() {
        let a = args("run --json");
        assert!(a.has("json"));
    }

    #[test]
    fn defaults_and_require() {
        let a = args("run");
        assert_eq!(a.get("mode", "fast"), "fast");
        assert!(a.require("mode").is_err());
        let b = args("run --mode slow");
        assert_eq!(b.require("mode").unwrap(), "slow");
    }
}
