//! From-scratch utility substrates.
//!
//! The build environment is fully offline and the cargo registry cache only
//! contains the `xla` crate's dependency closure, so the conveniences a
//! production crate would normally pull from crates.io (rand, serde_json,
//! clap, a thread pool, criterion) are implemented here in-tree. Each
//! submodule is self-contained and unit-tested.

pub mod cli;
pub mod crc32;
pub mod f16;
pub mod fsio;
pub mod histogram;
pub mod json;
pub mod poll;
pub mod prng;
pub mod stats;
pub mod threadpool;

pub use cli::Args;
pub use crc32::{crc32, Crc32};
pub use f16::{f16_bits_to_f32, f16_round, f32_to_f16_bits};
pub use histogram::Histogram;
pub use json::Json;
pub use prng::Prng;
pub use stats::Summary;
pub use threadpool::ThreadPool;
