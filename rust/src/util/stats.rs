//! Sample statistics + wall-clock timing for the bench harness.

use std::time::Instant;

/// Summary statistics over a sample of f64 measurements.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary. Panics on an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// The q-th percentile (q in [0,1]) of the magnitude threshold used by the
/// pruning algorithms: returns the value such that `q` fraction of the
/// entries are strictly below it (matching `numpy.percentile`-style linear
/// interpolation over sorted magnitudes).
///
/// Perf (EXPERIMENTS.md §Perf): uses `select_nth_unstable` to find the two
/// adjacent order statistics in O(n) instead of sorting — the pruning path
/// was ~18% of wall-clock in the experiment sweeps before this.
pub fn percentile_f32(values: &[f32], q: f64) -> f32 {
    assert!(!values.is_empty());
    let n = values.len();
    let pos = q.clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let frac = pos - lo as f64;
    let mut buf: Vec<f32> = values.to_vec();
    let (_, &mut lo_val, right) =
        buf.select_nth_unstable_by(lo, |a, b| a.partial_cmp(b).unwrap());
    if frac == 0.0 || right.is_empty() {
        return lo_val;
    }
    let hi_val = right
        .iter()
        .copied()
        .fold(f32::INFINITY, f32::min);
    (lo_val as f64 * (1.0 - frac) + hi_val as f64 * frac) as f32
}

/// Time a closure `reps` times after `warmup` runs; returns per-rep seconds.
pub fn time_reps<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..reps)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn percentile_f32_matches_sorted_fraction() {
        let v: Vec<f32> = (0..101).map(|i| i as f32).collect();
        let p90 = percentile_f32(&v, 0.9);
        assert!((p90 - 90.0).abs() < 1e-6);
    }

    #[test]
    fn std_of_constant_is_zero() {
        let s = Summary::of(&[2.0; 10]);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn time_reps_counts() {
        let mut calls = 0;
        let t = time_reps(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(t.len(), 5);
        assert!(t.iter().all(|&x| x >= 0.0));
    }
}
