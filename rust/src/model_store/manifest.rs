//! Crash-recoverable store manifest: the on-disk record of *what is
//! deployed* (`serve --store-dir DIR`).
//!
//! Every load/swap/unload/rollback rewrites
//! `<store-dir>/store-manifest.json` atomically and durably (via
//! [`crate::util::fsio::write_atomic`]), so a crashed or restarted server
//! can replay it and resume with the same registry: the same model names,
//! the same artifact paths, the same deployment versions — and therefore
//! bit-identical logits, since artifacts are themselves CRC-checked and
//! canonical.
//!
//! The file is one integrity-prefixed line followed by a JSON payload:
//!
//! ```text
//! gsm-manifest-v1 crc32=0a1b2c3d
//! {"default":"default","max_models":4,"models":{...}}
//! ```
//!
//! The CRC-32 covers the JSON bytes, so a torn or bit-rotted manifest is
//! rejected as corrupt rather than silently replayed into a wrong
//! registry. Recovery is deliberately *graceful*: a model whose artifact
//! is missing or corrupt is skipped with a recorded reason (the server
//! still starts and serves the slots that did restore), and only the
//! live generation of each slot is persisted — rollback history does not
//! survive a restart.

use super::artifact::ModelArtifact;
use super::store::{ModelSlot, ModelStore, SlotConfig};
use crate::util::crc32::crc32;
use crate::util::fsio;
use crate::util::json::Json;
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

const PREFIX: &str = "gsm-manifest-v1 crc32=";

/// File name of the manifest inside a `--store-dir`.
pub const MANIFEST_FILE: &str = "store-manifest.json";

/// One persisted slot: where its live generation came from and how it
/// was deployed.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestEntry {
    /// Artifact path (or an `inline-…` pseudo-source that cannot be
    /// restored and is skipped on replay).
    pub path: String,
    /// Deployment version the slot resumes at.
    pub version: u64,
    /// Plan precision name (`"f32"`/`"f16"`) — informational; the
    /// artifact itself is authoritative on restore.
    pub precision: Option<String>,
    pub pinned: bool,
}

/// The full persisted registry state.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// The default (pinned) slot name.
    pub default: String,
    /// Store capacity bound at persist time (0 = unbounded).
    pub max_models: usize,
    pub models: BTreeMap<String, ManifestEntry>,
}

impl Manifest {
    /// Snapshot the live registry of `store`.
    pub fn snapshot(store: &ModelStore, default: &str) -> Manifest {
        let mut models = BTreeMap::new();
        for name in store.names() {
            let Some(slot) = store.get(&name) else {
                continue; // concurrently unloaded between names() and get()
            };
            let vm = slot.current();
            models.insert(
                name.clone(),
                ManifestEntry {
                    path: vm.source.clone(),
                    version: vm.version,
                    precision: vm.precision().map(|p| p.name().to_string()),
                    pinned: name == store.pinned_name(),
                },
            );
        }
        Manifest {
            default: default.to_string(),
            max_models: store.max_models(),
            models,
        }
    }

    /// Serialize: integrity line + JSON payload.
    pub fn to_bytes(&self) -> Vec<u8> {
        let models = Json::Obj(
            self.models
                .iter()
                .map(|(name, e)| {
                    let mut pairs = vec![
                        ("path", Json::from(e.path.as_str())),
                        ("version", Json::Num(e.version as f64)),
                        ("pinned", Json::Bool(e.pinned)),
                    ];
                    if let Some(p) = &e.precision {
                        pairs.push(("precision", Json::from(p.as_str())));
                    }
                    (name.clone(), Json::obj(pairs))
                })
                .collect(),
        );
        let payload = Json::obj(vec![
            ("default", Json::from(self.default.as_str())),
            ("max_models", Json::Num(self.max_models as f64)),
            ("models", models),
        ])
        .to_string();
        let mut out = format!("{PREFIX}{:08x}\n", crc32(payload.as_bytes())).into_bytes();
        out.extend_from_slice(payload.as_bytes());
        out
    }

    /// Decode and integrity-check manifest bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<Manifest> {
        let text = std::str::from_utf8(bytes).context("manifest is not UTF-8")?;
        let (first, payload) = text
            .split_once('\n')
            .context("manifest is missing its integrity line")?;
        let stored = first
            .strip_prefix(PREFIX)
            .with_context(|| format!("manifest has an unrecognized header line {first:?}"))?;
        let stored = u32::from_str_radix(stored.trim(), 16)
            .context("manifest integrity line has a malformed crc32")?;
        let computed = crc32(payload.as_bytes());
        ensure!(
            stored == computed,
            "manifest checksum mismatch (stored {stored:08x}, computed {computed:08x}) — corrupt \
             or torn manifest"
        );
        let json = Json::parse(payload).context("manifest payload is not valid JSON")?;
        let default = json
            .get("default")
            .and_then(Json::as_str)
            .context("manifest payload is missing \"default\"")?
            .to_string();
        let max_models = json
            .get("max_models")
            .and_then(Json::as_usize)
            .context("manifest payload is missing \"max_models\"")?;
        let models_json = json
            .get("models")
            .context("manifest payload is missing \"models\"")?;
        let Json::Obj(map) = models_json else {
            anyhow::bail!("manifest \"models\" must be an object");
        };
        let mut models = BTreeMap::new();
        for (name, entry) in map {
            let path = entry
                .get("path")
                .and_then(Json::as_str)
                .with_context(|| format!("manifest model {name:?} is missing \"path\""))?
                .to_string();
            let version = entry
                .get("version")
                .and_then(Json::as_f64)
                .with_context(|| format!("manifest model {name:?} is missing \"version\""))?
                as u64;
            ensure!(
                version >= 1,
                "manifest model {name:?} has invalid version {version}"
            );
            let precision = entry
                .get("precision")
                .and_then(Json::as_str)
                .map(|s| s.to_string());
            let pinned = entry.get("pinned").and_then(Json::as_bool).unwrap_or(false);
            models.insert(
                name.clone(),
                ManifestEntry {
                    path,
                    version,
                    precision,
                    pinned,
                },
            );
        }
        Ok(Manifest {
            default,
            max_models,
            models,
        })
    }

    /// Read the manifest from a store directory. `Ok(None)` means no
    /// manifest exists yet (a fresh directory); corruption is an error.
    pub fn load_dir(dir: &Path) -> Result<Option<Manifest>> {
        let path = dir.join(MANIFEST_FILE);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(e).with_context(|| format!("read store manifest {}", path.display()))
            }
        };
        Manifest::from_bytes(&bytes)
            .with_context(|| format!("load store manifest {}", path.display()))
    }
}

/// Outcome of replaying a manifest: the slots that restored, and the
/// ones that were skipped (missing/corrupt/non-file artifacts) with the
/// reason the operator will see in the startup log.
pub struct RestoreReport {
    pub restored: Vec<(String, Arc<ModelSlot>)>,
    pub skipped: Vec<(String, String)>,
}

/// Rebuild slots from a manifest. Each entry's artifact is re-loaded
/// (CRC-validated) and instantiated; the slot resumes at its persisted
/// deployment version via [`SlotConfig::start_version`]. Failures are
/// collected, never fatal — serving degrades to the slots that restored.
pub fn restore(manifest: &Manifest, threads: usize, base: SlotConfig) -> RestoreReport {
    let mut report = RestoreReport {
        restored: Vec::new(),
        skipped: Vec::new(),
    };
    for (name, entry) in &manifest.models {
        let slot = ModelArtifact::load(&entry.path).and_then(|artifact| {
            let model = artifact
                .instantiate(threads)
                .with_context(|| format!("instantiate artifact {}", entry.path))?;
            let cfg = SlotConfig {
                start_version: entry.version,
                ..base
            };
            Ok(Arc::new(ModelSlot::with_config(
                model,
                &entry.path,
                threads,
                cfg,
            )))
        });
        match slot {
            Ok(slot) => report.restored.push((name.clone(), slot)),
            Err(e) => report.skipped.push((name.clone(), format!("{e:#}"))),
        }
    }
    report
}

/// Serialized persist handle the serving path holds: every deploy
/// operation calls [`ManifestWriter::persist`], which snapshots the
/// registry under a write mutex and atomically/durably replaces the
/// manifest file.
pub struct ManifestWriter {
    path: PathBuf,
    store: Arc<ModelStore>,
    default: String,
    write: Mutex<()>,
}

impl ManifestWriter {
    pub fn new(dir: &Path, store: Arc<ModelStore>, default: &str) -> ManifestWriter {
        ManifestWriter {
            path: dir.join(MANIFEST_FILE),
            store,
            default: default.to_string(),
            write: Mutex::new(()),
        }
    }

    /// Snapshot the registry and rewrite the manifest. Serialized: two
    /// concurrent deploys cannot interleave their snapshot/write pairs
    /// into an out-of-order manifest.
    pub fn persist(&self) -> Result<()> {
        let _guard = self.write.lock().unwrap();
        let manifest = Manifest::snapshot(&self.store, &self.default);
        fsio::write_atomic(&self.path, &manifest.to_bytes())
            .with_context(|| format!("persist store manifest {}", self.path.display()))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::pattern::Pattern;
    use crate::testing::model::{build_random_artifact, build_random_model, ModelSpec};

    fn spec(seed: u64) -> ModelSpec {
        ModelSpec {
            inputs: 8,
            hidden: 32,
            outputs: 16,
            max_batch: 4,
            pattern: Pattern::Gs { b: 8, k: 8 },
            sparsity: 0.75,
            threads: 1,
            seed,
            ..ModelSpec::default()
        }
    }

    fn scratch_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("gs-manifest-{}-{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_manifest() -> Manifest {
        let mut models = BTreeMap::new();
        models.insert(
            "default".to_string(),
            ManifestEntry {
                path: "/tmp/a.gsm".to_string(),
                version: 3,
                precision: Some("f32".to_string()),
                pinned: true,
            },
        );
        models.insert(
            "beta".to_string(),
            ManifestEntry {
                path: "/tmp/b.gsm".to_string(),
                version: 1,
                precision: Some("f16".to_string()),
                pinned: false,
            },
        );
        Manifest {
            default: "default".to_string(),
            max_models: 4,
            models,
        }
    }

    #[test]
    fn bytes_roundtrip_exactly() {
        let m = sample_manifest();
        let bytes = m.to_bytes();
        let back = Manifest::from_bytes(&bytes).unwrap();
        assert_eq!(back, m);
        // Canonical: re-encoding is byte-identical.
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn rejects_corruption_via_checksum() {
        let mut bytes = sample_manifest().to_bytes();
        let n = bytes.len();
        bytes[n - 3] ^= 0x20; // flip a payload character
        let err = Manifest::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    }

    #[test]
    fn rejects_garbage_headers() {
        assert!(Manifest::from_bytes(b"").is_err());
        assert!(Manifest::from_bytes(b"not a manifest\n{}").is_err());
        assert!(Manifest::from_bytes(b"gsm-manifest-v1 crc32=zzzz\n{}").is_err());
    }

    #[test]
    fn missing_file_is_none_but_corrupt_is_an_error() {
        let dir = scratch_dir("load");
        assert!(Manifest::load_dir(&dir).unwrap().is_none());
        std::fs::write(dir.join(MANIFEST_FILE), b"gsm-manifest-v1 crc32=00000000\n{}").unwrap();
        assert!(Manifest::load_dir(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_persist_load_roundtrip() {
        let dir = scratch_dir("persist");
        let store = Arc::new(ModelStore::with_capacity(4, "default"));
        let m = build_random_model(&spec(1)).unwrap().model;
        store
            .register("default", Arc::new(ModelSlot::new(m, "/tmp/d.gsm", 1)))
            .unwrap();
        let writer = ManifestWriter::new(&dir, Arc::clone(&store), "default");
        writer.persist().unwrap();
        let loaded = Manifest::load_dir(&dir).unwrap().unwrap();
        assert_eq!(loaded.default, "default");
        assert_eq!(loaded.max_models, 4);
        let entry = &loaded.models["default"];
        assert_eq!(entry.path, "/tmp/d.gsm");
        assert_eq!(entry.version, 1);
        assert!(entry.pinned);
        // A swap bumps the persisted version on the next persist.
        let m2 = build_random_model(&spec(2)).unwrap().model;
        store.get("default").unwrap().swap(m2, "/tmp/d2.gsm").unwrap();
        writer.persist().unwrap();
        let loaded = Manifest::load_dir(&dir).unwrap().unwrap();
        assert_eq!(loaded.models["default"].version, 2);
        assert_eq!(loaded.models["default"].path, "/tmp/d2.gsm");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restore_replays_versions_and_skips_broken_entries() {
        let dir = scratch_dir("restore");
        let good = dir.join("good.gsm");
        build_random_artifact(&spec(5)).unwrap().0.save(&good).unwrap();

        let mut models = BTreeMap::new();
        models.insert(
            "good".to_string(),
            ManifestEntry {
                path: good.display().to_string(),
                version: 6,
                precision: Some("f32".to_string()),
                pinned: true,
            },
        );
        models.insert(
            "gone".to_string(),
            ManifestEntry {
                path: dir.join("missing.gsm").display().to_string(),
                version: 2,
                precision: None,
                pinned: false,
            },
        );
        models.insert(
            "inline".to_string(),
            ManifestEntry {
                path: "inline-random".to_string(),
                version: 1,
                precision: None,
                pinned: false,
            },
        );
        let manifest = Manifest {
            default: "good".to_string(),
            max_models: 0,
            models,
        };

        let report = restore(&manifest, 1, SlotConfig::default());
        assert_eq!(report.restored.len(), 1);
        let (name, slot) = &report.restored[0];
        assert_eq!(name, "good");
        assert_eq!(slot.version(), 6, "slot resumes at its persisted version");
        assert_eq!(report.skipped.len(), 2);
        for (name, reason) in &report.skipped {
            assert!(name == "gone" || name == "inline");
            assert!(!reason.is_empty());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
