//! Versioned model artifact store: serialize pruned models as
//! self-describing binary artifacts and hot-swap them under live traffic.
//!
//! The paper's compact GS format (§V) and f16 storage resolution (§X)
//! exist so pruned models can be *shipped*; this module is the shipping
//! lane (cf. SparseDNN's deployable-artifact runtime):
//!
//! * [`artifact`] — the `.gsm` on-disk format: header + tagged per-layer
//!   sections (dense input layer, GS `value`/`index`/`indptr`/`rowmap`,
//!   biases, JSON metadata) with a length field and CRC-32 trailer. A
//!   validating reader rebuilds [`ModelArtifact`] and instantiates
//!   [`crate::coordinator::SparseModel`] — bit-identical logits to the
//!   model the artifact was exported from, at f32 and f16 plan
//!   precision, at any thread count.
//! * [`store`] — [`ModelSlot`], the versioned `Arc`-swappable slot the
//!   TCP server executes through (`{"op":"swap","path":...}` deploys a
//!   new pruning with zero downtime), and [`ModelStore`], the named
//!   registry behind multi-model routed serving: touch-on-infer LRU
//!   recency, a capacity bound with graceful eviction of cold models,
//!   and a pinned default slot eviction never removes. Slots also carry
//!   the deployment-safety machinery: bounded version retention with
//!   rollback, canary swaps with auto-rollback, and a quarantine
//!   circuit breaker with half-open probing.
//! * [`manifest`] — the crash-recoverable store manifest behind
//!   `serve --store-dir`: a CRC-checked JSON record of the deployed
//!   registry, rewritten atomically and durably on every
//!   load/swap/unload/rollback and replayed on startup so a restarted
//!   server resumes the exact pre-crash registry (missing or corrupt
//!   artifacts degrade gracefully to skipped slots).

pub mod artifact;
pub mod manifest;
pub mod store;

pub use artifact::ModelArtifact;
pub use manifest::{Manifest, ManifestWriter};
pub use store::{Admission, ModelSlot, ModelStore, SlotConfig, SlotEvent, VersionedModel};
