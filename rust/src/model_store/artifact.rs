//! The `.gsm` model artifact: a self-describing binary serialization of
//! one deployed sparse model (paper §V compact format + §X storage
//! resolution, packaged for shipping).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! [ 0.. 4)  magic  b"GSM1"
//! [ 4.. 8)  u32    format version (= 1)
//! [ 8..16)  u64    total file length in bytes (truncation check)
//! [16..20)  u32    plan precision (0 = f32, 1 = f16)
//! [20..24)  u32    inputs
//! [24..28)  u32    max_batch
//! [28..32)  u32    GS B
//! [32..36)  u32    GS k
//! [36..40)  u32    GS rows   (= outputs)
//! [40..44)  u32    GS cols   (= hidden)
//! [44..48)  u32    section count
//! [48.. )   sections: { u32 tag; u64 byte length; payload }
//! [-4.. )   u32    CRC-32 (IEEE) of every preceding byte
//! ```
//!
//! Sections carry the per-layer tensors: dense input layer (`W1`, `B1`),
//! the GS-compressed projection (`value`/`index`/`indptr` and, for
//! scatter patterns, `rowmap`), the output bias (`B2`), and a free-form
//! JSON metadata blob. Unknown tags are skipped (forward compatibility
//! within a format version); missing mandatory tags, duplicate tags,
//! length mismatches, bad magic, unsupported versions, truncation, and
//! checksum failures are all **errors, not panics**.
//!
//! Weight values are stored as raw f32 bit patterns regardless of the
//! declared plan precision: `GsExecPlan` quantizes at pack time, so a
//! reloaded artifact rebuilds the exact same plan — `export → load →
//! infer_batch` is bit-identical to the originating in-memory model at
//! both precisions (and at any thread count, since every kernel is
//! bit-identical serial vs parallel).
//!
//! The metadata blob optionally pins the dispatch kernel via a
//! `"kernel_variant"` key (a [`KernelVariant::name`] label, written by
//! `export` — see [`ModelArtifact::set_kernel_variant`]). The reader is
//! version-tolerant in both directions: artifacts without the key (or
//! with a label this build doesn't know, or one that doesn't fit the
//! plan's geometry) instantiate cleanly and fall back to geometry
//! classification, because every kernel variant is bit-identical — the
//! pin is a performance hint, never a correctness requirement.

use crate::coordinator::SparseModel;
use crate::kernels::dispatch::KernelVariant;
use crate::kernels::exec::PlanPrecision;
use crate::sparse::format::GsFormat;
use crate::util::crc32::{crc32, Crc32};
use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::io::Read;
use std::path::Path;

const MAGIC: &[u8; 4] = b"GSM1";
const FORMAT_VERSION: u32 = 1;
const HEADER_LEN: usize = 48;
/// Fixed read granularity of the streaming loader: payloads are pulled
/// through one bounded scratch buffer instead of buffering the file.
const READ_CHUNK: usize = 64 * 1024;

const TAG_W1: u32 = 1;
const TAG_B1: u32 = 2;
const TAG_GS_VALUE: u32 = 3;
const TAG_GS_INDEX: u32 = 4;
const TAG_GS_INDPTR: u32 = 5;
const TAG_GS_ROWMAP: u32 = 6;
const TAG_B2: u32 = 7;
const TAG_META: u32 = 8;

/// One deployable sparse model, decoupled from any execution plan: the
/// raw tensors plus the precision the plan should be packed at.
#[derive(Clone, Debug)]
pub struct ModelArtifact {
    pub inputs: usize,
    pub max_batch: usize,
    /// Packed-plan value resolution to instantiate with.
    pub precision: PlanPrecision,
    /// `[inputs, hidden]` row-major dense input layer.
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    /// GS compression of the `[outputs, hidden]` projection.
    pub gs: GsFormat,
    pub b2: Vec<f32>,
    /// Free-form metadata (name, seed, provenance — not interpreted).
    pub meta: Json,
}

impl ModelArtifact {
    pub fn hidden(&self) -> usize {
        self.gs.cols
    }

    pub fn outputs(&self) -> usize {
        self.gs.rows
    }

    /// Assemble an artifact from raw parts, validating shapes.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        w1: Vec<f32>,
        b1: Vec<f32>,
        gs: GsFormat,
        b2: Vec<f32>,
        inputs: usize,
        max_batch: usize,
        precision: PlanPrecision,
        meta: Json,
    ) -> Result<ModelArtifact> {
        gs.validate().context("artifact GS format invalid")?;
        let (hidden, outputs) = (gs.cols, gs.rows);
        ensure!(max_batch > 0, "max_batch must be positive");
        ensure!(
            w1.len() == inputs * hidden,
            "w1 length {} != inputs*hidden {}",
            w1.len(),
            inputs * hidden
        );
        ensure!(b1.len() == hidden, "b1 length {} != hidden {hidden}", b1.len());
        ensure!(b2.len() == outputs, "b2 length {} != outputs {outputs}", b2.len());
        if precision == PlanPrecision::F16 {
            ensure!(
                hidden <= u16::MAX as usize + 1,
                "f16 artifacts index columns with u16: hidden {hidden} > {}",
                u16::MAX as usize + 1
            );
        }
        Ok(ModelArtifact {
            inputs,
            max_batch,
            precision,
            w1,
            b1,
            gs,
            b2,
            meta,
        })
    }

    /// Build the native serving model this artifact describes. `threads`
    /// follows [`SparseModel::native`] semantics (0 = auto-detect). A
    /// `"kernel_variant"` pin in the metadata is applied when it fits
    /// the rebuilt plan's geometry; otherwise the plan serves on its
    /// pack-time classification (version tolerance — see the module
    /// docs).
    pub fn instantiate(&self, threads: usize) -> Result<SparseModel> {
        SparseModel::native_pinned(
            self.w1.clone(),
            self.b1.clone(),
            &self.gs,
            self.b2.clone(),
            self.inputs,
            self.max_batch,
            threads,
            self.precision,
            self.kernel_variant(),
        )
    }

    /// The dispatch-kernel pin carried in the metadata blob, if any.
    /// Lenient by design: a missing key, non-string value, or a label
    /// from a newer build all read as `None` (classification fallback),
    /// never an error.
    pub fn kernel_variant(&self) -> Option<KernelVariant> {
        self.meta
            .get("kernel_variant")
            .and_then(Json::as_str)
            .and_then(|s| KernelVariant::parse(s).ok())
    }

    /// Pin the dispatch kernel in the metadata blob (`export --tune`
    /// writes the tuned winner here so a served artifact inherits it
    /// across export → load → swap → rollback).
    pub fn set_kernel_variant(&mut self, v: KernelVariant) {
        let entry = (
            "kernel_variant".to_string(),
            Json::Str(v.name().to_string()),
        );
        match &mut self.meta {
            Json::Obj(map) => {
                map.insert(entry.0, entry.1);
            }
            _ => self.meta = Json::Obj([entry].into_iter().collect()),
        }
    }

    /// One-line human summary (CLI banners, logs).
    pub fn describe(&self) -> String {
        format!(
            "{}→{}→{} GS({},{}){} {} plan, {} nnz, batch {}",
            self.inputs,
            self.hidden(),
            self.outputs(),
            self.gs.b,
            self.gs.k,
            if self.gs.rowmap.is_some() { " scatter" } else { "" },
            self.precision.name(),
            self.gs.nnz(),
            self.max_batch
        )
    }

    // -- encoding -----------------------------------------------------------

    /// Serialize to the `.gsm` byte format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut sections: Vec<(u32, Vec<u8>)> = vec![
            (TAG_W1, f32_bytes(&self.w1)),
            (TAG_B1, f32_bytes(&self.b1)),
            (TAG_GS_VALUE, f32_bytes(&self.gs.value)),
            (TAG_GS_INDEX, u32_bytes(&self.gs.index)),
            (TAG_GS_INDPTR, u32_bytes(&self.gs.indptr)),
        ];
        if let Some(map) = &self.gs.rowmap {
            sections.push((TAG_GS_ROWMAP, u32_bytes(map)));
        }
        sections.push((TAG_B2, f32_bytes(&self.b2)));
        if self.meta != Json::Null {
            sections.push((TAG_META, self.meta.to_string().into_bytes()));
        }

        let body_len: usize = sections.iter().map(|(_, p)| 12 + p.len()).sum();
        let total = HEADER_LEN + body_len + 4;
        let mut out = Vec::with_capacity(total);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(total as u64).to_le_bytes());
        let precision_code: u32 = match self.precision {
            PlanPrecision::F32 => 0,
            PlanPrecision::F16 => 1,
        };
        for v in [
            precision_code,
            self.inputs as u32,
            self.max_batch as u32,
            self.gs.b as u32,
            self.gs.k as u32,
            self.gs.rows as u32,
            self.gs.cols as u32,
            sections.len() as u32,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for (tag, payload) in &sections {
            out.extend_from_slice(&tag.to_le_bytes());
            out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            out.extend_from_slice(payload);
        }
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        debug_assert_eq!(out.len(), total);
        out
    }

    /// Decode and validate a `.gsm` byte buffer.
    pub fn from_bytes(bytes: &[u8]) -> Result<ModelArtifact> {
        ensure!(
            bytes.len() >= HEADER_LEN + 4,
            "truncated artifact: {} bytes is smaller than the {}-byte header",
            bytes.len(),
            HEADER_LEN + 4
        );
        ensure!(
            &bytes[0..4] == MAGIC,
            "not a .gsm model artifact (bad magic {:02x?})",
            &bytes[0..4]
        );
        let version = read_u32(bytes, 4);
        ensure!(
            version == FORMAT_VERSION,
            "unsupported .gsm format version {version} (this build reads version {FORMAT_VERSION})"
        );
        let declared = read_u64(bytes, 8) as usize;
        ensure!(
            declared == bytes.len(),
            "truncated or padded artifact: header declares {declared} bytes, file has {}",
            bytes.len()
        );
        let stored_crc = read_u32(bytes, bytes.len() - 4);
        let actual_crc = crc32(&bytes[..bytes.len() - 4]);
        ensure!(
            stored_crc == actual_crc,
            "artifact checksum mismatch (stored {stored_crc:08x}, computed {actual_crc:08x}) — corrupt file"
        );

        let precision = match read_u32(bytes, 16) {
            0 => PlanPrecision::F32,
            1 => PlanPrecision::F16,
            other => bail!("unknown plan precision code {other} (0 = f32, 1 = f16)"),
        };
        let inputs = read_u32(bytes, 20) as usize;
        let max_batch = read_u32(bytes, 24) as usize;
        let b = read_u32(bytes, 28) as usize;
        let k = read_u32(bytes, 32) as usize;
        let rows = read_u32(bytes, 36) as usize;
        let cols = read_u32(bytes, 40) as usize;
        let section_count = read_u32(bytes, 44) as usize;
        ensure!(b > 0 && k > 0 && b % k == 0, "bad GS geometry B={b} k={k}");

        // Walk the tagged sections (payload bounds are inside the
        // CRC-covered region, but lengths are still checked — a reader
        // must never index past the buffer, and header-declared counts
        // must never drive allocations beyond what the file can hold).
        let body = &bytes[HEADER_LEN..bytes.len() - 4];
        ensure!(
            section_count <= body.len() / 12,
            "section count {section_count} cannot fit in a {}-byte body",
            body.len()
        );
        // 8 tags are defined; 64 leaves generous room for future minor
        // additions while keeping the per-section duplicate scan (and any
        // crafted-file parse work) trivially bounded.
        ensure!(
            section_count <= 64,
            "implausible section count {section_count} (max 64)"
        );
        let mut pos = 0usize;
        let mut found: Vec<(u32, &[u8])> = Vec::with_capacity(section_count);
        for s in 0..section_count {
            ensure!(
                pos + 12 <= body.len(),
                "section {s} header runs past the end of the artifact"
            );
            let tag = read_u32(body, pos);
            let len = read_u64(body, pos + 4) as usize;
            pos += 12;
            ensure!(
                len <= body.len() - pos,
                "section {s} (tag {tag}) payload of {len} bytes runs past the end of the artifact"
            );
            ensure!(
                !found.iter().any(|&(t, _)| t == tag),
                "duplicate section tag {tag}"
            );
            found.push((tag, &body[pos..pos + len]));
            pos += len;
        }
        ensure!(
            pos == body.len(),
            "{} trailing bytes after the last section",
            body.len() - pos
        );

        let w1 = f32_vec(section(&found, TAG_W1, "W1")?, inputs * cols, "W1")?;
        let b1 = f32_vec(section(&found, TAG_B1, "B1")?, cols, "B1")?;
        let value_raw = section(&found, TAG_GS_VALUE, "GS value")?;
        ensure!(
            value_raw.len() % (4 * b) == 0,
            "GS value section ({} bytes) is not a whole number of {b}-wide groups",
            value_raw.len()
        );
        let ngroups = value_raw.len() / (4 * b);
        let value = f32_vec(value_raw, ngroups * b, "GS value")?;
        let index = u32_vec(
            section(&found, TAG_GS_INDEX, "GS index")?,
            ngroups * b,
            "GS index",
        )?;
        let indptr_raw = section(&found, TAG_GS_INDPTR, "GS indptr")?;
        ensure!(
            indptr_raw.len() >= 4 && indptr_raw.len() % 4 == 0,
            "GS indptr section has invalid length {}",
            indptr_raw.len()
        );
        let indptr = u32_vec(indptr_raw, indptr_raw.len() / 4, "GS indptr")?;
        let nbands = indptr.len() - 1;
        let rowmap = match found.iter().find(|&&(t, _)| t == TAG_GS_ROWMAP) {
            Some(&(_, p)) => Some(u32_vec(p, nbands * (b / k), "GS rowmap")?),
            None => None,
        };
        let b2 = f32_vec(section(&found, TAG_B2, "B2")?, rows, "B2")?;
        let meta = match found.iter().find(|&&(t, _)| t == TAG_META) {
            Some(&(_, p)) => {
                let s = std::str::from_utf8(p).context("metadata section is not UTF-8")?;
                Json::parse(s).context("metadata section is not valid JSON")?
            }
            None => Json::Null,
        };

        let gs = GsFormat {
            b,
            k,
            rows,
            cols,
            value,
            index,
            indptr,
            rowmap,
        };
        ModelArtifact::from_parts(w1, b1, gs, b2, inputs, max_batch, precision, meta)
            .context("decoded artifact failed validation")
    }

    // -- file I/O -----------------------------------------------------------

    /// Write the artifact to `path` — atomically *and durably*: the temp
    /// file is fsynced before the rename and the parent directory after
    /// it (a crash at any point leaves either the old complete artifact
    /// or the new one, and the rename is never more durable than the
    /// bytes it publishes), and a stale `.tmp` from a previously crashed
    /// writer is removed first.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let path = path.as_ref();
        let bytes = self.to_bytes();
        // Fault-injection hook (no-op unless the `fault-inject` feature
        // is on): simulate the writer process dying mid-write — a prefix
        // of the bytes lands in the temp file, the rename never happens,
        // and the previous artifact (if any) must stay intact.
        if let Some(cut) = crate::coordinator::faults::torn_artifact_write(bytes.len()) {
            let tmp = crate::util::fsio::tmp_path(path);
            let _ = std::fs::write(&tmp, &bytes[..cut]);
            bail!(
                "injected fault: artifact writer crashed after {cut} of {} bytes",
                bytes.len()
            );
        }
        crate::util::fsio::write_atomic(path, &bytes)
            .with_context(|| format!("write artifact {}", path.display()))
    }

    /// Read and validate an artifact from `path`.
    ///
    /// Streaming: the 48-byte header (and the length it declares) is
    /// validated against the file's actual size *before* any
    /// payload-sized allocation, then sections are read and CRC-checked
    /// through a fixed 64 KiB scratch buffer — peak memory is the decoded
    /// tensors plus one chunk, never a second whole-file copy. Bit-
    /// identical to [`ModelArtifact::from_bytes`] on the same bytes, with
    /// the same error messages (a checksum mismatch always wins over a
    /// later parse error, exactly as the buffered decoder orders its
    /// checks).
    pub fn load<P: AsRef<Path>>(path: P) -> Result<ModelArtifact> {
        ModelArtifact::load_chunked(path.as_ref(), READ_CHUNK)
    }

    /// [`ModelArtifact::load`] with an explicit chunk size (tests shrink
    /// it below the section sizes to exercise multi-chunk reads).
    fn load_chunked(path: &Path, chunk: usize) -> Result<ModelArtifact> {
        let io_ctx = || format!("read model artifact {}", path.display());
        let parse_ctx = || format!("load model artifact {}", path.display());
        // Keep chunked payload reads 4-byte aligned so f32/u32 decoding
        // never straddles a chunk boundary.
        let chunk = (chunk.max(4) / 4) * 4;

        let mut file = std::fs::File::open(path).with_context(io_ctx)?;
        let actual = file.metadata().with_context(io_ctx)?.len() as usize;

        // Header first: every structural check that gates allocation runs
        // before a single payload byte is read.
        let header = read_validated_header(&mut file, actual, path).with_context(parse_ctx)?;

        let mut crc = Crc32::new();
        crc.update(&header);
        let mut body = BodyReader {
            file: &mut file,
            crc,
            left: actual - HEADER_LEN - 4,
            chunk,
        };

        // Parse the body, but *defer* any parse error until the CRC
        // trailer has been verified: a corrupt file must always report a
        // checksum mismatch (as the buffered decoder does, where the CRC
        // check runs before section parsing), not whatever structural
        // damage the corruption happened to cause.
        let parsed = decode_body(&header, &mut body);
        body.drain()?;
        let computed_crc = body.crc.value();
        let mut trailer = [0u8; 4];
        file.read_exact(&mut trailer).with_context(io_ctx)?;
        // Fault-injection hook (no-op unless the `fault-inject` feature
        // is on): lets the chaos suite prove that a damaged read fails
        // the deploy cleanly through the CRC check, without hand-
        // crafting broken files. Flipping trailer bits is equivalent to
        // the old whole-buffer hook, which flipped the final byte.
        crate::coordinator::faults::corrupt_artifact_bytes(&mut trailer);
        let stored_crc = u32::from_le_bytes(trailer);
        if stored_crc != computed_crc {
            return Err(anyhow::anyhow!(
                "artifact checksum mismatch (stored {stored_crc:08x}, computed {computed_crc:08x}) — corrupt file"
            ))
            .with_context(parse_ctx);
        }
        parsed.with_context(parse_ctx)
    }
}

// -- streaming decode -------------------------------------------------------

/// Read and validate the fixed 48-byte header: magic, format version,
/// and the declared total length against the file's actual size — every
/// structural check that gates allocation, before any payload byte.
fn read_validated_header(
    file: &mut std::fs::File,
    actual: usize,
    path: &Path,
) -> Result<[u8; HEADER_LEN]> {
    ensure!(
        actual >= HEADER_LEN + 4,
        "truncated artifact: {actual} bytes is smaller than the {}-byte header",
        HEADER_LEN + 4
    );
    let mut header = [0u8; HEADER_LEN];
    file.read_exact(&mut header)
        .with_context(|| format!("read model artifact {}", path.display()))?;
    ensure!(
        &header[0..4] == MAGIC,
        "not a .gsm model artifact (bad magic {:02x?})",
        &header[0..4]
    );
    let version = read_u32(&header, 4);
    ensure!(
        version == FORMAT_VERSION,
        "unsupported .gsm format version {version} (this build reads version {FORMAT_VERSION})"
    );
    let declared = read_u64(&header, 8) as usize;
    ensure!(
        declared == actual,
        "truncated or padded artifact: header declares {declared} bytes, file has {actual}"
    );
    Ok(header)
}

/// One streamed section: its tag, declared byte length, and the payload
/// decoded straight into its final typed form (the byte buffer is never
/// retained).
struct Section {
    tag: u32,
    len: usize,
    data: Payload,
}

enum Payload {
    F32(Vec<f32>),
    U32(Vec<u32>),
    Bytes(Vec<u8>),
    /// Unknown tag (forward compatibility) or a misaligned known payload
    /// whose count-mismatch error fires from the recorded length alone —
    /// the bytes were drained through the CRC and dropped.
    Skipped,
}

/// Incremental body reader: every byte read is folded into the running
/// CRC, `left` tracks the unread remainder of the section region (the
/// trailer is read separately by the caller).
struct BodyReader<'a> {
    file: &'a mut std::fs::File,
    crc: Crc32,
    left: usize,
    chunk: usize,
}

impl BodyReader<'_> {
    fn read_arr<const N: usize>(&mut self) -> Result<[u8; N]> {
        debug_assert!(N <= self.left);
        let mut buf = [0u8; N];
        self.file.read_exact(&mut buf)?;
        self.crc.update(&buf);
        self.left -= N;
        Ok(buf)
    }

    /// Pull `len` payload bytes through the fixed-size scratch buffer,
    /// feeding each chunk to `sink` after the CRC.
    fn read_chunked(&mut self, len: usize, mut sink: impl FnMut(&[u8])) -> Result<()> {
        debug_assert!(len <= self.left);
        if len == 0 {
            return Ok(());
        }
        let mut buf = vec![0u8; self.chunk.min(len)];
        let mut remaining = len;
        while remaining > 0 {
            let n = self.chunk.min(remaining);
            self.file.read_exact(&mut buf[..n])?;
            self.crc.update(&buf[..n]);
            sink(&buf[..n]);
            remaining -= n;
            self.left -= n;
        }
        Ok(())
    }

    fn read_f32s(&mut self, len: usize) -> Result<Vec<f32>> {
        debug_assert_eq!(len % 4, 0);
        let mut out = Vec::with_capacity(len / 4);
        self.read_chunked(len, |chunk| {
            out.extend(
                chunk
                    .chunks_exact(4)
                    .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap()))),
            )
        })?;
        Ok(out)
    }

    fn read_u32s(&mut self, len: usize) -> Result<Vec<u32>> {
        debug_assert_eq!(len % 4, 0);
        let mut out = Vec::with_capacity(len / 4);
        self.read_chunked(len, |chunk| {
            out.extend(
                chunk
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap())),
            )
        })?;
        Ok(out)
    }

    fn read_bytes(&mut self, len: usize) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(len);
        self.read_chunked(len, |chunk| out.extend_from_slice(chunk))?;
        Ok(out)
    }

    fn skip(&mut self, len: usize) -> Result<()> {
        self.read_chunked(len, |_| ())
    }

    /// Consume whatever the parser left unread (it may have bailed
    /// early) so the CRC covers the whole body.
    fn drain(&mut self) -> Result<()> {
        let left = self.left;
        self.skip(left)
    }
}

/// Decode the section region from a [`BodyReader`], mirroring
/// [`ModelArtifact::from_bytes`] check-for-check (same error messages,
/// same check order among parse errors; the caller enforces that a CRC
/// failure outranks anything returned here).
fn decode_body(header: &[u8; HEADER_LEN], body: &mut BodyReader) -> Result<ModelArtifact> {
    let precision = match read_u32(header, 16) {
        0 => PlanPrecision::F32,
        1 => PlanPrecision::F16,
        other => bail!("unknown plan precision code {other} (0 = f32, 1 = f16)"),
    };
    let inputs = read_u32(header, 20) as usize;
    let max_batch = read_u32(header, 24) as usize;
    let b = read_u32(header, 28) as usize;
    let k = read_u32(header, 32) as usize;
    let rows = read_u32(header, 36) as usize;
    let cols = read_u32(header, 40) as usize;
    let section_count = read_u32(header, 44) as usize;
    ensure!(b > 0 && k > 0 && b % k == 0, "bad GS geometry B={b} k={k}");

    let body_len = body.left;
    ensure!(
        section_count <= body_len / 12,
        "section count {section_count} cannot fit in a {body_len}-byte body"
    );
    ensure!(
        section_count <= 64,
        "implausible section count {section_count} (max 64)"
    );

    let mut secs: Vec<Section> = Vec::with_capacity(section_count);
    for s in 0..section_count {
        ensure!(
            body.left >= 12,
            "section {s} header runs past the end of the artifact"
        );
        let head: [u8; 12] = body.read_arr()?;
        let tag = read_u32(&head, 0);
        let len = read_u64(&head, 4) as usize;
        ensure!(
            len <= body.left,
            "section {s} (tag {tag}) payload of {len} bytes runs past the end of the artifact"
        );
        ensure!(
            !secs.iter().any(|e| e.tag == tag),
            "duplicate section tag {tag}"
        );
        let data = match tag {
            TAG_W1 | TAG_B1 | TAG_GS_VALUE | TAG_B2 if len % 4 == 0 => {
                Payload::F32(body.read_f32s(len)?)
            }
            TAG_GS_INDEX | TAG_GS_INDPTR | TAG_GS_ROWMAP if len % 4 == 0 => {
                Payload::U32(body.read_u32s(len)?)
            }
            TAG_META => Payload::Bytes(body.read_bytes(len)?),
            _ => {
                body.skip(len)?;
                Payload::Skipped
            }
        };
        secs.push(Section { tag, len, data });
    }
    ensure!(
        body.left == 0,
        "{} trailing bytes after the last section",
        body.left
    );

    let w1 = take_f32(&mut secs, TAG_W1, "W1", inputs * cols)?;
    let b1 = take_f32(&mut secs, TAG_B1, "B1", cols)?;
    let value_len = sec_len(&secs, TAG_GS_VALUE, "GS value")?;
    ensure!(
        value_len % (4 * b) == 0,
        "GS value section ({value_len} bytes) is not a whole number of {b}-wide groups"
    );
    let ngroups = value_len / (4 * b);
    let value = take_f32(&mut secs, TAG_GS_VALUE, "GS value", ngroups * b)?;
    let index = take_u32(&mut secs, TAG_GS_INDEX, "GS index", ngroups * b)?;
    let indptr_len = sec_len(&secs, TAG_GS_INDPTR, "GS indptr")?;
    ensure!(
        indptr_len >= 4 && indptr_len % 4 == 0,
        "GS indptr section has invalid length {indptr_len}"
    );
    let indptr = take_u32(&mut secs, TAG_GS_INDPTR, "GS indptr", indptr_len / 4)?;
    let nbands = indptr.len() - 1;
    let rowmap = if secs.iter().any(|e| e.tag == TAG_GS_ROWMAP) {
        Some(take_u32(&mut secs, TAG_GS_ROWMAP, "GS rowmap", nbands * (b / k))?)
    } else {
        None
    };
    let b2 = take_f32(&mut secs, TAG_B2, "B2", rows)?;
    let meta = match secs.iter().find(|e| e.tag == TAG_META) {
        Some(e) => match &e.data {
            Payload::Bytes(p) => {
                let s = std::str::from_utf8(p).context("metadata section is not UTF-8")?;
                Json::parse(s).context("metadata section is not valid JSON")?
            }
            _ => unreachable!("META is always decoded as bytes"),
        },
        None => Json::Null,
    };

    let gs = GsFormat {
        b,
        k,
        rows,
        cols,
        value,
        index,
        indptr,
        rowmap,
    };
    ModelArtifact::from_parts(w1, b1, gs, b2, inputs, max_batch, precision, meta)
        .context("decoded artifact failed validation")
}

/// Take a mandatory f32 section out of the streamed set, enforcing the
/// same count-mismatch message as [`f32_vec`].
fn take_f32(secs: &mut [Section], tag: u32, name: &str, expect: usize) -> Result<Vec<f32>> {
    let sec = secs
        .iter_mut()
        .find(|e| e.tag == tag)
        .with_context(|| format!("artifact is missing the {name} section"))?;
    ensure!(
        sec.len % 4 == 0 && sec.len / 4 == expect,
        "{name} section has {} bytes, expected {expect} f32 values",
        sec.len,
    );
    match &mut sec.data {
        Payload::F32(v) => Ok(std::mem::take(v)),
        _ => unreachable!("{name} is always decoded as f32"),
    }
}

/// Take a mandatory u32 section out of the streamed set, enforcing the
/// same count-mismatch message as [`u32_vec`].
fn take_u32(secs: &mut [Section], tag: u32, name: &str, expect: usize) -> Result<Vec<u32>> {
    let sec = secs
        .iter_mut()
        .find(|e| e.tag == tag)
        .with_context(|| format!("artifact is missing the {name} section"))?;
    ensure!(
        sec.len % 4 == 0 && sec.len / 4 == expect,
        "{name} section has {} bytes, expected {expect} u32 values",
        sec.len,
    );
    match &mut sec.data {
        Payload::U32(v) => Ok(std::mem::take(v)),
        _ => unreachable!("{name} is always decoded as u32"),
    }
}

/// Byte length of a mandatory section in the streamed set.
fn sec_len(secs: &[Section], tag: u32, name: &str) -> Result<usize> {
    secs.iter()
        .find(|e| e.tag == tag)
        .map(|e| e.len)
        .with_context(|| format!("artifact is missing the {name} section"))
}

/// Find a mandatory section by tag.
fn section<'a>(found: &[(u32, &'a [u8])], tag: u32, name: &str) -> Result<&'a [u8]> {
    found
        .iter()
        .find(|&&(t, _)| t == tag)
        .map(|&(_, p)| p)
        .with_context(|| format!("artifact is missing the {name} section"))
}

// -- little-endian helpers (offsets pre-checked by callers) -----------------

fn read_u32(bytes: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap())
}

fn read_u64(bytes: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(bytes[at..at + 8].try_into().unwrap())
}

fn f32_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
    out
}

fn u32_bytes(v: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

// The `expect` counts below are products of header-declared u32 fields,
// so they are compared against `payload.len() / 4` (never multiplied by
// 4, which could wrap for hostile headers); the mismatch error fires
// before any `expect`-sized allocation.

fn f32_vec(payload: &[u8], expect: usize, name: &str) -> Result<Vec<f32>> {
    ensure!(
        payload.len() % 4 == 0 && payload.len() / 4 == expect,
        "{name} section has {} bytes, expected {expect} f32 values",
        payload.len(),
    );
    Ok(payload
        .chunks_exact(4)
        .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
        .collect())
}

fn u32_vec(payload: &[u8], expect: usize, name: &str) -> Result<Vec<u32>> {
    ensure!(
        payload.len() % 4 == 0 && payload.len() / 4 == expect,
        "{name} section has {} bytes, expected {expect} u32 values",
        payload.len(),
    );
    Ok(payload
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::pattern::Pattern;
    use crate::testing::model::build_random_gs;

    fn sample(precision: PlanPrecision, pattern: Pattern, seed: u64) -> ModelArtifact {
        let (_, gs) = build_random_gs(16, 32, pattern, 0.75, seed).unwrap();
        let (inputs, hidden, outputs) = (8usize, gs.cols, gs.rows);
        let mut rng = crate::util::prng::Prng::new(seed ^ 0xA5);
        ModelArtifact::from_parts(
            rng.normal_vec(inputs * hidden, 0.1),
            rng.normal_vec(hidden, 0.05),
            gs,
            rng.normal_vec(outputs, 0.1),
            inputs,
            4,
            precision,
            Json::obj(vec![("seed", Json::Num(seed as f64))]),
        )
        .unwrap()
    }

    #[test]
    fn bytes_roundtrip_exactly() {
        for (precision, pattern) in [
            (PlanPrecision::F32, Pattern::Gs { b: 8, k: 8 }),
            (PlanPrecision::F16, Pattern::Gs { b: 8, k: 2 }),
            (PlanPrecision::F32, Pattern::GsScatter { b: 8, k: 1 }),
        ] {
            let a = sample(precision, pattern, 5);
            let bytes = a.to_bytes();
            let b = ModelArtifact::from_bytes(&bytes).unwrap();
            assert_eq!(a.w1, b.w1);
            assert_eq!(a.b1, b.b1);
            assert_eq!(a.gs, b.gs);
            assert_eq!(a.b2, b.b2);
            assert_eq!(a.inputs, b.inputs);
            assert_eq!(a.max_batch, b.max_batch);
            assert_eq!(a.precision, b.precision);
            assert_eq!(a.meta, b.meta);
            // Re-encoding the decode is byte-identical (canonical format).
            assert_eq!(b.to_bytes(), bytes);
        }
    }

    #[test]
    fn kernel_variant_pin_roundtrips_and_reads_leniently() {
        let mut a = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 2 }, 14);
        assert_eq!(a.kernel_variant(), None, "sample meta carries no pin");
        a.set_kernel_variant(KernelVariant::SmallGroupUnrolled);
        assert_eq!(a.kernel_variant(), Some(KernelVariant::SmallGroupUnrolled));
        let b = ModelArtifact::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(b.kernel_variant(), Some(KernelVariant::SmallGroupUnrolled));
        assert!(b.meta.get("seed").is_some(), "existing meta keys survive the pin");
        // A label from a newer build reads as None (classification
        // fallback) and still instantiates cleanly.
        let mut c = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 2 }, 15);
        if let Json::Obj(m) = &mut c.meta {
            m.insert("kernel_variant".into(), Json::Str("from_the_future".into()));
        }
        assert_eq!(c.kernel_variant(), None);
        assert!(c.instantiate(1).is_ok());
        // Pinning onto Null meta creates the metadata object.
        let mut d = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 8 }, 16);
        d.meta = Json::Null;
        d.set_kernel_variant(KernelVariant::Generic);
        assert_eq!(d.kernel_variant(), Some(KernelVariant::Generic));
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 8 }, 1).to_bytes();
        bytes[0] = b'X';
        let err = ModelArtifact::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");
    }

    #[test]
    fn rejects_unsupported_version() {
        let mut bytes = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 8 }, 2).to_bytes();
        bytes[4] = 9; // version 9
        let n = bytes.len();
        let crc = crc32(&bytes[..n - 4]).to_le_bytes();
        bytes[n - 4..].copy_from_slice(&crc); // keep the checksum honest
        let err = ModelArtifact::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
    }

    #[test]
    fn rejects_truncation() {
        let bytes = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 8 }, 3).to_bytes();
        let err = ModelArtifact::from_bytes(&bytes[..bytes.len() / 2]).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
        let err = ModelArtifact::from_bytes(&bytes[..10]).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn rejects_corruption_via_checksum() {
        let mut bytes = sample(PlanPrecision::F16, Pattern::Gs { b: 8, k: 8 }, 4).to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = ModelArtifact::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(ModelArtifact::from_bytes(&[]).is_err());
        assert!(ModelArtifact::from_bytes(&[0u8; 64]).is_err());
    }

    #[test]
    fn save_load_file_roundtrip() {
        let a = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 2 }, 6);
        let path = std::env::temp_dir().join(format!("gsm-artifact-test-{}.gsm", std::process::id()));
        a.save(&path).unwrap();
        let b = ModelArtifact::load(&path).unwrap();
        assert_eq!(a.gs, b.gs);
        assert_eq!(a.w1, b.w1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_is_a_clear_error() {
        let err = ModelArtifact::load("/nonexistent/nowhere.gsm").unwrap_err();
        assert!(format!("{err:#}").contains("nowhere.gsm"), "{err:#}");
    }

    fn scratch(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gsm-stream-test-{}-{name}", std::process::id()))
    }

    #[test]
    fn streaming_load_is_bit_identical_across_chunk_sizes() {
        // The sample's W1 section alone is 8*32*4 = 1024 bytes, so a
        // 64-byte chunk forces multi-chunk reads inside every large
        // section; a huge chunk degenerates to one read per section.
        let a = sample(PlanPrecision::F16, Pattern::GsScatter { b: 8, k: 1 }, 9);
        let bytes = a.to_bytes();
        let path = scratch("chunks.gsm");
        a.save(&path).unwrap();
        for chunk in [4usize, 64, 1000, 1 << 22] {
            let b = ModelArtifact::load_chunked(&path, chunk).unwrap();
            assert_eq!(
                b.to_bytes(),
                bytes,
                "chunk size {chunk} must decode bit-identically"
            );
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn streaming_load_rejects_corrupt_final_chunk() {
        let a = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 2 }, 10);
        let path = scratch("tail.gsm");
        a.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Damage a payload byte inside the last chunk-sized span before
        // the trailer: only the final incremental CRC update can see it.
        let n = bytes.len();
        bytes[n - 9] ^= 0x04;
        std::fs::write(&path, &bytes).unwrap();
        let err = ModelArtifact::load_chunked(&path, 64).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn streaming_load_reports_checksum_over_structural_damage() {
        // Corrupting the section count breaks both structure and CRC;
        // the buffered decoder checks the CRC first, so the streaming
        // decoder must defer its parse error and report the checksum.
        let a = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 8 }, 11);
        let path = scratch("defer.gsm");
        let mut bytes = a.to_bytes();
        bytes[44] = 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let from_bytes_err = ModelArtifact::from_bytes(&bytes).unwrap_err();
        let streamed_err = ModelArtifact::load_chunked(&path, 64).unwrap_err();
        assert!(format!("{from_bytes_err:#}").contains("checksum"), "{from_bytes_err:#}");
        assert!(format!("{streamed_err:#}").contains("checksum"), "{streamed_err:#}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn streaming_load_validates_length_before_payloads() {
        let a = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 8 }, 12);
        let path = scratch("short.gsm");
        let bytes = a.to_bytes();
        // File shorter than the header declares: caught from metadata
        // alone, with the same message as the buffered decoder.
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        let err = ModelArtifact::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated or padded"), "{err:#}");
        // File smaller than the fixed header.
        std::fs::write(&path, &bytes[..20]).unwrap();
        let err = ModelArtifact::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated artifact"), "{err:#}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_cleans_stale_tmp_from_crashed_writer() {
        let a = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 8 }, 13);
        let path = scratch("stale.gsm");
        let tmp = crate::util::fsio::tmp_path(&path);
        std::fs::write(&tmp, b"half-written junk from a dead process").unwrap();
        a.save(&path).unwrap();
        assert!(!tmp.exists(), "save must clear the stale temp file");
        assert_eq!(ModelArtifact::load(&path).unwrap().gs, a.gs);
        let _ = std::fs::remove_file(&path);
    }
}
