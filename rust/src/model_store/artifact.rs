//! The `.gsm` model artifact: a self-describing binary serialization of
//! one deployed sparse model (paper §V compact format + §X storage
//! resolution, packaged for shipping).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! [ 0.. 4)  magic  b"GSM1"
//! [ 4.. 8)  u32    format version (= 1)
//! [ 8..16)  u64    total file length in bytes (truncation check)
//! [16..20)  u32    plan precision (0 = f32, 1 = f16)
//! [20..24)  u32    inputs
//! [24..28)  u32    max_batch
//! [28..32)  u32    GS B
//! [32..36)  u32    GS k
//! [36..40)  u32    GS rows   (= outputs)
//! [40..44)  u32    GS cols   (= hidden)
//! [44..48)  u32    section count
//! [48.. )   sections: { u32 tag; u64 byte length; payload }
//! [-4.. )   u32    CRC-32 (IEEE) of every preceding byte
//! ```
//!
//! Sections carry the per-layer tensors: dense input layer (`W1`, `B1`),
//! the GS-compressed projection (`value`/`index`/`indptr` and, for
//! scatter patterns, `rowmap`), the output bias (`B2`), and a free-form
//! JSON metadata blob. Unknown tags are skipped (forward compatibility
//! within a format version); missing mandatory tags, duplicate tags,
//! length mismatches, bad magic, unsupported versions, truncation, and
//! checksum failures are all **errors, not panics**.
//!
//! Weight values are stored as raw f32 bit patterns regardless of the
//! declared plan precision: `GsExecPlan` quantizes at pack time, so a
//! reloaded artifact rebuilds the exact same plan — `export → load →
//! infer_batch` is bit-identical to the originating in-memory model at
//! both precisions (and at any thread count, since every kernel is
//! bit-identical serial vs parallel).

use crate::coordinator::SparseModel;
use crate::kernels::exec::PlanPrecision;
use crate::sparse::format::GsFormat;
use crate::util::crc32::crc32;
use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

const MAGIC: &[u8; 4] = b"GSM1";
const FORMAT_VERSION: u32 = 1;
const HEADER_LEN: usize = 48;

const TAG_W1: u32 = 1;
const TAG_B1: u32 = 2;
const TAG_GS_VALUE: u32 = 3;
const TAG_GS_INDEX: u32 = 4;
const TAG_GS_INDPTR: u32 = 5;
const TAG_GS_ROWMAP: u32 = 6;
const TAG_B2: u32 = 7;
const TAG_META: u32 = 8;

/// One deployable sparse model, decoupled from any execution plan: the
/// raw tensors plus the precision the plan should be packed at.
#[derive(Clone, Debug)]
pub struct ModelArtifact {
    pub inputs: usize,
    pub max_batch: usize,
    /// Packed-plan value resolution to instantiate with.
    pub precision: PlanPrecision,
    /// `[inputs, hidden]` row-major dense input layer.
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    /// GS compression of the `[outputs, hidden]` projection.
    pub gs: GsFormat,
    pub b2: Vec<f32>,
    /// Free-form metadata (name, seed, provenance — not interpreted).
    pub meta: Json,
}

impl ModelArtifact {
    pub fn hidden(&self) -> usize {
        self.gs.cols
    }

    pub fn outputs(&self) -> usize {
        self.gs.rows
    }

    /// Assemble an artifact from raw parts, validating shapes.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        w1: Vec<f32>,
        b1: Vec<f32>,
        gs: GsFormat,
        b2: Vec<f32>,
        inputs: usize,
        max_batch: usize,
        precision: PlanPrecision,
        meta: Json,
    ) -> Result<ModelArtifact> {
        gs.validate().context("artifact GS format invalid")?;
        let (hidden, outputs) = (gs.cols, gs.rows);
        ensure!(max_batch > 0, "max_batch must be positive");
        ensure!(
            w1.len() == inputs * hidden,
            "w1 length {} != inputs*hidden {}",
            w1.len(),
            inputs * hidden
        );
        ensure!(b1.len() == hidden, "b1 length {} != hidden {hidden}", b1.len());
        ensure!(b2.len() == outputs, "b2 length {} != outputs {outputs}", b2.len());
        if precision == PlanPrecision::F16 {
            ensure!(
                hidden <= u16::MAX as usize + 1,
                "f16 artifacts index columns with u16: hidden {hidden} > {}",
                u16::MAX as usize + 1
            );
        }
        Ok(ModelArtifact {
            inputs,
            max_batch,
            precision,
            w1,
            b1,
            gs,
            b2,
            meta,
        })
    }

    /// Build the native serving model this artifact describes. `threads`
    /// follows [`SparseModel::native`] semantics (0 = auto-detect).
    pub fn instantiate(&self, threads: usize) -> Result<SparseModel> {
        SparseModel::native(
            self.w1.clone(),
            self.b1.clone(),
            &self.gs,
            self.b2.clone(),
            self.inputs,
            self.max_batch,
            threads,
            self.precision,
        )
    }

    /// One-line human summary (CLI banners, logs).
    pub fn describe(&self) -> String {
        format!(
            "{}→{}→{} GS({},{}){} {} plan, {} nnz, batch {}",
            self.inputs,
            self.hidden(),
            self.outputs(),
            self.gs.b,
            self.gs.k,
            if self.gs.rowmap.is_some() { " scatter" } else { "" },
            self.precision.name(),
            self.gs.nnz(),
            self.max_batch
        )
    }

    // -- encoding -----------------------------------------------------------

    /// Serialize to the `.gsm` byte format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut sections: Vec<(u32, Vec<u8>)> = vec![
            (TAG_W1, f32_bytes(&self.w1)),
            (TAG_B1, f32_bytes(&self.b1)),
            (TAG_GS_VALUE, f32_bytes(&self.gs.value)),
            (TAG_GS_INDEX, u32_bytes(&self.gs.index)),
            (TAG_GS_INDPTR, u32_bytes(&self.gs.indptr)),
        ];
        if let Some(map) = &self.gs.rowmap {
            sections.push((TAG_GS_ROWMAP, u32_bytes(map)));
        }
        sections.push((TAG_B2, f32_bytes(&self.b2)));
        if self.meta != Json::Null {
            sections.push((TAG_META, self.meta.to_string().into_bytes()));
        }

        let body_len: usize = sections.iter().map(|(_, p)| 12 + p.len()).sum();
        let total = HEADER_LEN + body_len + 4;
        let mut out = Vec::with_capacity(total);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(total as u64).to_le_bytes());
        let precision_code: u32 = match self.precision {
            PlanPrecision::F32 => 0,
            PlanPrecision::F16 => 1,
        };
        for v in [
            precision_code,
            self.inputs as u32,
            self.max_batch as u32,
            self.gs.b as u32,
            self.gs.k as u32,
            self.gs.rows as u32,
            self.gs.cols as u32,
            sections.len() as u32,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for (tag, payload) in &sections {
            out.extend_from_slice(&tag.to_le_bytes());
            out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            out.extend_from_slice(payload);
        }
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        debug_assert_eq!(out.len(), total);
        out
    }

    /// Decode and validate a `.gsm` byte buffer.
    pub fn from_bytes(bytes: &[u8]) -> Result<ModelArtifact> {
        ensure!(
            bytes.len() >= HEADER_LEN + 4,
            "truncated artifact: {} bytes is smaller than the {}-byte header",
            bytes.len(),
            HEADER_LEN + 4
        );
        ensure!(
            &bytes[0..4] == MAGIC,
            "not a .gsm model artifact (bad magic {:02x?})",
            &bytes[0..4]
        );
        let version = read_u32(bytes, 4);
        ensure!(
            version == FORMAT_VERSION,
            "unsupported .gsm format version {version} (this build reads version {FORMAT_VERSION})"
        );
        let declared = read_u64(bytes, 8) as usize;
        ensure!(
            declared == bytes.len(),
            "truncated or padded artifact: header declares {declared} bytes, file has {}",
            bytes.len()
        );
        let stored_crc = read_u32(bytes, bytes.len() - 4);
        let actual_crc = crc32(&bytes[..bytes.len() - 4]);
        ensure!(
            stored_crc == actual_crc,
            "artifact checksum mismatch (stored {stored_crc:08x}, computed {actual_crc:08x}) — corrupt file"
        );

        let precision = match read_u32(bytes, 16) {
            0 => PlanPrecision::F32,
            1 => PlanPrecision::F16,
            other => bail!("unknown plan precision code {other} (0 = f32, 1 = f16)"),
        };
        let inputs = read_u32(bytes, 20) as usize;
        let max_batch = read_u32(bytes, 24) as usize;
        let b = read_u32(bytes, 28) as usize;
        let k = read_u32(bytes, 32) as usize;
        let rows = read_u32(bytes, 36) as usize;
        let cols = read_u32(bytes, 40) as usize;
        let section_count = read_u32(bytes, 44) as usize;
        ensure!(b > 0 && k > 0 && b % k == 0, "bad GS geometry B={b} k={k}");

        // Walk the tagged sections (payload bounds are inside the
        // CRC-covered region, but lengths are still checked — a reader
        // must never index past the buffer, and header-declared counts
        // must never drive allocations beyond what the file can hold).
        let body = &bytes[HEADER_LEN..bytes.len() - 4];
        ensure!(
            section_count <= body.len() / 12,
            "section count {section_count} cannot fit in a {}-byte body",
            body.len()
        );
        // 8 tags are defined; 64 leaves generous room for future minor
        // additions while keeping the per-section duplicate scan (and any
        // crafted-file parse work) trivially bounded.
        ensure!(
            section_count <= 64,
            "implausible section count {section_count} (max 64)"
        );
        let mut pos = 0usize;
        let mut found: Vec<(u32, &[u8])> = Vec::with_capacity(section_count);
        for s in 0..section_count {
            ensure!(
                pos + 12 <= body.len(),
                "section {s} header runs past the end of the artifact"
            );
            let tag = read_u32(body, pos);
            let len = read_u64(body, pos + 4) as usize;
            pos += 12;
            ensure!(
                len <= body.len() - pos,
                "section {s} (tag {tag}) payload of {len} bytes runs past the end of the artifact"
            );
            ensure!(
                !found.iter().any(|&(t, _)| t == tag),
                "duplicate section tag {tag}"
            );
            found.push((tag, &body[pos..pos + len]));
            pos += len;
        }
        ensure!(
            pos == body.len(),
            "{} trailing bytes after the last section",
            body.len() - pos
        );

        let w1 = f32_vec(section(&found, TAG_W1, "W1")?, inputs * cols, "W1")?;
        let b1 = f32_vec(section(&found, TAG_B1, "B1")?, cols, "B1")?;
        let value_raw = section(&found, TAG_GS_VALUE, "GS value")?;
        ensure!(
            value_raw.len() % (4 * b) == 0,
            "GS value section ({} bytes) is not a whole number of {b}-wide groups",
            value_raw.len()
        );
        let ngroups = value_raw.len() / (4 * b);
        let value = f32_vec(value_raw, ngroups * b, "GS value")?;
        let index = u32_vec(
            section(&found, TAG_GS_INDEX, "GS index")?,
            ngroups * b,
            "GS index",
        )?;
        let indptr_raw = section(&found, TAG_GS_INDPTR, "GS indptr")?;
        ensure!(
            indptr_raw.len() >= 4 && indptr_raw.len() % 4 == 0,
            "GS indptr section has invalid length {}",
            indptr_raw.len()
        );
        let indptr = u32_vec(indptr_raw, indptr_raw.len() / 4, "GS indptr")?;
        let nbands = indptr.len() - 1;
        let rowmap = match found.iter().find(|&&(t, _)| t == TAG_GS_ROWMAP) {
            Some(&(_, p)) => Some(u32_vec(p, nbands * (b / k), "GS rowmap")?),
            None => None,
        };
        let b2 = f32_vec(section(&found, TAG_B2, "B2")?, rows, "B2")?;
        let meta = match found.iter().find(|&&(t, _)| t == TAG_META) {
            Some(&(_, p)) => {
                let s = std::str::from_utf8(p).context("metadata section is not UTF-8")?;
                Json::parse(s).context("metadata section is not valid JSON")?
            }
            None => Json::Null,
        };

        let gs = GsFormat {
            b,
            k,
            rows,
            cols,
            value,
            index,
            indptr,
            rowmap,
        };
        ModelArtifact::from_parts(w1, b1, gs, b2, inputs, max_batch, precision, meta)
            .context("decoded artifact failed validation")
    }

    // -- file I/O -----------------------------------------------------------

    /// Write the artifact to `path` (atomically: temp file + rename, so a
    /// concurrent `swap` never observes a half-written artifact).
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let path = path.as_ref();
        let bytes = self.to_bytes();
        let tmp = path.with_extension("gsm.tmp");
        std::fs::write(&tmp, &bytes)
            .with_context(|| format!("write artifact temp file {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("rename artifact into place at {}", path.display()))?;
        Ok(())
    }

    /// Read and validate an artifact from `path`.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<ModelArtifact> {
        let path = path.as_ref();
        let mut bytes = std::fs::read(path)
            .with_context(|| format!("read model artifact {}", path.display()))?;
        // Fault-injection hook (no-op unless the `fault-inject` feature
        // is on): lets the chaos suite prove that a damaged read fails
        // the deploy cleanly through the CRC check, without hand-
        // crafting broken files.
        crate::coordinator::faults::corrupt_artifact_bytes(&mut bytes);
        ModelArtifact::from_bytes(&bytes)
            .with_context(|| format!("load model artifact {}", path.display()))
    }
}

/// Find a mandatory section by tag.
fn section<'a>(found: &[(u32, &'a [u8])], tag: u32, name: &str) -> Result<&'a [u8]> {
    found
        .iter()
        .find(|&&(t, _)| t == tag)
        .map(|&(_, p)| p)
        .with_context(|| format!("artifact is missing the {name} section"))
}

// -- little-endian helpers (offsets pre-checked by callers) -----------------

fn read_u32(bytes: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap())
}

fn read_u64(bytes: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(bytes[at..at + 8].try_into().unwrap())
}

fn f32_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
    out
}

fn u32_bytes(v: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

// The `expect` counts below are products of header-declared u32 fields,
// so they are compared against `payload.len() / 4` (never multiplied by
// 4, which could wrap for hostile headers); the mismatch error fires
// before any `expect`-sized allocation.

fn f32_vec(payload: &[u8], expect: usize, name: &str) -> Result<Vec<f32>> {
    ensure!(
        payload.len() % 4 == 0 && payload.len() / 4 == expect,
        "{name} section has {} bytes, expected {expect} f32 values",
        payload.len(),
    );
    Ok(payload
        .chunks_exact(4)
        .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
        .collect())
}

fn u32_vec(payload: &[u8], expect: usize, name: &str) -> Result<Vec<u32>> {
    ensure!(
        payload.len() % 4 == 0 && payload.len() / 4 == expect,
        "{name} section has {} bytes, expected {expect} u32 values",
        payload.len(),
    );
    Ok(payload
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::pattern::Pattern;
    use crate::testing::model::build_random_gs;

    fn sample(precision: PlanPrecision, pattern: Pattern, seed: u64) -> ModelArtifact {
        let (_, gs) = build_random_gs(16, 32, pattern, 0.75, seed).unwrap();
        let (inputs, hidden, outputs) = (8usize, gs.cols, gs.rows);
        let mut rng = crate::util::prng::Prng::new(seed ^ 0xA5);
        ModelArtifact::from_parts(
            rng.normal_vec(inputs * hidden, 0.1),
            rng.normal_vec(hidden, 0.05),
            gs,
            rng.normal_vec(outputs, 0.1),
            inputs,
            4,
            precision,
            Json::obj(vec![("seed", Json::Num(seed as f64))]),
        )
        .unwrap()
    }

    #[test]
    fn bytes_roundtrip_exactly() {
        for (precision, pattern) in [
            (PlanPrecision::F32, Pattern::Gs { b: 8, k: 8 }),
            (PlanPrecision::F16, Pattern::Gs { b: 8, k: 2 }),
            (PlanPrecision::F32, Pattern::GsScatter { b: 8, k: 1 }),
        ] {
            let a = sample(precision, pattern, 5);
            let bytes = a.to_bytes();
            let b = ModelArtifact::from_bytes(&bytes).unwrap();
            assert_eq!(a.w1, b.w1);
            assert_eq!(a.b1, b.b1);
            assert_eq!(a.gs, b.gs);
            assert_eq!(a.b2, b.b2);
            assert_eq!(a.inputs, b.inputs);
            assert_eq!(a.max_batch, b.max_batch);
            assert_eq!(a.precision, b.precision);
            assert_eq!(a.meta, b.meta);
            // Re-encoding the decode is byte-identical (canonical format).
            assert_eq!(b.to_bytes(), bytes);
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 8 }, 1).to_bytes();
        bytes[0] = b'X';
        let err = ModelArtifact::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");
    }

    #[test]
    fn rejects_unsupported_version() {
        let mut bytes = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 8 }, 2).to_bytes();
        bytes[4] = 9; // version 9
        let n = bytes.len();
        let crc = crc32(&bytes[..n - 4]).to_le_bytes();
        bytes[n - 4..].copy_from_slice(&crc); // keep the checksum honest
        let err = ModelArtifact::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
    }

    #[test]
    fn rejects_truncation() {
        let bytes = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 8 }, 3).to_bytes();
        let err = ModelArtifact::from_bytes(&bytes[..bytes.len() / 2]).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
        let err = ModelArtifact::from_bytes(&bytes[..10]).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn rejects_corruption_via_checksum() {
        let mut bytes = sample(PlanPrecision::F16, Pattern::Gs { b: 8, k: 8 }, 4).to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = ModelArtifact::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(ModelArtifact::from_bytes(&[]).is_err());
        assert!(ModelArtifact::from_bytes(&[0u8; 64]).is_err());
    }

    #[test]
    fn save_load_file_roundtrip() {
        let a = sample(PlanPrecision::F32, Pattern::Gs { b: 8, k: 2 }, 6);
        let path = std::env::temp_dir().join(format!("gsm-artifact-test-{}.gsm", std::process::id()));
        a.save(&path).unwrap();
        let b = ModelArtifact::load(&path).unwrap();
        assert_eq!(a.gs, b.gs);
        assert_eq!(a.w1, b.w1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_is_a_clear_error() {
        let err = ModelArtifact::load("/nonexistent/nowhere.gsm").unwrap_err();
        assert!(format!("{err:#}").contains("nowhere.gsm"), "{err:#}");
    }
}
