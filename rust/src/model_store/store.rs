//! Versioned, `Arc`-swappable model slots and the named slot registry.
//!
//! A [`ModelSlot`] is the coordinator-level unit of zero-downtime
//! deployment: serving workers take an `Arc` snapshot of the current
//! [`VersionedModel`] once per batch, so a [`ModelSlot::swap`] installed
//! under live traffic changes which model *future* batches execute while
//! every in-flight batch keeps (and finishes on) the version it started
//! with — no dropped connections, no torn batches, never two versions
//! inside one batch. The displaced model is freed when its last in-flight
//! batch drops its `Arc`.
//!
//! Beyond plain swaps the slot carries the deployment-safety machinery:
//!
//! * **Retention + rollback** — each swap pushes the displaced generation
//!   onto a bounded history ([`SlotConfig::retain`]); [`ModelSlot::rollback`]
//!   restores the newest retained generation under live traffic with the
//!   same snapshot guarantees as swap (the exact prior `Arc` comes back,
//!   so logits are bit-identical to before the bad deploy).
//! * **Canary swaps** — [`ModelSlot::swap_canary`] installs a generation
//!   that serves normally but is *watched* for its first N requests; if
//!   the error rate exceeds the configured threshold the slot
//!   auto-rolls-back and records the reason, otherwise it promotes to
//!   plain serving. Decisions come out of [`ModelSlot::observe_execution`]
//!   as [`SlotEvent`]s the serving workers act on.
//! * **Quarantine circuit breaker** — repeated failures within a sliding
//!   window ([`SlotConfig::quarantine_after`]) flip the slot to
//!   `quarantined`: [`ModelSlot::admit`] fast-fails new requests instead
//!   of burning batch slots, then lets one probe request through per
//!   cool-down interval; a clean probe closes the circuit.
//!
//! [`ModelStore`] is the named registry of slots behind multi-model
//! serving: requests route by slot name, [`ModelStore::acquire`] bumps a
//! slot's recency on every routed infer, and a capacity bound
//! (`max_models`) triggers **LRU eviction of cold models** when a new one
//! is registered. The pinned default slot is never evicted, and eviction
//! is graceful: it only drops the registry's `Arc` — requests and batches
//! already holding the slot (or a `VersionedModel` snapshot) finish
//! undisturbed.

use super::artifact::ModelArtifact;
use crate::coordinator::SparseModel;
use crate::kernels::exec::PlanPrecision;
use anyhow::{bail, ensure, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// One deployed model generation.
pub struct VersionedModel {
    /// Monotonic deployment version, starting at 1.
    pub version: u64,
    pub model: SparseModel,
    /// Where this generation came from (artifact path, "inline", …).
    pub source: String,
}

impl VersionedModel {
    /// Packed-plan precision of this generation (None for pjrt models).
    pub fn precision(&self) -> Option<PlanPrecision> {
        self.model.precision()
    }

    /// Dispatch-kernel variant this generation serves on (None for pjrt
    /// models).
    pub fn kernel_variant(&self) -> Option<crate::kernels::dispatch::KernelVariant> {
        self.model.kernel_variant()
    }
}

/// Per-slot deployment-safety knobs.
#[derive(Debug, Clone, Copy)]
pub struct SlotConfig {
    /// Previous generations kept for rollback (0 disables rollback and
    /// canary swaps).
    pub retain: usize,
    /// Quarantine the slot after this many failed requests inside the
    /// sliding window (0 disables the circuit breaker).
    pub quarantine_after: usize,
    /// Sliding-window width for counting failures, milliseconds.
    pub quarantine_window_ms: u64,
    /// Cool-down before a quarantined slot admits a half-open probe
    /// request (and between successive probes), milliseconds.
    pub quarantine_cooldown_ms: u64,
    /// Version number of the initial generation (manifest replay restores
    /// a slot at its pre-crash version instead of 1).
    pub start_version: u64,
}

impl Default for SlotConfig {
    fn default() -> SlotConfig {
        SlotConfig {
            retain: 2,
            quarantine_after: 0,
            quarantine_window_ms: 10_000,
            quarantine_cooldown_ms: 2_000,
            start_version: 1,
        }
    }
}

/// Admission verdict for one infer request against a slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Slot is healthy: enqueue normally.
    Admit,
    /// Slot is quarantined but due for a half-open probe: enqueue this
    /// one request marked as the probe whose outcome decides recovery.
    AdmitProbe,
    /// Slot is quarantined: fail fast without burning a batch slot.
    /// `retry_in_ms` is the time until the next probe opportunity.
    FastFail {
        /// Milliseconds until the breaker will admit a probe.
        retry_in_ms: u64,
    },
}

/// A state transition produced by [`ModelSlot::observe_execution`]. The
/// serving worker that observes the batch outcome surfaces these into
/// metrics/logs/manifest persistence.
#[derive(Debug, Clone, PartialEq)]
pub enum SlotEvent {
    /// A canary generation survived its request budget.
    CanaryPromoted { version: u64 },
    /// A canary generation exceeded its error budget and the slot rolled
    /// back to the retained previous generation.
    CanaryRolledBack { from: u64, to: u64, reason: String },
    /// The circuit breaker tripped: the slot now fast-fails admission.
    Quarantined { reason: String },
    /// A half-open probe succeeded: the slot serves normally again.
    Recovered,
}

/// The live generation plus the bounded rollback history, guarded by one
/// lock so swap/rollback are atomic against snapshot readers.
struct Generations {
    live: Arc<VersionedModel>,
    /// Displaced generations, oldest at the front, at most
    /// [`SlotConfig::retain`] entries. `rollback` pops the back.
    history: VecDeque<Arc<VersionedModel>>,
}

/// Canary watch state for a freshly swapped generation.
struct CanaryState {
    version: u64,
    budget: u64,
    max_error_rate: f64,
    seen: u64,
    failed: u64,
}

/// Quarantine circuit breaker.
enum Circuit {
    /// Serving normally; `failures` holds the timestamps of recent failed
    /// requests (bounded at `quarantine_after` entries).
    Closed { failures: VecDeque<Instant> },
    /// Quarantined. `last_probe` rate-limits half-open probes to one per
    /// cool-down interval — a probe that is shed or expires can never
    /// wedge the breaker, the next interval simply admits another.
    Open {
        since: Instant,
        last_probe: Option<Instant>,
    },
}

impl Circuit {
    fn closed() -> Circuit {
        Circuit::Closed {
            failures: VecDeque::new(),
        }
    }
}

/// Health state that changes on batch outcomes, kept apart from the
/// generation lock. Lock order is `gens` → `health` (rollback takes
/// both); `observe_execution` decides under `health` alone, releases it,
/// then calls rollback — never `health` → `gens`.
struct Health {
    canary: Option<CanaryState>,
    circuit: Circuit,
    /// Human-readable record of the most recent rollback on this slot.
    last_rollback: Option<String>,
}

/// An atomically swappable slot holding the live model generation plus
/// its bounded rollback history and health state.
pub struct ModelSlot {
    gens: RwLock<Generations>,
    health: Mutex<Health>,
    next_version: AtomicU64,
    cfg: SlotConfig,
    /// Kernel threads for models instantiated by [`ModelSlot::swap_path`]
    /// (0 = auto-detect, per [`SparseModel::native`]).
    threads: usize,
    /// Frozen serving contract: every swapped-in model must accept the
    /// same input width and at least the original batch capacity, so the
    /// TCP front-end's admission checks stay valid across deployments.
    input_width: usize,
    min_batch: usize,
}

impl ModelSlot {
    /// Create a slot serving `model` as version 1 with default safety
    /// config (retain 2, circuit breaker off). `threads` is the
    /// kernel-thread setting future [`ModelSlot::swap_path`] loads
    /// instantiate with.
    pub fn new(model: SparseModel, source: &str, threads: usize) -> ModelSlot {
        ModelSlot::with_config(model, source, threads, SlotConfig::default())
    }

    /// Create a slot with explicit deployment-safety configuration.
    pub fn with_config(
        model: SparseModel,
        source: &str,
        threads: usize,
        cfg: SlotConfig,
    ) -> ModelSlot {
        let input_width = model.inputs;
        let min_batch = model.max_batch;
        let start = cfg.start_version.max(1);
        ModelSlot {
            gens: RwLock::new(Generations {
                live: Arc::new(VersionedModel {
                    version: start,
                    model,
                    source: source.to_string(),
                }),
                history: VecDeque::new(),
            }),
            health: Mutex::new(Health {
                canary: None,
                circuit: Circuit::closed(),
                last_rollback: None,
            }),
            next_version: AtomicU64::new(start + 1),
            cfg,
            threads,
            input_width,
            min_batch,
        }
    }

    /// Snapshot the live generation. Cheap (one `Arc` clone under a read
    /// lock); callers execute whole batches against the snapshot.
    pub fn current(&self) -> Arc<VersionedModel> {
        Arc::clone(&self.gens.read().unwrap().live)
    }

    /// The live deployment version.
    pub fn version(&self) -> u64 {
        self.gens.read().unwrap().live.version
    }

    /// The input width every generation of this slot accepts.
    pub fn input_width(&self) -> usize {
        self.input_width
    }

    /// The batch capacity every generation of this slot guarantees (the
    /// serving contract floor — a later generation may accept more).
    pub fn batch_capacity(&self) -> usize {
        self.min_batch
    }

    /// This slot's deployment-safety configuration.
    pub fn config(&self) -> &SlotConfig {
        &self.cfg
    }

    /// Number of previous generations currently retained for rollback.
    pub fn retained(&self) -> usize {
        self.gens.read().unwrap().history.len()
    }

    /// Human-readable record of the most recent rollback, if any.
    pub fn last_rollback(&self) -> Option<String> {
        self.health.lock().unwrap().last_rollback.clone()
    }

    /// Deploy state for operators: `"quarantined"` while the circuit is
    /// open, `"canary"` while a canary watch is active, else `"serving"`.
    pub fn state_name(&self) -> &'static str {
        let health = self.health.lock().unwrap();
        match health.circuit {
            Circuit::Open { .. } => "quarantined",
            Circuit::Closed { .. } => {
                if health.canary.is_some() {
                    "canary"
                } else {
                    "serving"
                }
            }
        }
    }

    /// Install `model` as the next generation and return exactly the
    /// generation that was installed (its version/precision — not
    /// whatever a concurrent later swap may have made current).
    /// Rejects models that would break the slot's serving contract.
    /// The displaced generation is retained for rollback; a swap also
    /// clears any active canary watch and closes the circuit breaker
    /// (the new generation earns its own health record).
    pub fn swap(&self, model: SparseModel, source: &str) -> Result<Arc<VersionedModel>> {
        self.install(model, source, None)
    }

    /// Install `model` as a **canary**: it serves traffic normally, but
    /// the slot watches its first `requests` requests and auto-rolls-back
    /// if more than `max_error_rate * requests` of them fail. Requires at
    /// least one retained generation to roll back to.
    pub fn swap_canary(
        &self,
        model: SparseModel,
        source: &str,
        requests: u64,
        max_error_rate: f64,
    ) -> Result<Arc<VersionedModel>> {
        ensure!(
            self.cfg.retain >= 1,
            "canary swap requires --retain-versions >= 1 (slot retains 0)"
        );
        ensure!(requests >= 1, "canary requests must be >= 1");
        ensure!(
            (0.0..=1.0).contains(&max_error_rate),
            "canary max_error_rate must be within 0..=1, got {max_error_rate}"
        );
        self.install(model, source, Some((requests, max_error_rate)))
    }

    fn install(
        &self,
        model: SparseModel,
        source: &str,
        canary: Option<(u64, f64)>,
    ) -> Result<Arc<VersionedModel>> {
        ensure!(
            model.inputs == self.input_width,
            "swap rejected: new model takes {} inputs, slot serves {}",
            model.inputs,
            self.input_width
        );
        ensure!(
            model.max_batch >= self.min_batch,
            "swap rejected: new model max_batch {} < slot batch capacity {}",
            model.max_batch,
            self.min_batch
        );
        // Version assignment and installation happen under one write
        // lock, so concurrent swaps install in strictly increasing
        // version order (a later version is never overwritten by an
        // earlier one). The health lock is taken inside the generation
        // lock (the one sanctioned order) so the canary watch starts
        // atomically with the install.
        let mut gens = self.gens.write().unwrap();
        let version = self.next_version.fetch_add(1, Ordering::SeqCst);
        let vm = Arc::new(VersionedModel {
            version,
            model,
            source: source.to_string(),
        });
        let displaced = std::mem::replace(&mut gens.live, Arc::clone(&vm));
        gens.history.push_back(displaced);
        while gens.history.len() > self.cfg.retain {
            gens.history.pop_front();
        }
        let mut health = self.health.lock().unwrap();
        health.canary = canary.map(|(budget, max_error_rate)| CanaryState {
            version,
            budget,
            max_error_rate,
            seen: 0,
            failed: 0,
        });
        health.circuit = Circuit::closed();
        Ok(vm)
    }

    /// Load a `.gsm` artifact, instantiate it with the slot's thread
    /// setting, and swap it in, returning the installed generation. The
    /// load and plan pack happen *before* the write lock is taken, so
    /// traffic never stalls on disk I/O.
    pub fn swap_path(&self, path: &str) -> Result<Arc<VersionedModel>> {
        let model = self.load_for_swap(path)?;
        self.swap(model, path)
    }

    /// [`ModelSlot::swap_path`] in canary mode.
    pub fn swap_path_canary(
        &self,
        path: &str,
        requests: u64,
        max_error_rate: f64,
    ) -> Result<Arc<VersionedModel>> {
        let model = self.load_for_swap(path)?;
        self.swap_canary(model, path, requests, max_error_rate)
    }

    fn load_for_swap(&self, path: &str) -> Result<SparseModel> {
        let artifact = ModelArtifact::load(path)?;
        artifact
            .instantiate(self.threads)
            .with_context(|| format!("instantiate artifact {path}"))
    }

    /// Restore the newest retained generation as live (the exact
    /// `Arc<VersionedModel>` that was displaced comes back: same version
    /// number, bit-identical logits). The displaced generation is
    /// discarded — **not** retained — so a bad deploy cannot oscillate
    /// back in through repeated rollbacks. Clears any canary watch and
    /// closes the circuit breaker.
    pub fn rollback(&self, reason: &str) -> Result<Arc<VersionedModel>> {
        match self.rollback_inner(None, reason)? {
            Some(vm) => Ok(vm),
            None => unreachable!("unconditional rollback never version-mismatches"),
        }
    }

    /// [`ModelSlot::rollback`] guarded on the live version: rolls back
    /// only if the live generation is still `expected_version`, returning
    /// `Ok(None)` if a concurrent swap already replaced it (the
    /// auto-rollback path must never clobber a newer deploy).
    pub fn rollback_if(
        &self,
        expected_version: u64,
        reason: &str,
    ) -> Result<Option<Arc<VersionedModel>>> {
        self.rollback_inner(Some(expected_version), reason)
    }

    fn rollback_inner(
        &self,
        expected_version: Option<u64>,
        reason: &str,
    ) -> Result<Option<Arc<VersionedModel>>> {
        let mut gens = self.gens.write().unwrap();
        if let Some(expected) = expected_version {
            if gens.live.version != expected {
                return Ok(None);
            }
        }
        let Some(prev) = gens.history.pop_back() else {
            bail!("nothing to roll back to: no retained previous version");
        };
        let from = gens.live.version;
        gens.live = Arc::clone(&prev);
        let mut health = self.health.lock().unwrap();
        health.canary = None;
        health.circuit = Circuit::closed();
        health.last_rollback = Some(format!("v{from} -> v{}: {reason}", prev.version));
        Ok(Some(prev))
    }

    /// Admission check for one infer request. Healthy slots admit;
    /// quarantined slots fast-fail, except that once per cool-down
    /// interval a single request is admitted as the half-open probe.
    pub fn admit(&self) -> Admission {
        let mut health = self.health.lock().unwrap();
        let cooldown = Duration::from_millis(self.cfg.quarantine_cooldown_ms.max(1));
        match &mut health.circuit {
            Circuit::Closed { .. } => Admission::Admit,
            Circuit::Open { since, last_probe } => {
                let now = Instant::now();
                let anchor = last_probe.unwrap_or(*since);
                let elapsed = now.saturating_duration_since(anchor);
                if elapsed >= cooldown {
                    *last_probe = Some(now);
                    Admission::AdmitProbe
                } else {
                    let remaining = (cooldown - elapsed).as_millis() as u64;
                    Admission::FastFail {
                        retry_in_ms: remaining.max(1),
                    }
                }
            }
        }
    }

    /// Record a batch outcome against the generation it executed on:
    /// `ok`/`err` request counts, and whether the batch carried the
    /// half-open probe. Returns the state transitions the outcome caused
    /// (canary promotion/rollback, quarantine trip, recovery) for the
    /// worker to surface.
    pub fn observe_execution(
        &self,
        version: u64,
        ok: u64,
        err: u64,
        probe: bool,
    ) -> Vec<SlotEvent> {
        enum CircuitNext {
            Close,
            Reopen,
            Trip(String),
        }
        let mut events = Vec::new();
        let mut rollback_req: Option<(u64, String)> = None;
        {
            let mut health = self.health.lock().unwrap();
            let next = match &mut health.circuit {
                Circuit::Open { .. } => {
                    // Only the probe's outcome moves an open circuit:
                    // pre-trip straggler batches finishing late must
                    // neither close nor re-trip it.
                    if probe && err == 0 && ok > 0 {
                        Some(CircuitNext::Close)
                    } else if probe && err > 0 {
                        Some(CircuitNext::Reopen)
                    } else {
                        None
                    }
                }
                Circuit::Closed { failures } => {
                    if self.cfg.quarantine_after > 0 && err > 0 {
                        let now = Instant::now();
                        let window = Duration::from_millis(self.cfg.quarantine_window_ms);
                        for _ in 0..err {
                            failures.push_back(now);
                            // The trip check only needs the most recent
                            // `quarantine_after` failures; cap the deque
                            // so a flood cannot grow it unboundedly.
                            if failures.len() > self.cfg.quarantine_after {
                                failures.pop_front();
                            }
                        }
                        while failures
                            .front()
                            .is_some_and(|t| now.saturating_duration_since(*t) > window)
                        {
                            failures.pop_front();
                        }
                        if failures.len() >= self.cfg.quarantine_after {
                            Some(CircuitNext::Trip(format!(
                                "{} failed requests within {}ms",
                                failures.len(),
                                self.cfg.quarantine_window_ms
                            )))
                        } else {
                            None
                        }
                    } else {
                        None
                    }
                }
            };
            match next {
                Some(CircuitNext::Close) => {
                    health.circuit = Circuit::closed();
                    events.push(SlotEvent::Recovered);
                }
                Some(CircuitNext::Reopen) => {
                    // Failed probe: restart the cool-down clock.
                    health.circuit = Circuit::Open {
                        since: Instant::now(),
                        last_probe: None,
                    };
                }
                Some(CircuitNext::Trip(reason)) => {
                    health.circuit = Circuit::Open {
                        since: Instant::now(),
                        last_probe: None,
                    };
                    events.push(SlotEvent::Quarantined { reason });
                }
                None => {}
            }
            if let Some(c) = health.canary.as_mut() {
                if c.version == version {
                    c.seen += ok + err;
                    c.failed += err;
                    if c.failed as f64 > c.max_error_rate * c.budget as f64 {
                        // Even if every remaining budgeted request were
                        // to succeed, the final error rate would exceed
                        // the threshold — trip early.
                        let reason = format!(
                            "canary failed: {}/{} requests errored (budget {}, max_error_rate {})",
                            c.failed, c.seen, c.budget, c.max_error_rate
                        );
                        rollback_req = Some((c.version, reason));
                        health.canary = None;
                    } else if c.seen >= c.budget {
                        events.push(SlotEvent::CanaryPromoted { version: c.version });
                        health.canary = None;
                    }
                }
            }
        }
        // Health lock released: rollback takes gens → health.
        if let Some((from, reason)) = rollback_req {
            // Ok(None) means a concurrent swap already replaced the
            // canary — nothing to do. Err cannot happen here: the canary
            // install retained its predecessor and any interleaved
            // rollback would have changed the live version first.
            if let Ok(Some(restored)) = self.rollback_if(from, &reason) {
                events.push(SlotEvent::CanaryRolledBack {
                    from,
                    to: restored.version,
                    reason,
                });
            }
        }
        events
    }
}

/// A registered slot plus its LRU recency stamp.
struct StoreEntry {
    slot: Arc<ModelSlot>,
    /// Logical-clock tick of the last [`ModelStore::acquire`] (or the
    /// registration itself). Larger = more recently used.
    last_used: AtomicU64,
}

/// Named registry of model slots with optional LRU capacity bounding.
pub struct ModelStore {
    slots: RwLock<BTreeMap<String, StoreEntry>>,
    /// Monotonic logical clock backing LRU recency (ticks on every
    /// acquire/registration; an atomic under the map's read lock, so the
    /// infer hot path never takes the write lock).
    clock: AtomicU64,
    /// Maximum resident models (0 = unbounded).
    max_models: usize,
    /// The slot name LRU eviction must never remove.
    pinned: String,
}

impl Default for ModelStore {
    fn default() -> ModelStore {
        ModelStore::new()
    }
}

impl ModelStore {
    /// Unbounded store with `"default"` pinned.
    pub fn new() -> ModelStore {
        ModelStore::with_capacity(0, "default")
    }

    /// A store holding at most `max_models` resident slots (0 =
    /// unbounded); `pinned` names the slot eviction must never remove.
    pub fn with_capacity(max_models: usize, pinned: &str) -> ModelStore {
        ModelStore {
            slots: RwLock::new(BTreeMap::new()),
            clock: AtomicU64::new(1),
            max_models,
            pinned: pinned.to_string(),
        }
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Register (or replace) a named slot, evicting least-recently-used
    /// cold models if the capacity bound is exceeded. Returns the names
    /// evicted to make room (empty when under capacity). Fails — and
    /// leaves the store unchanged — if capacity cannot be honored
    /// without evicting the pinned slot or `name` itself.
    pub fn register(&self, name: &str, slot: Arc<ModelSlot>) -> Result<Vec<String>> {
        let mut map = self.slots.write().unwrap();
        self.insert_locked(&mut map, name, slot)
    }

    /// Register `name` only if it is not already resident — one atomic
    /// check+insert under the write lock, so two concurrent loads of the
    /// same fresh name cannot both "win". `Ok(None)` means the name is
    /// already resident (the caller should swap into the existing slot,
    /// which applies the serving-contract check); `Ok(Some(evicted))` is
    /// a successful fresh registration.
    pub fn register_new(&self, name: &str, slot: Arc<ModelSlot>) -> Result<Option<Vec<String>>> {
        let mut map = self.slots.write().unwrap();
        if map.contains_key(name) {
            return Ok(None);
        }
        self.insert_locked(&mut map, name, slot).map(Some)
    }

    /// The single insert point behind [`ModelStore::register`] and
    /// [`ModelStore::register_new`]: evict-then-insert under the
    /// caller's write lock.
    fn insert_locked(
        &self,
        map: &mut BTreeMap<String, StoreEntry>,
        name: &str,
        slot: Arc<ModelSlot>,
    ) -> Result<Vec<String>> {
        let replacing = map.contains_key(name);
        let mut evicted = Vec::new();
        if self.max_models > 0 && !replacing {
            // Evict coldest non-pinned entries until one seat is free.
            while map.len() + 1 > self.max_models {
                let coldest = map
                    .iter()
                    .filter(|(n, _)| **n != self.pinned)
                    .min_by_key(|(_, e)| e.last_used.load(Ordering::Relaxed))
                    .map(|(n, _)| n.clone());
                match coldest {
                    Some(n) => {
                        map.remove(&n);
                        evicted.push(n);
                    }
                    None => bail!(
                        "cannot load \"{name}\": store capacity {} is exhausted by the pinned \
                         default model",
                        self.max_models
                    ),
                }
            }
        }
        map.insert(
            name.to_string(),
            StoreEntry {
                slot,
                last_used: AtomicU64::new(self.tick()),
            },
        );
        Ok(evicted)
    }

    /// Look up a slot by name without touching its recency (admin reads:
    /// `models`, `stats`, swap routing).
    pub fn get(&self, name: &str) -> Option<Arc<ModelSlot>> {
        self.slots
            .read()
            .unwrap()
            .get(name)
            .map(|e| Arc::clone(&e.slot))
    }

    /// Look up a slot for an infer request: returns it *and* bumps its
    /// LRU recency (touch-on-infer). Read lock + one atomic store — the
    /// hot path never contends with registration.
    pub fn acquire(&self, name: &str) -> Option<Arc<ModelSlot>> {
        let map = self.slots.read().unwrap();
        let entry = map.get(name)?;
        entry.last_used.store(self.tick(), Ordering::Relaxed);
        Some(Arc::clone(&entry.slot))
    }

    /// Remove a slot. Fails on the pinned default or an unknown name.
    /// Graceful: in-flight holders of the slot `Arc` keep serving.
    pub fn unload(&self, name: &str) -> Result<()> {
        ensure!(
            name != self.pinned,
            "cannot unload \"{name}\": it is the pinned default model"
        );
        let removed = self.slots.write().unwrap().remove(name);
        ensure!(removed.is_some(), "unknown model \"{name}\"");
        Ok(())
    }

    /// Registered slot names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.slots.read().unwrap().keys().cloned().collect()
    }

    /// Number of resident models.
    pub fn len(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.read().unwrap().is_empty()
    }

    /// The capacity bound (0 = unbounded).
    pub fn max_models(&self) -> usize {
        self.max_models
    }

    /// The slot name eviction never removes.
    pub fn pinned_name(&self) -> &str {
        &self.pinned
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::pattern::Pattern;
    use crate::testing::model::{build_random_model, ModelSpec};

    fn spec(seed: u64) -> ModelSpec {
        ModelSpec {
            inputs: 8,
            hidden: 32,
            outputs: 16,
            max_batch: 4,
            pattern: Pattern::Gs { b: 8, k: 8 },
            sparsity: 0.75,
            threads: 1,
            seed,
            ..ModelSpec::default()
        }
    }

    fn model(seed: u64) -> SparseModel {
        build_random_model(&spec(seed)).unwrap().model
    }

    fn slot(seed: u64) -> Arc<ModelSlot> {
        Arc::new(ModelSlot::new(model(seed), &format!("inline-{seed}"), 1))
    }

    #[test]
    fn slot_versions_advance_and_snapshots_pin() {
        let slot = ModelSlot::new(model(1), "inline", 1);
        assert_eq!(slot.version(), 1);
        let pinned = slot.current();

        let vm = slot.swap(model(2), "inline-2").unwrap();
        assert_eq!(vm.version, 2);
        assert_eq!(slot.version(), 2);
        // The old snapshot still serves version 1.
        assert_eq!(pinned.version, 1);
        assert_eq!(slot.current().source, "inline-2");
    }

    #[test]
    fn slot_rejects_contract_breaking_models() {
        let slot = ModelSlot::new(model(1), "inline", 1);
        // Different input width.
        let narrow = build_random_model(&ModelSpec { inputs: 6, ..spec(3) }).unwrap().model;
        assert!(slot.swap(narrow, "bad").is_err());
        // Smaller batch capacity.
        let small = build_random_model(&ModelSpec { max_batch: 2, ..spec(4) }).unwrap().model;
        assert!(slot.swap(small, "bad").is_err());
        assert_eq!(slot.version(), 1, "failed swaps must not bump the version");
        assert_eq!(slot.retained(), 0, "failed swaps must not grow history");
    }

    #[test]
    fn swap_path_surfaces_load_errors() {
        let slot = ModelSlot::new(model(1), "inline", 1);
        let err = slot.swap_path("/nonexistent/model.gsm").unwrap_err();
        assert!(format!("{err:#}").contains("model.gsm"), "{err:#}");
        assert_eq!(slot.version(), 1);
    }

    #[test]
    fn retention_is_bounded() {
        let slot = ModelSlot::new(model(1), "inline-1", 1); // retain = 2
        for seed in 2..=5 {
            slot.swap(model(seed), &format!("inline-{seed}")).unwrap();
        }
        assert_eq!(slot.version(), 5);
        assert_eq!(slot.retained(), 2, "history must be capped at retain");
        // Rollback walks back through exactly the retained generations.
        assert_eq!(slot.rollback("op request").unwrap().version, 4);
        assert_eq!(slot.rollback("op request").unwrap().version, 3);
        let err = slot.rollback("op request").unwrap_err();
        assert!(format!("{err:#}").contains("nothing to roll back"), "{err:#}");
    }

    #[test]
    fn rollback_restores_bit_identical_generation() {
        let input = vec![0.25_f32; 8];
        let slot = ModelSlot::new(model(1), "inline-1", 1);
        let want = slot.current().model.infer_batch(&[input.clone()]).unwrap();
        slot.swap(model(2), "inline-2").unwrap();
        let swapped = slot.current().model.infer_batch(&[input.clone()]).unwrap();
        assert_ne!(want, swapped, "distinct seeds must produce distinct logits");

        let restored = slot.rollback("bad deploy").unwrap();
        assert_eq!(restored.version, 1, "the exact prior generation returns");
        assert_eq!(slot.version(), 1);
        let got = slot.current().model.infer_batch(&[input]).unwrap();
        assert_eq!(got, want, "rollback must restore bit-identical serving");
        let note = slot.last_rollback().expect("rollback recorded");
        assert!(note.contains("v2 -> v1"), "{note}");
        assert!(note.contains("bad deploy"), "{note}");
        // The rolled-back (bad) generation is discarded, not retained.
        assert_eq!(slot.retained(), 0);
        // Future swaps keep strictly increasing versions.
        assert_eq!(slot.swap(model(3), "inline-3").unwrap().version, 3);
    }

    #[test]
    fn rollback_if_guards_concurrent_swaps() {
        let slot = ModelSlot::new(model(1), "inline-1", 1);
        slot.swap(model(2), "inline-2").unwrap();
        // A stale auto-rollback aimed at v2 after v3 deployed is a no-op.
        slot.swap(model(3), "inline-3").unwrap();
        assert!(slot.rollback_if(2, "stale").unwrap().is_none());
        assert_eq!(slot.version(), 3);
        // Aimed at the live version, it fires.
        let restored = slot.rollback_if(3, "fresh").unwrap().unwrap();
        assert_eq!(restored.version, 2);
    }

    #[test]
    fn canary_requires_retention() {
        let cfg = SlotConfig { retain: 0, ..SlotConfig::default() };
        let slot = ModelSlot::with_config(model(1), "inline-1", 1, cfg);
        let err = slot.swap_canary(model(2), "inline-2", 8, 0.5).unwrap_err();
        assert!(format!("{err:#}").contains("retain"), "{err:#}");
        assert_eq!(slot.version(), 1);
    }

    #[test]
    fn canary_promotes_after_clean_budget() {
        let slot = ModelSlot::new(model(1), "inline-1", 1);
        let vm = slot.swap_canary(model(2), "inline-2", 4, 0.25).unwrap();
        assert_eq!(vm.version, 2);
        assert_eq!(slot.state_name(), "canary");
        assert!(slot.observe_execution(2, 2, 0, false).is_empty());
        let events = slot.observe_execution(2, 2, 0, false);
        assert_eq!(events, vec![SlotEvent::CanaryPromoted { version: 2 }]);
        assert_eq!(slot.state_name(), "serving");
        assert_eq!(slot.version(), 2, "promotion keeps the canary serving");
        // Further outcomes are no longer watched.
        assert!(slot.observe_execution(2, 0, 4, false).is_empty());
    }

    #[test]
    fn canary_trips_and_rolls_back() {
        let slot = ModelSlot::new(model(1), "inline-1", 1);
        slot.swap_canary(model(2), "inline-2", 8, 0.25).unwrap();
        // 2 failures: 2 > 0.25 * 8 — even 6 straight successes could not
        // bring the final rate under the threshold, so trip now.
        assert!(slot.observe_execution(2, 1, 1, false).is_empty());
        let events = slot.observe_execution(2, 0, 1, false);
        assert_eq!(events.len(), 1, "{events:?}");
        match &events[0] {
            SlotEvent::CanaryRolledBack { from, to, reason } => {
                assert_eq!((*from, *to), (2, 1));
                assert!(reason.contains("canary failed"), "{reason}");
            }
            other => panic!("expected rollback, got {other:?}"),
        }
        assert_eq!(slot.version(), 1);
        assert_eq!(slot.state_name(), "serving");
        assert!(slot.last_rollback().unwrap().contains("canary failed"));
    }

    #[test]
    fn canary_ignores_other_generations() {
        let slot = ModelSlot::new(model(1), "inline-1", 1);
        slot.swap_canary(model(2), "inline-2", 2, 0.0).unwrap();
        // Straggler batches from v1 finishing with errors must not count
        // against v2's canary watch.
        assert!(slot.observe_execution(1, 0, 5, false).is_empty());
        assert_eq!(slot.state_name(), "canary");
        let events = slot.observe_execution(2, 2, 0, false);
        assert_eq!(events, vec![SlotEvent::CanaryPromoted { version: 2 }]);
    }

    #[test]
    fn quarantine_trips_probes_and_recovers() {
        let cfg = SlotConfig {
            quarantine_after: 3,
            quarantine_window_ms: 10_000,
            quarantine_cooldown_ms: 20,
            ..SlotConfig::default()
        };
        let slot = ModelSlot::with_config(model(1), "inline-1", 1, cfg);
        assert_eq!(slot.admit(), Admission::Admit);
        assert!(slot.observe_execution(1, 0, 2, false).is_empty());
        let events = slot.observe_execution(1, 0, 1, false);
        assert!(
            matches!(&events[0], SlotEvent::Quarantined { reason } if reason.contains("3")),
            "{events:?}"
        );
        assert_eq!(slot.state_name(), "quarantined");
        // Inside the cool-down: fast-fail with a retry hint.
        match slot.admit() {
            Admission::FastFail { retry_in_ms } => assert!(retry_in_ms <= 20),
            other => panic!("expected fast-fail, got {other:?}"),
        }
        // A straggler success (not the probe) must not close the circuit.
        assert!(slot.observe_execution(1, 4, 0, false).is_empty());
        assert_eq!(slot.state_name(), "quarantined");
        std::thread::sleep(Duration::from_millis(25));
        // Cool-down elapsed: exactly one probe is admitted per interval.
        assert_eq!(slot.admit(), Admission::AdmitProbe);
        assert!(matches!(slot.admit(), Admission::FastFail { .. }));
        // Failed probe keeps the circuit open and restarts the clock.
        assert!(slot.observe_execution(1, 0, 1, true).is_empty());
        assert_eq!(slot.state_name(), "quarantined");
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(slot.admit(), Admission::AdmitProbe);
        let events = slot.observe_execution(1, 1, 0, true);
        assert_eq!(events, vec![SlotEvent::Recovered]);
        assert_eq!(slot.state_name(), "serving");
        assert_eq!(slot.admit(), Admission::Admit);
    }

    #[test]
    fn swap_clears_quarantine() {
        let cfg = SlotConfig {
            quarantine_after: 1,
            quarantine_cooldown_ms: 60_000,
            ..SlotConfig::default()
        };
        let slot = ModelSlot::with_config(model(1), "inline-1", 1, cfg);
        slot.observe_execution(1, 0, 1, false);
        assert_eq!(slot.state_name(), "quarantined");
        // Deploying a replacement gives the slot a fresh health record.
        slot.swap(model(2), "inline-2").unwrap();
        assert_eq!(slot.state_name(), "serving");
        assert_eq!(slot.admit(), Admission::Admit);
    }

    #[test]
    fn manifest_replay_restores_start_version() {
        let cfg = SlotConfig { start_version: 7, ..SlotConfig::default() };
        let slot = ModelSlot::with_config(model(1), "replayed.gsm", 1, cfg);
        assert_eq!(slot.version(), 7);
        assert_eq!(slot.swap(model(2), "next.gsm").unwrap().version, 8);
    }

    #[test]
    fn store_registers_and_lists() {
        let store = ModelStore::new();
        assert!(store.get("default").is_none());
        store.register("default", slot(1)).unwrap();
        assert!(store.get("default").is_some());
        assert_eq!(store.names(), vec!["default".to_string()]);
        assert_eq!(store.len(), 1);
        assert_eq!(store.max_models(), 0, "ModelStore::new is unbounded");
    }

    #[test]
    fn lru_recency_updated_on_acquire() {
        let store = ModelStore::with_capacity(3, "default");
        store.register("default", slot(1)).unwrap();
        store.register("a", slot(2)).unwrap();
        store.register("b", slot(3)).unwrap();
        // "a" is older than "b" by registration; an infer-path acquire
        // of "a" must make "b" the eviction candidate.
        assert!(store.acquire("a").is_some());
        let evicted = store.register("c", slot(4)).unwrap();
        assert_eq!(evicted, vec!["b".to_string()]);
        assert_eq!(
            store.names(),
            vec!["a".to_string(), "c".to_string(), "default".to_string()]
        );
        // get() must NOT touch recency: read "c" via get, then acquire
        // "a"; the next eviction takes "c" (get left it cold)… but "c"
        // was registered after the acquire of "a", so acquire "a" again
        // to make the ordering unambiguous.
        assert!(store.get("c").is_some());
        assert!(store.acquire("a").is_some());
        let evicted = store.register("d", slot(5)).unwrap();
        assert_eq!(evicted, vec!["c".to_string()], "get() must not bump recency");
    }

    #[test]
    fn pinned_default_survives_pressure() {
        let store = ModelStore::with_capacity(2, "default");
        store.register("default", slot(1)).unwrap();
        store.register("a", slot(2)).unwrap();
        // Even though "default" is the coldest entry (never acquired,
        // registered first), pressure evicts "a", not the pinned slot.
        for (i, name) in ["b", "c", "d"].iter().enumerate() {
            let evicted = store.register(name, slot(10 + i as u64)).unwrap();
            assert_eq!(evicted.len(), 1);
            assert_ne!(evicted[0], "default", "pinned slot must never be evicted");
            assert!(store.get("default").is_some());
        }
        assert_eq!(store.len(), 2);
    }

    #[test]
    fn evicted_slot_stays_alive_for_holders() {
        let store = ModelStore::with_capacity(2, "default");
        store.register("default", slot(1)).unwrap();
        store.register("a", slot(2)).unwrap();
        // An in-flight request holds the slot (and a batch snapshot).
        let held = store.acquire("a").unwrap();
        let snapshot = held.current();
        let evicted = store.register("b", slot(3)).unwrap();
        assert_eq!(evicted, vec!["a".to_string()]);
        assert!(store.get("a").is_none(), "registry no longer serves a");
        // …but the holder's Arc still executes fine.
        assert_eq!(snapshot.version, 1);
        let out = snapshot.model.infer_batch(&[vec![0.5; 8]]).unwrap();
        assert_eq!(out[0].len(), 16);
    }

    #[test]
    fn capacity_one_pins_the_default() {
        let store = ModelStore::with_capacity(1, "default");
        store.register("default", slot(1)).unwrap();
        // No evictable seat: the only resident is pinned.
        let err = store.register("a", slot(2)).unwrap_err();
        assert!(format!("{err:#}").contains("capacity 1"), "{err:#}");
        assert_eq!(store.names(), vec!["default".to_string()]);
        // Replacing the pinned slot in place is still allowed (it is a
        // replace, not a second resident).
        store.register("default", slot(3)).unwrap();
        assert_eq!(store.len(), 1);
        assert_eq!(store.get("default").unwrap().current().source, "inline-3");
    }

    #[test]
    fn capacity_one_unpinned_rotates() {
        // Capacity 1 with the pinned name never registered: each load
        // evicts the previous resident.
        let store = ModelStore::with_capacity(1, "default");
        store.register("a", slot(1)).unwrap();
        let evicted = store.register("b", slot(2)).unwrap();
        assert_eq!(evicted, vec!["a".to_string()]);
        assert_eq!(store.names(), vec!["b".to_string()]);
    }

    #[test]
    fn unload_refuses_pinned_and_unknown() {
        let store = ModelStore::with_capacity(0, "default");
        store.register("default", slot(1)).unwrap();
        store.register("a", slot(2)).unwrap();
        assert!(store.unload("default").is_err());
        assert!(store.unload("nope").is_err());
        store.unload("a").unwrap();
        assert_eq!(store.names(), vec!["default".to_string()]);
    }

    #[test]
    fn evict_then_reload_restores_serving() {
        let store = ModelStore::with_capacity(2, "default");
        store.register("default", slot(1)).unwrap();
        store.register("a", slot(7)).unwrap();
        let want = store
            .acquire("a")
            .unwrap()
            .current()
            .model
            .infer_batch(&[vec![0.25; 8]])
            .unwrap();
        // Pressure "a" out, then reload the same model under the same
        // name: serving must be bit-identical to before the eviction.
        store.register("b", slot(8)).unwrap();
        assert!(store.get("a").is_none());
        let evicted = store.register("a", slot(7)).unwrap();
        assert_eq!(evicted, vec!["b".to_string()]);
        let got = store
            .acquire("a")
            .unwrap()
            .current()
            .model
            .infer_batch(&[vec![0.25; 8]])
            .unwrap();
        assert_eq!(got, want, "evict → reload must restore bit-identical serving");
    }
}
