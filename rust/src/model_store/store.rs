//! Versioned, `Arc`-swappable model slots and the named slot registry.
//!
//! A [`ModelSlot`] is the coordinator-level unit of zero-downtime
//! deployment: serving workers take an `Arc` snapshot of the current
//! [`VersionedModel`] once per batch, so a [`ModelSlot::swap`] installed
//! under live traffic changes which model *future* batches execute while
//! every in-flight batch keeps (and finishes on) the version it started
//! with — no dropped connections, no torn batches, never two versions
//! inside one batch. The displaced model is freed when its last in-flight
//! batch drops its `Arc`.
//!
//! [`ModelStore`] is a named registry of slots — one slot per deployed
//! model today (`"default"` for the TCP server), the substrate for
//! multi-model and sharded serving later.

use super::artifact::ModelArtifact;
use crate::coordinator::SparseModel;
use crate::kernels::exec::PlanPrecision;
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// One deployed model generation.
pub struct VersionedModel {
    /// Monotonic deployment version, starting at 1.
    pub version: u64,
    pub model: SparseModel,
    /// Where this generation came from (artifact path, "inline", …).
    pub source: String,
}

impl VersionedModel {
    /// Packed-plan precision of this generation (None for pjrt models).
    pub fn precision(&self) -> Option<PlanPrecision> {
        self.model.precision()
    }
}

/// An atomically swappable slot holding the live model generation.
pub struct ModelSlot {
    current: RwLock<Arc<VersionedModel>>,
    next_version: AtomicU64,
    /// Kernel threads for models instantiated by [`ModelSlot::swap_path`]
    /// (0 = auto-detect, per [`SparseModel::native`]).
    threads: usize,
    /// Frozen serving contract: every swapped-in model must accept the
    /// same input width and at least the original batch capacity, so the
    /// TCP front-end's admission checks stay valid across deployments.
    input_width: usize,
    min_batch: usize,
}

impl ModelSlot {
    /// Create a slot serving `model` as version 1. `threads` is the
    /// kernel-thread setting future [`ModelSlot::swap_path`] loads
    /// instantiate with.
    pub fn new(model: SparseModel, source: &str, threads: usize) -> ModelSlot {
        let input_width = model.inputs;
        let min_batch = model.max_batch;
        ModelSlot {
            current: RwLock::new(Arc::new(VersionedModel {
                version: 1,
                model,
                source: source.to_string(),
            })),
            next_version: AtomicU64::new(2),
            threads,
            input_width,
            min_batch,
        }
    }

    /// Snapshot the live generation. Cheap (one `Arc` clone under a read
    /// lock); callers execute whole batches against the snapshot.
    pub fn current(&self) -> Arc<VersionedModel> {
        Arc::clone(&self.current.read().unwrap())
    }

    /// The live deployment version.
    pub fn version(&self) -> u64 {
        self.current.read().unwrap().version
    }

    /// The input width every generation of this slot accepts.
    pub fn input_width(&self) -> usize {
        self.input_width
    }

    /// Install `model` as the next generation and return exactly the
    /// generation that was installed (its version/precision — not
    /// whatever a concurrent later swap may have made current).
    /// Rejects models that would break the slot's serving contract.
    pub fn swap(&self, model: SparseModel, source: &str) -> Result<Arc<VersionedModel>> {
        ensure!(
            model.inputs == self.input_width,
            "swap rejected: new model takes {} inputs, slot serves {}",
            model.inputs,
            self.input_width
        );
        ensure!(
            model.max_batch >= self.min_batch,
            "swap rejected: new model max_batch {} < slot batch capacity {}",
            model.max_batch,
            self.min_batch
        );
        // Version assignment and installation happen under one write
        // lock, so concurrent swaps install in strictly increasing
        // version order (a later version is never overwritten by an
        // earlier one).
        let mut cur = self.current.write().unwrap();
        let version = self.next_version.fetch_add(1, Ordering::SeqCst);
        let vm = Arc::new(VersionedModel {
            version,
            model,
            source: source.to_string(),
        });
        *cur = Arc::clone(&vm);
        Ok(vm)
    }

    /// Load a `.gsm` artifact, instantiate it with the slot's thread
    /// setting, and swap it in, returning the installed generation. The
    /// load and plan pack happen *before* the write lock is taken, so
    /// traffic never stalls on disk I/O.
    pub fn swap_path(&self, path: &str) -> Result<Arc<VersionedModel>> {
        let artifact = ModelArtifact::load(path)?;
        let model = artifact
            .instantiate(self.threads)
            .with_context(|| format!("instantiate artifact {path}"))?;
        self.swap(model, path)
    }
}

/// Named registry of model slots.
#[derive(Default)]
pub struct ModelStore {
    slots: RwLock<BTreeMap<String, Arc<ModelSlot>>>,
}

impl ModelStore {
    pub fn new() -> ModelStore {
        ModelStore::default()
    }

    /// Register (or replace) a named slot.
    pub fn register(&self, name: &str, slot: Arc<ModelSlot>) {
        self.slots.write().unwrap().insert(name.to_string(), slot);
    }

    /// Look up a slot by name.
    pub fn get(&self, name: &str) -> Option<Arc<ModelSlot>> {
        self.slots.read().unwrap().get(name).cloned()
    }

    /// Registered slot names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.slots.read().unwrap().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::pattern::Pattern;
    use crate::testing::model::{build_random_model, ModelSpec};

    fn spec(seed: u64) -> ModelSpec {
        ModelSpec {
            inputs: 8,
            hidden: 32,
            outputs: 16,
            max_batch: 4,
            pattern: Pattern::Gs { b: 8, k: 8 },
            sparsity: 0.75,
            threads: 1,
            seed,
            ..ModelSpec::default()
        }
    }

    #[test]
    fn slot_versions_advance_and_snapshots_pin() {
        let m1 = build_random_model(&spec(1)).unwrap().model;
        let slot = ModelSlot::new(m1, "inline", 1);
        assert_eq!(slot.version(), 1);
        let pinned = slot.current();

        let m2 = build_random_model(&spec(2)).unwrap().model;
        let vm = slot.swap(m2, "inline-2").unwrap();
        assert_eq!(vm.version, 2);
        assert_eq!(slot.version(), 2);
        // The old snapshot still serves version 1.
        assert_eq!(pinned.version, 1);
        assert_eq!(slot.current().source, "inline-2");
    }

    #[test]
    fn slot_rejects_contract_breaking_models() {
        let m1 = build_random_model(&spec(1)).unwrap().model;
        let slot = ModelSlot::new(m1, "inline", 1);
        // Different input width.
        let narrow = build_random_model(&ModelSpec { inputs: 6, ..spec(3) }).unwrap().model;
        assert!(slot.swap(narrow, "bad").is_err());
        // Smaller batch capacity.
        let small = build_random_model(&ModelSpec { max_batch: 2, ..spec(4) }).unwrap().model;
        assert!(slot.swap(small, "bad").is_err());
        assert_eq!(slot.version(), 1, "failed swaps must not bump the version");
    }

    #[test]
    fn swap_path_surfaces_load_errors() {
        let m1 = build_random_model(&spec(1)).unwrap().model;
        let slot = ModelSlot::new(m1, "inline", 1);
        let err = slot.swap_path("/nonexistent/model.gsm").unwrap_err();
        assert!(format!("{err:#}").contains("model.gsm"), "{err:#}");
        assert_eq!(slot.version(), 1);
    }

    #[test]
    fn store_registers_and_lists() {
        let store = ModelStore::new();
        assert!(store.get("default").is_none());
        let m = build_random_model(&spec(1)).unwrap().model;
        store.register("default", Arc::new(ModelSlot::new(m, "inline", 1)));
        assert!(store.get("default").is_some());
        assert_eq!(store.names(), vec!["default".to_string()]);
    }
}
