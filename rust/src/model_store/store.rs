//! Versioned, `Arc`-swappable model slots and the named slot registry.
//!
//! A [`ModelSlot`] is the coordinator-level unit of zero-downtime
//! deployment: serving workers take an `Arc` snapshot of the current
//! [`VersionedModel`] once per batch, so a [`ModelSlot::swap`] installed
//! under live traffic changes which model *future* batches execute while
//! every in-flight batch keeps (and finishes on) the version it started
//! with — no dropped connections, no torn batches, never two versions
//! inside one batch. The displaced model is freed when its last in-flight
//! batch drops its `Arc`.
//!
//! [`ModelStore`] is the named registry of slots behind multi-model
//! serving: requests route by slot name, [`ModelStore::acquire`] bumps a
//! slot's recency on every routed infer, and a capacity bound
//! (`max_models`) triggers **LRU eviction of cold models** when a new one
//! is registered. The pinned default slot is never evicted, and eviction
//! is graceful: it only drops the registry's `Arc` — requests and batches
//! already holding the slot (or a `VersionedModel` snapshot) finish
//! undisturbed.

use super::artifact::ModelArtifact;
use crate::coordinator::SparseModel;
use crate::kernels::exec::PlanPrecision;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// One deployed model generation.
pub struct VersionedModel {
    /// Monotonic deployment version, starting at 1.
    pub version: u64,
    pub model: SparseModel,
    /// Where this generation came from (artifact path, "inline", …).
    pub source: String,
}

impl VersionedModel {
    /// Packed-plan precision of this generation (None for pjrt models).
    pub fn precision(&self) -> Option<PlanPrecision> {
        self.model.precision()
    }
}

/// An atomically swappable slot holding the live model generation.
pub struct ModelSlot {
    current: RwLock<Arc<VersionedModel>>,
    next_version: AtomicU64,
    /// Kernel threads for models instantiated by [`ModelSlot::swap_path`]
    /// (0 = auto-detect, per [`SparseModel::native`]).
    threads: usize,
    /// Frozen serving contract: every swapped-in model must accept the
    /// same input width and at least the original batch capacity, so the
    /// TCP front-end's admission checks stay valid across deployments.
    input_width: usize,
    min_batch: usize,
}

impl ModelSlot {
    /// Create a slot serving `model` as version 1. `threads` is the
    /// kernel-thread setting future [`ModelSlot::swap_path`] loads
    /// instantiate with.
    pub fn new(model: SparseModel, source: &str, threads: usize) -> ModelSlot {
        let input_width = model.inputs;
        let min_batch = model.max_batch;
        ModelSlot {
            current: RwLock::new(Arc::new(VersionedModel {
                version: 1,
                model,
                source: source.to_string(),
            })),
            next_version: AtomicU64::new(2),
            threads,
            input_width,
            min_batch,
        }
    }

    /// Snapshot the live generation. Cheap (one `Arc` clone under a read
    /// lock); callers execute whole batches against the snapshot.
    pub fn current(&self) -> Arc<VersionedModel> {
        Arc::clone(&self.current.read().unwrap())
    }

    /// The live deployment version.
    pub fn version(&self) -> u64 {
        self.current.read().unwrap().version
    }

    /// The input width every generation of this slot accepts.
    pub fn input_width(&self) -> usize {
        self.input_width
    }

    /// The batch capacity every generation of this slot guarantees (the
    /// serving contract floor — a later generation may accept more).
    pub fn batch_capacity(&self) -> usize {
        self.min_batch
    }

    /// Install `model` as the next generation and return exactly the
    /// generation that was installed (its version/precision — not
    /// whatever a concurrent later swap may have made current).
    /// Rejects models that would break the slot's serving contract.
    pub fn swap(&self, model: SparseModel, source: &str) -> Result<Arc<VersionedModel>> {
        ensure!(
            model.inputs == self.input_width,
            "swap rejected: new model takes {} inputs, slot serves {}",
            model.inputs,
            self.input_width
        );
        ensure!(
            model.max_batch >= self.min_batch,
            "swap rejected: new model max_batch {} < slot batch capacity {}",
            model.max_batch,
            self.min_batch
        );
        // Version assignment and installation happen under one write
        // lock, so concurrent swaps install in strictly increasing
        // version order (a later version is never overwritten by an
        // earlier one).
        let mut cur = self.current.write().unwrap();
        let version = self.next_version.fetch_add(1, Ordering::SeqCst);
        let vm = Arc::new(VersionedModel {
            version,
            model,
            source: source.to_string(),
        });
        *cur = Arc::clone(&vm);
        Ok(vm)
    }

    /// Load a `.gsm` artifact, instantiate it with the slot's thread
    /// setting, and swap it in, returning the installed generation. The
    /// load and plan pack happen *before* the write lock is taken, so
    /// traffic never stalls on disk I/O.
    pub fn swap_path(&self, path: &str) -> Result<Arc<VersionedModel>> {
        let artifact = ModelArtifact::load(path)?;
        let model = artifact
            .instantiate(self.threads)
            .with_context(|| format!("instantiate artifact {path}"))?;
        self.swap(model, path)
    }
}

/// A registered slot plus its LRU recency stamp.
struct StoreEntry {
    slot: Arc<ModelSlot>,
    /// Logical-clock tick of the last [`ModelStore::acquire`] (or the
    /// registration itself). Larger = more recently used.
    last_used: AtomicU64,
}

/// Named registry of model slots with optional LRU capacity bounding.
pub struct ModelStore {
    slots: RwLock<BTreeMap<String, StoreEntry>>,
    /// Monotonic logical clock backing LRU recency (ticks on every
    /// acquire/registration; an atomic under the map's read lock, so the
    /// infer hot path never takes the write lock).
    clock: AtomicU64,
    /// Maximum resident models (0 = unbounded).
    max_models: usize,
    /// The slot name LRU eviction must never remove.
    pinned: String,
}

impl Default for ModelStore {
    fn default() -> ModelStore {
        ModelStore::new()
    }
}

impl ModelStore {
    /// Unbounded store with `"default"` pinned.
    pub fn new() -> ModelStore {
        ModelStore::with_capacity(0, "default")
    }

    /// A store holding at most `max_models` resident slots (0 =
    /// unbounded); `pinned` names the slot eviction must never remove.
    pub fn with_capacity(max_models: usize, pinned: &str) -> ModelStore {
        ModelStore {
            slots: RwLock::new(BTreeMap::new()),
            clock: AtomicU64::new(1),
            max_models,
            pinned: pinned.to_string(),
        }
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Register (or replace) a named slot, evicting least-recently-used
    /// cold models if the capacity bound is exceeded. Returns the names
    /// evicted to make room (empty when under capacity). Fails — and
    /// leaves the store unchanged — if capacity cannot be honored
    /// without evicting the pinned slot or `name` itself.
    pub fn register(&self, name: &str, slot: Arc<ModelSlot>) -> Result<Vec<String>> {
        let mut map = self.slots.write().unwrap();
        self.insert_locked(&mut map, name, slot)
    }

    /// Register `name` only if it is not already resident — one atomic
    /// check+insert under the write lock, so two concurrent loads of the
    /// same fresh name cannot both "win". `Ok(None)` means the name is
    /// already resident (the caller should swap into the existing slot,
    /// which applies the serving-contract check); `Ok(Some(evicted))` is
    /// a successful fresh registration.
    pub fn register_new(&self, name: &str, slot: Arc<ModelSlot>) -> Result<Option<Vec<String>>> {
        let mut map = self.slots.write().unwrap();
        if map.contains_key(name) {
            return Ok(None);
        }
        self.insert_locked(&mut map, name, slot).map(Some)
    }

    /// The single insert point behind [`ModelStore::register`] and
    /// [`ModelStore::register_new`]: evict-then-insert under the
    /// caller's write lock.
    fn insert_locked(
        &self,
        map: &mut BTreeMap<String, StoreEntry>,
        name: &str,
        slot: Arc<ModelSlot>,
    ) -> Result<Vec<String>> {
        let replacing = map.contains_key(name);
        let mut evicted = Vec::new();
        if self.max_models > 0 && !replacing {
            // Evict coldest non-pinned entries until one seat is free.
            while map.len() + 1 > self.max_models {
                let coldest = map
                    .iter()
                    .filter(|(n, _)| **n != self.pinned)
                    .min_by_key(|(_, e)| e.last_used.load(Ordering::Relaxed))
                    .map(|(n, _)| n.clone());
                match coldest {
                    Some(n) => {
                        map.remove(&n);
                        evicted.push(n);
                    }
                    None => bail!(
                        "cannot load \"{name}\": store capacity {} is exhausted by the pinned \
                         default model",
                        self.max_models
                    ),
                }
            }
        }
        map.insert(
            name.to_string(),
            StoreEntry {
                slot,
                last_used: AtomicU64::new(self.tick()),
            },
        );
        Ok(evicted)
    }

    /// Look up a slot by name without touching its recency (admin reads:
    /// `models`, `stats`, swap routing).
    pub fn get(&self, name: &str) -> Option<Arc<ModelSlot>> {
        self.slots
            .read()
            .unwrap()
            .get(name)
            .map(|e| Arc::clone(&e.slot))
    }

    /// Look up a slot for an infer request: returns it *and* bumps its
    /// LRU recency (touch-on-infer). Read lock + one atomic store — the
    /// hot path never contends with registration.
    pub fn acquire(&self, name: &str) -> Option<Arc<ModelSlot>> {
        let map = self.slots.read().unwrap();
        let entry = map.get(name)?;
        entry.last_used.store(self.tick(), Ordering::Relaxed);
        Some(Arc::clone(&entry.slot))
    }

    /// Remove a slot. Fails on the pinned default or an unknown name.
    /// Graceful: in-flight holders of the slot `Arc` keep serving.
    pub fn unload(&self, name: &str) -> Result<()> {
        ensure!(
            name != self.pinned,
            "cannot unload \"{name}\": it is the pinned default model"
        );
        let removed = self.slots.write().unwrap().remove(name);
        ensure!(removed.is_some(), "unknown model \"{name}\"");
        Ok(())
    }

    /// Registered slot names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.slots.read().unwrap().keys().cloned().collect()
    }

    /// Number of resident models.
    pub fn len(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.read().unwrap().is_empty()
    }

    /// The capacity bound (0 = unbounded).
    pub fn max_models(&self) -> usize {
        self.max_models
    }

    /// The slot name eviction never removes.
    pub fn pinned_name(&self) -> &str {
        &self.pinned
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::pattern::Pattern;
    use crate::testing::model::{build_random_model, ModelSpec};

    fn spec(seed: u64) -> ModelSpec {
        ModelSpec {
            inputs: 8,
            hidden: 32,
            outputs: 16,
            max_batch: 4,
            pattern: Pattern::Gs { b: 8, k: 8 },
            sparsity: 0.75,
            threads: 1,
            seed,
            ..ModelSpec::default()
        }
    }

    fn slot(seed: u64) -> Arc<ModelSlot> {
        Arc::new(ModelSlot::new(
            build_random_model(&spec(seed)).unwrap().model,
            &format!("inline-{seed}"),
            1,
        ))
    }

    #[test]
    fn slot_versions_advance_and_snapshots_pin() {
        let m1 = build_random_model(&spec(1)).unwrap().model;
        let slot = ModelSlot::new(m1, "inline", 1);
        assert_eq!(slot.version(), 1);
        let pinned = slot.current();

        let m2 = build_random_model(&spec(2)).unwrap().model;
        let vm = slot.swap(m2, "inline-2").unwrap();
        assert_eq!(vm.version, 2);
        assert_eq!(slot.version(), 2);
        // The old snapshot still serves version 1.
        assert_eq!(pinned.version, 1);
        assert_eq!(slot.current().source, "inline-2");
    }

    #[test]
    fn slot_rejects_contract_breaking_models() {
        let m1 = build_random_model(&spec(1)).unwrap().model;
        let slot = ModelSlot::new(m1, "inline", 1);
        // Different input width.
        let narrow = build_random_model(&ModelSpec { inputs: 6, ..spec(3) }).unwrap().model;
        assert!(slot.swap(narrow, "bad").is_err());
        // Smaller batch capacity.
        let small = build_random_model(&ModelSpec { max_batch: 2, ..spec(4) }).unwrap().model;
        assert!(slot.swap(small, "bad").is_err());
        assert_eq!(slot.version(), 1, "failed swaps must not bump the version");
    }

    #[test]
    fn swap_path_surfaces_load_errors() {
        let m1 = build_random_model(&spec(1)).unwrap().model;
        let slot = ModelSlot::new(m1, "inline", 1);
        let err = slot.swap_path("/nonexistent/model.gsm").unwrap_err();
        assert!(format!("{err:#}").contains("model.gsm"), "{err:#}");
        assert_eq!(slot.version(), 1);
    }

    #[test]
    fn store_registers_and_lists() {
        let store = ModelStore::new();
        assert!(store.get("default").is_none());
        store.register("default", slot(1)).unwrap();
        assert!(store.get("default").is_some());
        assert_eq!(store.names(), vec!["default".to_string()]);
        assert_eq!(store.len(), 1);
        assert_eq!(store.max_models(), 0, "ModelStore::new is unbounded");
    }

    #[test]
    fn lru_recency_updated_on_acquire() {
        let store = ModelStore::with_capacity(3, "default");
        store.register("default", slot(1)).unwrap();
        store.register("a", slot(2)).unwrap();
        store.register("b", slot(3)).unwrap();
        // "a" is older than "b" by registration; an infer-path acquire
        // of "a" must make "b" the eviction candidate.
        assert!(store.acquire("a").is_some());
        let evicted = store.register("c", slot(4)).unwrap();
        assert_eq!(evicted, vec!["b".to_string()]);
        assert_eq!(
            store.names(),
            vec!["a".to_string(), "c".to_string(), "default".to_string()]
        );
        // get() must NOT touch recency: read "c" via get, then acquire
        // "a"; the next eviction takes "c" (get left it cold)… but "c"
        // was registered after the acquire of "a", so acquire "a" again
        // to make the ordering unambiguous.
        assert!(store.get("c").is_some());
        assert!(store.acquire("a").is_some());
        let evicted = store.register("d", slot(5)).unwrap();
        assert_eq!(evicted, vec!["c".to_string()], "get() must not bump recency");
    }

    #[test]
    fn pinned_default_survives_pressure() {
        let store = ModelStore::with_capacity(2, "default");
        store.register("default", slot(1)).unwrap();
        store.register("a", slot(2)).unwrap();
        // Even though "default" is the coldest entry (never acquired,
        // registered first), pressure evicts "a", not the pinned slot.
        for (i, name) in ["b", "c", "d"].iter().enumerate() {
            let evicted = store.register(name, slot(10 + i as u64)).unwrap();
            assert_eq!(evicted.len(), 1);
            assert_ne!(evicted[0], "default", "pinned slot must never be evicted");
            assert!(store.get("default").is_some());
        }
        assert_eq!(store.len(), 2);
    }

    #[test]
    fn evicted_slot_stays_alive_for_holders() {
        let store = ModelStore::with_capacity(2, "default");
        store.register("default", slot(1)).unwrap();
        store.register("a", slot(2)).unwrap();
        // An in-flight request holds the slot (and a batch snapshot).
        let held = store.acquire("a").unwrap();
        let snapshot = held.current();
        let evicted = store.register("b", slot(3)).unwrap();
        assert_eq!(evicted, vec!["a".to_string()]);
        assert!(store.get("a").is_none(), "registry no longer serves a");
        // …but the holder's Arc still executes fine.
        assert_eq!(snapshot.version, 1);
        let out = snapshot.model.infer_batch(&[vec![0.5; 8]]).unwrap();
        assert_eq!(out[0].len(), 16);
    }

    #[test]
    fn capacity_one_pins_the_default() {
        let store = ModelStore::with_capacity(1, "default");
        store.register("default", slot(1)).unwrap();
        // No evictable seat: the only resident is pinned.
        let err = store.register("a", slot(2)).unwrap_err();
        assert!(format!("{err:#}").contains("capacity 1"), "{err:#}");
        assert_eq!(store.names(), vec!["default".to_string()]);
        // Replacing the pinned slot in place is still allowed (it is a
        // replace, not a second resident).
        store.register("default", slot(3)).unwrap();
        assert_eq!(store.len(), 1);
        assert_eq!(store.get("default").unwrap().current().source, "inline-3");
    }

    #[test]
    fn capacity_one_unpinned_rotates() {
        // Capacity 1 with the pinned name never registered: each load
        // evicts the previous resident.
        let store = ModelStore::with_capacity(1, "default");
        store.register("a", slot(1)).unwrap();
        let evicted = store.register("b", slot(2)).unwrap();
        assert_eq!(evicted, vec!["a".to_string()]);
        assert_eq!(store.names(), vec!["b".to_string()]);
    }

    #[test]
    fn unload_refuses_pinned_and_unknown() {
        let store = ModelStore::with_capacity(0, "default");
        store.register("default", slot(1)).unwrap();
        store.register("a", slot(2)).unwrap();
        assert!(store.unload("default").is_err());
        assert!(store.unload("nope").is_err());
        store.unload("a").unwrap();
        assert_eq!(store.names(), vec!["default".to_string()]);
    }

    #[test]
    fn evict_then_reload_restores_serving() {
        let store = ModelStore::with_capacity(2, "default");
        store.register("default", slot(1)).unwrap();
        store.register("a", slot(7)).unwrap();
        let want = store
            .acquire("a")
            .unwrap()
            .current()
            .model
            .infer_batch(&[vec![0.25; 8]])
            .unwrap();
        // Pressure "a" out, then reload the same model under the same
        // name: serving must be bit-identical to before the eviction.
        store.register("b", slot(8)).unwrap();
        assert!(store.get("a").is_none());
        let evicted = store.register("a", slot(7)).unwrap();
        assert_eq!(evicted, vec!["b".to_string()]);
        let got = store
            .acquire("a")
            .unwrap()
            .current()
            .model
            .infer_batch(&[vec![0.25; 8]])
            .unwrap();
        assert_eq!(got, want, "evict → reload must restore bit-identical serving");
    }
}
