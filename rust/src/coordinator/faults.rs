//! Deterministic fault injection for the resilience test suite
//! (`rust/tests/chaos.rs`).
//!
//! The serving path calls two hooks — [`on_batch_execute`] just before a
//! batch runs and [`corrupt_artifact_bytes`] on every artifact read.
//! Without the `fault-inject` cargo feature both compile to empty
//! `#[inline]` functions, so production builds pay nothing. With the
//! feature, each hook consults process-global arm state that tests set
//! programmatically (`arm_*`) or through environment variables read once
//! at first use:
//!
//! * `GS_FAULT_PANIC_BATCH=N`  — panic when the N-th batch executes
//! * `GS_FAULT_LATENCY_MS=MS`  — sleep `MS` before every batch
//! * `GS_FAULT_CORRUPT_ARTIFACT=1` — flip a byte in every artifact read
//! * `GS_FAULT_TORN_WRITE=1` — the next artifact save crashes mid-write,
//!   leaving a torn temp file and the old artifact intact
//!
//! Injection is deterministic — batches are counted, not sampled — so a
//! chaos test can say "the 3rd batch panics" and assert the exact
//! recovery accounting. The state is process-global; tests that arm
//! faults must run single-threaded (`--test-threads=1`) and call
//! [`reset`] around themselves.

#[cfg(feature = "fault-inject")]
mod imp {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::OnceLock;
    use std::time::Duration;

    /// Batch index (1-based) that panics; 0 = disarmed.
    static PANIC_ON_BATCH: AtomicU64 = AtomicU64::new(0);
    /// Batches that have entered execution since startup/[`reset`].
    static BATCHES: AtomicU64 = AtomicU64::new(0);
    /// Sleep injected before each batch executes; 0 = disarmed.
    static LATENCY_MS: AtomicU64 = AtomicU64::new(0);
    /// Flip a byte in every artifact read.
    static CORRUPT_ARTIFACT: AtomicBool = AtomicBool::new(false);
    /// Tear the next artifact write (one-shot: trips once, then disarms).
    static TORN_WRITE: AtomicBool = AtomicBool::new(false);

    fn env_init() {
        static INIT: OnceLock<()> = OnceLock::new();
        INIT.get_or_init(|| {
            let num = |key: &str| {
                std::env::var(key)
                    .ok()
                    .and_then(|v| v.parse::<u64>().ok())
                    .unwrap_or(0)
            };
            PANIC_ON_BATCH.store(num("GS_FAULT_PANIC_BATCH"), Ordering::SeqCst);
            LATENCY_MS.store(num("GS_FAULT_LATENCY_MS"), Ordering::SeqCst);
            CORRUPT_ARTIFACT.store(num("GS_FAULT_CORRUPT_ARTIFACT") != 0, Ordering::SeqCst);
            TORN_WRITE.store(num("GS_FAULT_TORN_WRITE") != 0, Ordering::SeqCst);
        });
    }

    pub fn on_batch_execute() {
        env_init();
        let n = BATCHES.fetch_add(1, Ordering::SeqCst) + 1;
        let ms = LATENCY_MS.load(Ordering::SeqCst);
        if ms > 0 {
            std::thread::sleep(Duration::from_millis(ms));
        }
        if PANIC_ON_BATCH.load(Ordering::SeqCst) == n {
            panic!("injected fault: panic on batch {n}");
        }
    }

    pub fn corrupt_artifact_bytes(bytes: &mut [u8]) {
        env_init();
        if CORRUPT_ARTIFACT.load(Ordering::SeqCst) {
            if let Some(last) = bytes.last_mut() {
                // The artifact trailer is its CRC-32: flipping bits in
                // the final byte guarantees a checksum mismatch.
                *last ^= 0x5A;
            }
        }
    }

    pub fn torn_artifact_write(len: usize) -> Option<usize> {
        env_init();
        if TORN_WRITE.swap(false, Ordering::SeqCst) {
            // Crash "mid-write": half the bytes make it to disk.
            Some(len / 2)
        } else {
            None
        }
    }

    pub fn arm_panic_on_batch(n: u64) {
        env_init();
        PANIC_ON_BATCH.store(n, Ordering::SeqCst);
    }

    pub fn arm_latency_ms(ms: u64) {
        env_init();
        LATENCY_MS.store(ms, Ordering::SeqCst);
    }

    pub fn arm_corrupt_artifact(on: bool) {
        env_init();
        CORRUPT_ARTIFACT.store(on, Ordering::SeqCst);
    }

    pub fn arm_torn_artifact_write(on: bool) {
        env_init();
        TORN_WRITE.store(on, Ordering::SeqCst);
    }

    pub fn batches_executed() -> u64 {
        env_init();
        BATCHES.load(Ordering::SeqCst)
    }

    pub fn reset() {
        env_init();
        PANIC_ON_BATCH.store(0, Ordering::SeqCst);
        LATENCY_MS.store(0, Ordering::SeqCst);
        CORRUPT_ARTIFACT.store(false, Ordering::SeqCst);
        TORN_WRITE.store(false, Ordering::SeqCst);
        BATCHES.store(0, Ordering::SeqCst);
    }
}

#[cfg(not(feature = "fault-inject"))]
mod imp {
    #[inline(always)]
    pub fn on_batch_execute() {}

    #[inline(always)]
    pub fn corrupt_artifact_bytes(_bytes: &mut [u8]) {}

    #[inline(always)]
    pub fn torn_artifact_write(_len: usize) -> Option<usize> {
        None
    }

    pub fn arm_panic_on_batch(_n: u64) {}

    pub fn arm_latency_ms(_ms: u64) {}

    pub fn arm_corrupt_artifact(_on: bool) {}

    pub fn arm_torn_artifact_write(_on: bool) {}

    pub fn batches_executed() -> u64 {
        0
    }

    pub fn reset() {}
}

/// Hook: a batch is about to execute. May sleep (injected latency) or
/// panic (injected crash). No-op without the `fault-inject` feature.
pub use imp::on_batch_execute;

/// Hook: an artifact was just read from disk. May flip a byte so the
/// CRC check fails. No-op without the `fault-inject` feature.
pub use imp::corrupt_artifact_bytes;

/// Hook: an artifact of `len` bytes is about to be written. When the
/// torn-write fault is armed, returns `Some(cut)` — the writer must
/// leave only the first `cut` bytes in its temp file and fail as if the
/// process died mid-write. One-shot; always `None` without the feature.
pub use imp::torn_artifact_write;

/// Arm: panic when the `n`-th batch (1-based, counted from startup or
/// [`reset`]) enters execution. `0` disarms.
pub use imp::arm_panic_on_batch;

/// Arm: sleep `ms` before every batch executes. `0` disarms.
pub use imp::arm_latency_ms;

/// Arm: corrupt every artifact read until disarmed.
pub use imp::arm_corrupt_artifact;

/// Arm: tear the next artifact save (one-shot — the save fails leaving a
/// partial temp file, then the fault disarms itself).
pub use imp::arm_torn_artifact_write;

/// Batches that have entered execution since startup or [`reset`]
/// (always 0 without the feature).
pub use imp::batches_executed;

/// Disarm every fault and zero the batch counter.
pub use imp::reset;
