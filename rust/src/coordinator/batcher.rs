//! Dynamic batcher: requests queue until the batch fills or a latency
//! window expires (the vLLM-router-style admission loop, scaled to this
//! artifact's static batch).
//!
//! The queue is a set of **per-model sub-queues** (keyed by the slot the
//! request was admitted against) plus a FIFO ready-list of sub-queue
//! keys. `next_batch` *claims* the oldest ready key exclusively, so two
//! idle workers drain two different models concurrently instead of both
//! window-waiting on the same head — the request-level analogue of the
//! paper's load-balance argument (no lane idles while another drowns).
//! Claiming also makes per-model counts O(1) (a `VecDeque` length, not
//! an O(queue) same-key scan) and restores `notify_one` on submit: a
//! wake can only be consumed by a worker that will actually claim a
//! ready sub-queue, never by one window-waiting on a different model.
//!
//! A formed batch is always **model-homogeneous** — requests for the
//! same slot `Arc` only (models have different input widths; a mixed
//! batch could not execute), FIFO within the model, capped by the
//! model's own serving-contract capacity and the global `max_batch`.
//! The batching window is anchored at the *head request's enqueue time*,
//! so worst-case batching delay is bounded by one window no matter how
//! long the head already sat queued.
//!
//! **Bounded admission** (`max_depth > 0`): the total queued-request
//! count never exceeds `max_depth`. At the bound, admission is
//! longest-queue-drop fair shedding: an arrival whose model queues less
//! than the longest unclaimed sub-queue sheds that queue's *newest*
//! request and takes its place (a flooding model cannot starve a trickle
//! model); otherwise the arrival itself is shed. Shed requests fail
//! immediately with an overload [`Reject`] carrying a `retry_after_ms`
//! backoff hint — adaptive: the model's measured p50 service time once
//! latency samples exist, the static window estimate before — so they
//! are never silently queued without limit.
//!
//! **Deadlines** (`InferRequest::deadline_ms`): a request may carry a
//! queue-wait budget. Enforcement happens at *batch-formation* time —
//! the one choke point every request passes through — so an expired
//! request is never executed: it is failed with a structured
//! `"deadline exceeded"` [`Reject`] carrying `waited_ms`, counted in
//! the `expired` metrics, and the conservation invariant becomes
//! `requests == responses + errors + shed + expired`. The batching
//! window wait is capped by the head's deadline, so a deadline shorter
//! than the window is honored rather than blown by the batcher itself.

use super::metrics::{Metrics, Stage};
use super::trace::EventKind;
use crate::model_store::{Admission, ModelSlot};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Terminal failure delivered on a request's reply channel instead of an
/// output row.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Reject {
    pub error: String,
    /// Client backoff hint, set when the request was shed under
    /// overload (serialized as `retry_after_ms` in the protocol).
    pub retry_after_ms: Option<u64>,
    /// How long the request sat queued, set when it expired past its
    /// deadline (serialized as `waited_ms` in the protocol).
    pub waited_ms: Option<u64>,
    /// Time until the quarantined slot admits its next half-open probe,
    /// set when the circuit breaker fast-failed this request (serialized
    /// as `quarantined_for_ms` in the protocol). Deliberately *not*
    /// `retry_after_ms`: a quarantine fast-fail is a hard error, not an
    /// overload, and clients must not classify it as retryable backoff.
    pub quarantined_for_ms: Option<u64>,
}

impl Reject {
    /// A plain execution/infrastructure failure (no backoff hint).
    pub fn error(msg: impl Into<String>) -> Reject {
        Reject {
            error: msg.into(),
            retry_after_ms: None,
            waited_ms: None,
            quarantined_for_ms: None,
        }
    }

    fn overloaded(retry_after_ms: u64) -> Reject {
        Reject {
            retry_after_ms: Some(retry_after_ms),
            ..Reject::error("overloaded: request shed to protect tail latency; back off and retry")
        }
    }

    fn expired(waited_ms: u64) -> Reject {
        Reject {
            waited_ms: Some(waited_ms),
            ..Reject::error("deadline exceeded")
        }
    }

    fn shutdown() -> Reject {
        Reject::error("server shutting down; request not accepted")
    }

    fn quarantined(retry_in_ms: u64) -> Reject {
        Reject {
            quarantined_for_ms: Some(retry_in_ms),
            ..Reject::error(
                "model quarantined: repeated failures tripped the circuit breaker; failing fast \
                 until a probe succeeds",
            )
        }
    }
}

/// Why [`Batcher::submit`] refused a request. The request's `tx` has
/// already been failed with the matching [`Reject`] when this is
/// returned — callers waiting on the reply channel need no special
/// handling; this return value is for callers that want the structured
/// reason without a channel roundtrip.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded admission shed this request; retry after the hint.
    Overloaded { retry_after_ms: u64 },
    /// The routed slot is quarantined by its circuit breaker; the
    /// request was fast-failed without occupying queue space.
    Quarantined { retry_in_ms: u64 },
    /// The batcher is shut down; workers may already be gone, so
    /// queueing would strand the request forever.
    ShutDown,
}

/// One in-flight inference request.
pub struct InferRequest {
    pub id: u64,
    pub input: Vec<f32>,
    pub enqueued: Instant,
    /// Where the result row goes (failure as a [`Reject`]).
    pub tx: Sender<(u64, Result<Vec<f32>, Reject>)>,
    /// Slot name this request routed to (metrics key; "" in factory
    /// mode, where there is exactly one anonymous model).
    pub model: String,
    /// The slot resolved at admission time. Holding the `Arc` here is
    /// what makes LRU eviction graceful: a request admitted before an
    /// eviction executes on its slot even after the registry dropped it.
    /// None in factory mode (workers own their model instance).
    pub slot: Option<Arc<ModelSlot>>,
    /// Per-model batch-size cap (the slot's serving-contract capacity);
    /// `usize::MAX` defers entirely to the batcher's global cap.
    pub cap: usize,
    /// Queue-wait budget in whole milliseconds (None = no deadline). A
    /// request still queued when the budget lapses is failed at
    /// batch-formation time with a structured "deadline exceeded"
    /// [`Reject`] and counted in the `expired` metrics — it never
    /// executes.
    pub deadline_ms: Option<u64>,
    /// Marked by admission when this request is a quarantined slot's
    /// half-open probe: the outcome of the batch carrying it decides
    /// whether the circuit closes. Workers pass it through to
    /// [`ModelSlot::observe_execution`].
    pub probe: bool,
    /// The server-minted id of the batch this request was sealed into,
    /// stamped at batch formation (0 until then). Links the request's
    /// `reply` trace event to the batch's `batch_formed`/`exec_*`
    /// events.
    pub batch_id: u64,
}

impl InferRequest {
    /// An unrouted request (factory mode, tests): no slot, no per-model
    /// cap.
    pub fn new(id: u64, input: Vec<f32>, tx: Sender<(u64, Result<Vec<f32>, Reject>)>) -> Self {
        InferRequest {
            id,
            input,
            enqueued: Instant::now(),
            tx,
            model: String::new(),
            slot: None,
            cap: usize::MAX,
            deadline_ms: None,
            probe: false,
            batch_id: 0,
        }
    }

    /// Whole milliseconds this request has waited in queue so far.
    fn waited_ms(&self) -> u64 {
        self.enqueued.elapsed().as_millis() as u64
    }

    /// True once the queue-wait budget has lapsed. The comparison is a
    /// strict `>` on whole milliseconds: a batch formed *exactly* at the
    /// deadline still executes (`waited == deadline`), so a lone request
    /// whose deadline is shorter than the batching window is released by
    /// the deadline-capped window wait and served, not spuriously
    /// expired; sub-millisecond scheduling jitter is absorbed by the
    /// truncation.
    fn is_expired(&self) -> bool {
        self.deadline_ms.map_or(false, |d| self.waited_ms() > d)
    }

    /// The instant the budget lapses (None = no deadline).
    fn deadline_instant(&self) -> Option<Instant> {
        self.deadline_ms.map(|d| self.enqueued + Duration::from_millis(d))
    }

    /// Batch-homogeneity key: the slot identity (requests admitted
    /// against the same slot `Arc` may share a batch). Keying on the
    /// `Arc` pointer rather than the name means a request admitted
    /// before a same-named slot was replaced never shares a batch with
    /// requests for the replacement. (Safe against pointer reuse: a
    /// sub-queue's requests hold the `Arc`, so the address cannot be
    /// recycled while the sub-queue exists.)
    fn batch_key(&self) -> usize {
        self.slot.as_ref().map_or(0, |s| Arc::as_ptr(s) as usize)
    }

    /// Fail this request's reply channel with `why`.
    fn fail(self, why: Reject) {
        let _ = self.tx.send((self.id, Err(why)));
    }
}

/// One model's pending requests.
struct SubQueue {
    q: VecDeque<InferRequest>,
    /// A worker holds this sub-queue exclusively (window-waiting or
    /// extracting); it is not in the ready-list and no other worker may
    /// drain it, so a claimed queue can never yield an empty batch.
    claimed: bool,
}

struct QueueState {
    /// Per-model sub-queues, keyed by [`InferRequest::batch_key`].
    /// Entries exist iff non-empty.
    queues: BTreeMap<usize, SubQueue>,
    /// Unclaimed keys with queued requests, oldest-ready first.
    ready_keys: VecDeque<usize>,
    /// Total queued requests across every sub-queue (O(1) depth).
    depth: usize,
    shutdown: bool,
}

/// MPMC request queue with batch-forming semantics.
pub struct Batcher {
    state: Mutex<QueueState>,
    /// Signaled when a key joins the ready-list (and on shutdown/final
    /// drain): wakes one worker looking for a sub-queue to claim.
    ready: Condvar,
    /// Signaled when a request joins a *claimed* sub-queue (and on
    /// shutdown): window-waiting workers re-check their O(1) count.
    stragglers: Condvar,
    pub max_batch: usize,
    /// How long the head request of a batch may wait for company,
    /// measured from its *enqueue* time.
    pub window: Duration,
    /// Global bound on queued requests (0 = unbounded, no shedding).
    pub max_depth: usize,
    pub metrics: Arc<Metrics>,
}

impl Batcher {
    pub fn new(
        max_batch: usize,
        window: Duration,
        max_depth: usize,
        metrics: Arc<Metrics>,
    ) -> Batcher {
        Batcher {
            state: Mutex::new(QueueState {
                queues: BTreeMap::new(),
                ready_keys: VecDeque::new(),
                depth: 0,
                shutdown: false,
            }),
            ready: Condvar::new(),
            stragglers: Condvar::new(),
            max_batch,
            window,
            max_depth,
            metrics,
        }
    }

    /// Total queued requests right now (all models).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().depth
    }

    /// Queued requests right now: the global total and the per-model
    /// breakdown, read under one lock so the two views are mutually
    /// consistent (per-name values always sum to the total, minus any
    /// unnamed factory-mode requests). Sub-queues for the same name —
    /// e.g. across a hot swap — are summed.
    pub fn queue_depths(&self) -> (usize, BTreeMap<String, usize>) {
        let st = self.state.lock().unwrap();
        let mut per_model = BTreeMap::new();
        for sq in st.queues.values() {
            let Some(head) = sq.q.front() else { continue };
            if !head.model.is_empty() {
                *per_model.entry(head.model.clone()).or_insert(0) += sq.q.len();
            }
        }
        (st.depth, per_model)
    }

    /// Backoff hint: roughly how long the queued backlog needs to
    /// drain — one batch service time per cap-sized batch over the
    /// *whole* queue (workers round-robin the ready models, so the
    /// global depth, not just the shed request's own model queue,
    /// governs when room opens up).
    ///
    /// The per-batch service time is **adaptive**: the measured p50
    /// request latency for `model` (the global histogram for unrouted
    /// factory-mode requests) once samples exist — a model serving 50 ms
    /// batches tells its clients to back off 25× longer than one serving
    /// 2 ms batches — falling back to the static batching-window
    /// estimate before the first response.
    fn retry_hint(&self, model: &str, backlog: usize, cap: usize) -> u64 {
        let per_batch = self.max_batch.min(cap).max(1);
        let batches = (backlog / per_batch + 1) as u64;
        let p50 = if model.is_empty() {
            self.metrics.latency_summary()
        } else {
            self.metrics.model(model).latency_summary()
        };
        let per_batch_ms = match p50 {
            Some(s) => ((s.p50 * 1e3).ceil() as u64).max(1),
            None => self.window.as_millis().max(1) as u64,
        };
        per_batch_ms * batches
    }

    /// Count a shed request (global + per-model) and fail its channel.
    fn shed(&self, req: InferRequest, retry_after_ms: u64) {
        self.metrics.count_shed(&req.model);
        if self.metrics.recorder.is_enabled() {
            self.metrics.recorder.record(
                EventKind::Shed,
                &req.model,
                req.id,
                0,
                &format!("retry_after_ms={retry_after_ms}"),
            );
        }
        req.fail(Reject::overloaded(retry_after_ms));
    }

    /// Count an expired request (global + per-model) and fail its
    /// channel with the structured deadline reject.
    fn expire(&self, req: InferRequest) {
        self.metrics.count_expired(&req.model);
        let waited = req.waited_ms();
        if self.metrics.recorder.is_enabled() {
            self.metrics.recorder.record(
                EventKind::Expired,
                &req.model,
                req.id,
                0,
                &format!("waited_ms={waited}"),
            );
        }
        req.fail(Reject::expired(waited));
    }

    /// Enqueue a request (from server/router threads).
    ///
    /// Every attempt counts toward `metrics.requests`, and every
    /// refused request is failed on its `tx` *before* this returns, so
    /// `requests == responses + errors + shed + expired` holds and
    /// nothing ever blocks forever on a reply channel:
    ///
    /// * after [`shutdown`](Batcher::shutdown), the request is failed
    ///   immediately (workers may already be gone — queueing would
    ///   strand it) and counted as an error;
    /// * with `max_depth` reached, longest-queue-drop fair shedding
    ///   runs: if some unclaimed sub-queue is longer than this model's,
    ///   its newest request is shed to make room (counted against *its*
    ///   model) and this one is admitted; otherwise this request is
    ///   shed. Either way exactly one request gets the overload
    ///   [`Reject`] with a `retry_after_ms` hint.
    pub fn submit(&self, mut req: InferRequest) -> Result<(), SubmitError> {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        // Quarantine circuit breaker: fail fast before the request can
        // occupy queue space or evict a shedding victim.
        if let Some(slot) = &req.slot {
            match slot.admit() {
                Admission::Admit => {}
                Admission::AdmitProbe => req.probe = true,
                Admission::FastFail { retry_in_ms } => {
                    self.metrics.count_quarantined(&req.model);
                    req.fail(Reject::quarantined(retry_in_ms));
                    return Err(SubmitError::Quarantined { retry_in_ms });
                }
            }
        }
        let key = req.batch_key();
        let trace_id = if self.metrics.recorder.is_enabled() {
            Some((req.id, req.model.clone()))
        } else {
            None
        };
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            drop(st);
            self.metrics.count_errors(&req.model, 1);
            req.fail(Reject::shutdown());
            return Err(SubmitError::ShutDown);
        }
        // Bounded admission with longest-queue-drop fair shedding.
        let mut victim = None;
        if self.max_depth > 0 && st.depth >= self.max_depth {
            let mine = st.queues.get(&key).map_or(0, |sq| sq.q.len());
            // Claimed sub-queues are already being formed into a batch
            // (in service); only still-waiting queues are drop targets.
            let longest = st
                .queues
                .iter()
                .filter(|(_, sq)| !sq.claimed)
                .max_by_key(|(_, sq)| sq.q.len())
                .map(|(k, sq)| (*k, sq.q.len()));
            match longest {
                Some((vk, vlen)) if vlen > mine => {
                    let stm = &mut *st;
                    let vsq = stm.queues.get_mut(&vk).expect("longest key exists");
                    let v = vsq.q.pop_back().expect("longest sub-queue is non-empty");
                    if vsq.q.is_empty() {
                        stm.queues.remove(&vk);
                        stm.ready_keys.retain(|k| *k != vk);
                    }
                    stm.depth -= 1;
                    victim = Some(v);
                }
                _ => {
                    let backlog = st.depth;
                    drop(st);
                    let retry = self.retry_hint(&req.model, backlog, req.cap);
                    self.shed(req, retry);
                    return Err(SubmitError::Overloaded { retry_after_ms: retry });
                }
            }
        }
        // Admit.
        st.depth += 1;
        let stm = &mut *st;
        let wake_stragglers = match stm.queues.entry(key) {
            std::collections::btree_map::Entry::Occupied(mut e) => {
                let claimed = e.get().claimed;
                e.get_mut().q.push_back(req);
                claimed
            }
            std::collections::btree_map::Entry::Vacant(e) => {
                e.insert(SubQueue { q: VecDeque::from([req]), claimed: false });
                stm.ready_keys.push_back(key);
                false
            }
        };
        drop(st);
        if wake_stragglers {
            // The claiming worker re-checks its count (it may now be
            // full); only window-waiters listen here, and each check is
            // O(1), so this is not the old thundering herd.
            self.stragglers.notify_all();
        } else {
            // Exactly one idle worker is enough: it will claim a ready
            // sub-queue (maybe this one). A worker window-waiting on a
            // different model cannot consume this wake.
            self.ready.notify_one();
        }
        if let Some((rid, rmodel)) = trace_id {
            self.metrics.recorder.record(EventKind::Enqueue, &rmodel, rid, 0, "");
        }
        if let Some(v) = victim {
            // The queue is back at the bound after the swap-in.
            let retry = self.retry_hint(&v.model, self.max_depth, v.cap);
            self.shed(v, retry);
        }
        Ok(())
    }

    /// Stop all workers after the queue drains. Subsequent `submit`
    /// calls fail fast instead of queueing.
    pub fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        self.ready.notify_all();
        self.stragglers.notify_all();
    }

    /// Block for the next batch: claims the oldest ready model's
    /// sub-queue exclusively, gives stragglers *for that model* until
    /// `head.enqueued + window` — capped by the head's own deadline —
    /// to join (skipping the wait if already full or the head has
    /// waited its window out), then extracts up to `min(max_batch,
    /// model cap)` requests in FIFO order. Requests that outwaited
    /// their `deadline_ms` are failed at extraction with a structured
    /// "deadline exceeded" [`Reject`] instead of joining the batch
    /// (enforcement at batch-formation time: an expired request is
    /// *never* executed). Other models' sub-queues stay ready for
    /// concurrent `next_batch` calls on other workers. Never returns an
    /// empty batch (if everything claimed had expired, the worker fails
    /// them and claims the next ready sub-queue); returns `None` on
    /// shutdown with an empty queue.
    pub fn next_batch(&self) -> Option<Vec<InferRequest>> {
        loop {
            let mut st = self.state.lock().unwrap();
            // Claim the oldest ready sub-queue.
            let key = loop {
                if let Some(k) = st.ready_keys.pop_front() {
                    break k;
                }
                if st.shutdown && st.depth == 0 {
                    return None;
                }
                // Nothing ready: idle, or (under shutdown with depth >
                // 0) every pending sub-queue is claimed by another
                // worker — wait for a submit, a leftover re-queue, or
                // the final drain notification.
                st = self.ready.wait(st).unwrap();
            };
            let (cap, deadline) = {
                let sq = st.queues.get_mut(&key).expect("ready key has a sub-queue");
                sq.claimed = true;
                let head = sq.q.front().expect("ready sub-queue is non-empty");
                // Anchor the window at the head's *enqueue* time:
                // however long it already waited counts against its
                // window, so worst-case batching delay is one window —
                // not one window per worker that happens to observe the
                // head. The head's own deadline caps the wait: never
                // hold a request for stragglers past the point where it
                // would expire.
                let window_end = head.enqueued + self.window;
                let end = match head.deadline_instant() {
                    Some(d) if d < window_end => d,
                    _ => window_end,
                };
                (self.max_batch.min(head.cap).max(1), end)
            };
            // Window-wait for same-model stragglers (O(1) count per
            // wake).
            loop {
                let n = st.queues.get(&key).map_or(0, |sq| sq.q.len());
                if n >= cap || st.shutdown {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (next, timeout) = self.stragglers.wait_timeout(st, deadline - now).unwrap();
                st = next;
                if timeout.timed_out() {
                    break;
                }
            }
            // Extract up to `cap` live requests in FIFO order, setting
            // expired ones aside; the claim is exclusive, so the
            // sub-queue is still non-empty.
            let stm = &mut *st;
            let mut batch: Vec<InferRequest> = Vec::new();
            let mut expired: Vec<InferRequest> = Vec::new();
            let leftover = {
                let sq = stm.queues.get_mut(&key).expect("claimed sub-queue persists");
                while batch.len() < cap {
                    let Some(req) = sq.q.pop_front() else { break };
                    if req.is_expired() {
                        expired.push(req);
                    } else {
                        batch.push(req);
                    }
                }
                !sq.q.is_empty()
            };
            stm.depth -= batch.len() + expired.len();
            if leftover {
                // More of this model remains: back to the end of the
                // ready-list so other models get their turn first.
                let sq = stm.queues.get_mut(&key).expect("claimed sub-queue persists");
                sq.claimed = false;
                stm.ready_keys.push_back(key);
                self.ready.notify_one();
            } else {
                stm.queues.remove(&key);
            }
            if stm.shutdown && stm.depth == 0 {
                // Final drain: release workers parked in the claim loop.
                self.ready.notify_all();
            }
            drop(st);
            // Fail expired requests outside the lock (each send + metric
            // bump is per-request work no other worker needs to wait on).
            for req in expired {
                self.expire(req);
            }
            if batch.is_empty() {
                // Everything claimed had outwaited its budget: go claim
                // the next ready sub-queue instead of returning an empty
                // batch.
                continue;
            }
            // Seal the batch: mint its id, stamp every member, and
            // attribute queue-wait (per request) and batch-formation
            // (head enqueue → seal) time to the stage histograms.
            let batch_id = self.metrics.record_batch(batch.len());
            let sealed = Instant::now();
            let model = batch[0].model.clone();
            let mm = if model.is_empty() { None } else { Some(self.metrics.model(&model)) };
            for req in &mut batch {
                req.batch_id = batch_id;
                let wait = sealed.saturating_duration_since(req.enqueued).as_secs_f64();
                self.metrics.stages.record(Stage::QueueWait, wait);
                if let Some(mm) = &mm {
                    mm.stages.record(Stage::QueueWait, wait);
                }
            }
            let form = sealed.saturating_duration_since(batch[0].enqueued).as_secs_f64();
            self.metrics.stages.record(Stage::BatchForm, form);
            if let Some(mm) = &mm {
                mm.stages.record(Stage::BatchForm, form);
            }
            if self.metrics.recorder.is_enabled() {
                self.metrics.recorder.record(
                    EventKind::BatchFormed,
                    &model,
                    0,
                    batch_id,
                    &format!("n={}", batch.len()),
                );
            }
            return Some(batch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::model::{build_random_model, ModelSpec};
    use std::sync::mpsc::{channel, Receiver};

    type Rx = Receiver<(u64, Result<Vec<f32>, Reject>)>;

    fn req(id: u64, tx: &Sender<(u64, Result<Vec<f32>, Reject>)>) -> InferRequest {
        InferRequest::new(id, vec![id as f32], tx.clone())
    }

    fn batcher(max_batch: usize, window_ms: u64, max_depth: usize) -> Batcher {
        Batcher::new(
            max_batch,
            Duration::from_millis(window_ms),
            max_depth,
            Arc::new(Metrics::new()),
        )
    }

    #[test]
    fn forms_full_batches_without_waiting() {
        let b = batcher(4, 50, 0);
        let (tx, _rx) = channel();
        for i in 0..4 {
            b.submit(req(i, &tx)).unwrap();
        }
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert!(t.elapsed() < Duration::from_millis(40), "full batch should not wait");
    }

    #[test]
    fn window_expiry_releases_partial_batch() {
        let b = batcher(8, 20, 0);
        let (tx, _rx) = channel();
        b.submit(req(1, &tx)).unwrap();
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() >= Duration::from_millis(15));
    }

    /// Regression (window anchor): the window runs from the head's
    /// *enqueue* time. A head that already waited its window out is
    /// released immediately instead of paying a fresh full window when
    /// a worker first observes it.
    #[test]
    fn window_is_anchored_at_enqueue_not_observation() {
        let b = batcher(8, 60, 0);
        let (tx, _rx) = channel();
        b.submit(req(1, &tx)).unwrap();
        std::thread::sleep(Duration::from_millis(80));
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t.elapsed() < Duration::from_millis(30),
            "expired window must release immediately, waited {:?}",
            t.elapsed()
        );
    }

    #[test]
    fn preserves_fifo_order() {
        let b = batcher(3, 5, 0);
        let (tx, _rx) = channel();
        for i in 0..5 {
            b.submit(req(i, &tx)).unwrap();
        }
        let first = b.next_batch().unwrap();
        let second = b.next_batch().unwrap();
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn shutdown_unblocks_workers() {
        let b = Arc::new(batcher(4, 5, 0));
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(10));
        b.shutdown();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn drains_queue_before_shutdown_none() {
        let b = batcher(4, 1, 0);
        let (tx, _rx) = channel();
        b.submit(req(7, &tx)).unwrap();
        b.shutdown();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(b.next_batch().is_none());
    }

    /// Regression (post-shutdown submit hang): submitting after
    /// `shutdown()` fails the request's reply channel immediately with
    /// a clear error — it must never sit in the queue forever after the
    /// workers have drained and exited.
    #[test]
    fn submit_after_shutdown_fails_fast() {
        let b = batcher(4, 5, 0);
        b.shutdown();
        assert!(b.next_batch().is_none());
        let (tx, rx): (_, Rx) = channel();
        let err = b.submit(req(1, &tx)).unwrap_err();
        assert_eq!(err, SubmitError::ShutDown);
        // The reply channel already carries the failure — a connection
        // thread blocked on it returns instead of hanging.
        let (id, result) = rx.try_recv().expect("tx failed immediately");
        assert_eq!(id, 1);
        let why = result.unwrap_err();
        assert!(why.error.contains("shutting down"), "{}", why.error);
        assert_eq!(b.depth(), 0, "rejected request must not be queued");
        // Accounting: the attempt counts as a request and an error.
        assert_eq!(b.metrics.requests.load(Ordering::Relaxed), 1);
        assert_eq!(b.metrics.errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn bounded_admission_sheds_with_retry_hint() {
        let b = batcher(2, 10, 3);
        let (tx, rx): (_, Rx) = channel();
        for i in 0..3 {
            b.submit(req(i, &tx)).unwrap();
        }
        assert_eq!(b.depth(), 3);
        // Single model at the bound: its own queue is the longest, so
        // the arrival itself is shed.
        let err = b.submit(req(3, &tx)).unwrap_err();
        let SubmitError::Overloaded { retry_after_ms } = err else {
            panic!("expected overload, got {err:?}");
        };
        assert!(retry_after_ms >= 10, "hint covers at least one window");
        let (id, result) = rx.try_recv().expect("shed fails the channel immediately");
        assert_eq!(id, 3);
        let why = result.unwrap_err();
        assert!(why.error.contains("overloaded"), "{}", why.error);
        assert_eq!(why.retry_after_ms, Some(retry_after_ms));
        assert_eq!(b.depth(), 3, "queue bound holds exactly");
        assert_eq!(b.metrics.shed.load(Ordering::Relaxed), 1);
        // Draining makes room again.
        assert_eq!(b.next_batch().unwrap().len(), 2);
        b.submit(req(4, &tx)).unwrap();
        assert_eq!(b.depth(), 2);
    }

    fn routed(
        id: u64,
        slot: &Arc<ModelSlot>,
        name: &str,
        tx: &Sender<(u64, Result<Vec<f32>, Reject>)>,
    ) -> InferRequest {
        InferRequest {
            model: name.to_string(),
            slot: Some(Arc::clone(slot)),
            cap: slot.batch_capacity(),
            ..InferRequest::new(id, vec![id as f32], tx.clone())
        }
    }

    fn test_slot(max_batch: usize, seed: u64) -> Arc<ModelSlot> {
        let model = build_random_model(&ModelSpec {
            inputs: 8,
            hidden: 32,
            outputs: 8,
            max_batch,
            pattern: crate::sparse::pattern::Pattern::Gs { b: 8, k: 8 },
            sparsity: 0.75,
            threads: 1,
            seed,
            ..ModelSpec::default()
        })
        .unwrap()
        .model;
        Arc::new(ModelSlot::new(model, "inline", 1))
    }

    #[test]
    fn batches_never_mix_models() {
        let b = batcher(8, 1, 0);
        let (tx, _rx) = channel();
        let (sa, sb) = (test_slot(8, 1), test_slot(8, 2));
        // Interleaved arrivals: a b a b a.
        let arrivals = [(&sa, "a"), (&sb, "b"), (&sa, "a"), (&sb, "b"), (&sa, "a")];
        for (i, (slot, name)) in arrivals.into_iter().enumerate() {
            b.submit(routed(i as u64, slot, name, &tx)).unwrap();
        }
        // "a" became ready first: its batch takes ids 0, 2, 4.
        let first = b.next_batch().unwrap();
        assert!(first.iter().all(|r| r.model == "a"));
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 4]);
        // The "b" requests remained queued in order.
        let second = b.next_batch().unwrap();
        assert!(second.iter().all(|r| r.model == "b"));
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn per_model_cap_bounds_the_batch() {
        // Global max_batch 8, but the model's contract capacity is 2.
        let b = batcher(8, 1, 0);
        let (tx, _rx) = channel();
        let s = test_slot(2, 3);
        for i in 0..5 {
            b.submit(routed(i, &s, "m", &tx)).unwrap();
        }
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 1);
    }

    #[test]
    fn same_name_different_slot_does_not_mix() {
        // A replaced slot under the same name: older requests hold the
        // old Arc and must not share a batch with new ones.
        let b = batcher(8, 1, 0);
        let (tx, _rx) = channel();
        let (old, new) = (test_slot(8, 4), test_slot(8, 5));
        b.submit(routed(0, &old, "m", &tx)).unwrap();
        b.submit(routed(1, &new, "m", &tx)).unwrap();
        let first = b.next_batch().unwrap();
        assert_eq!(first.len(), 1);
        assert!(Arc::ptr_eq(first[0].slot.as_ref().unwrap(), &old));
        let second = b.next_batch().unwrap();
        assert!(Arc::ptr_eq(second[0].slot.as_ref().unwrap(), &new));
        // Both sub-queues fold into one name in the depth breakdown,
        // and the total/per-model views agree (one lock hold).
        b.submit(routed(2, &old, "m", &tx)).unwrap();
        b.submit(routed(3, &new, "m", &tx)).unwrap();
        let (total, per_model) = b.queue_depths();
        assert_eq!(per_model.get("m"), Some(&2));
        assert_eq!(total, 2);
    }

    /// Quarantine fast-fail at admission: a tripped slot's request is
    /// rejected before it can touch the queue, the reject carries
    /// `quarantined_for_ms` (not the overload backoff hint), and the
    /// accounting keeps conservation exact: the fast-fail is an error
    /// plus the supplementary `quarantined` counter.
    #[test]
    fn quarantined_slot_fast_fails_at_admission() {
        use crate::model_store::SlotConfig;
        let b = batcher(8, 1, 0);
        let (tx, rx): (_, Rx) = channel();
        let model = build_random_model(&ModelSpec {
            inputs: 8,
            hidden: 32,
            outputs: 8,
            max_batch: 8,
            pattern: crate::sparse::pattern::Pattern::Gs { b: 8, k: 8 },
            sparsity: 0.75,
            threads: 1,
            seed: 11,
            ..ModelSpec::default()
        })
        .unwrap()
        .model;
        let slot = Arc::new(ModelSlot::with_config(
            model,
            "inline",
            1,
            SlotConfig {
                quarantine_after: 1,
                quarantine_cooldown_ms: 60_000,
                ..SlotConfig::default()
            },
        ));
        // One failed request trips the breaker.
        slot.observe_execution(slot.version(), 0, 1, false);
        assert_eq!(slot.state_name(), "quarantined");
        let err = b.submit(routed(1, &slot, "m", &tx)).unwrap_err();
        assert!(matches!(err, SubmitError::Quarantined { .. }), "{err:?}");
        let (id, result) = rx.try_recv().expect("fast-fail delivered on the reply channel");
        assert_eq!(id, 1);
        let why = result.unwrap_err();
        assert!(why.error.starts_with("model quarantined"), "{}", why.error);
        assert!(why.quarantined_for_ms.is_some());
        assert!(why.retry_after_ms.is_none(), "quarantine is a hard error, not backoff");
        assert_eq!(b.depth(), 0, "fast-failed request never queued");
        assert_eq!(b.metrics.requests.load(Ordering::Relaxed), 1);
        assert_eq!(b.metrics.errors.load(Ordering::Relaxed), 1);
        assert_eq!(b.metrics.quarantined.load(Ordering::Relaxed), 1);
        assert_eq!(b.metrics.model("m").quarantined.load(Ordering::Relaxed), 1);
    }

    /// Half-open recovery through the batcher: once the cool-down
    /// elapses the next submission is admitted as the probe (marked on
    /// the request), and a clean probe outcome closes the circuit.
    #[test]
    fn half_open_probe_is_marked_and_admitted() {
        use crate::model_store::{SlotConfig, SlotEvent};
        let b = batcher(8, 1, 0);
        let (tx, _rx) = channel();
        let model = build_random_model(&ModelSpec {
            inputs: 8,
            hidden: 32,
            outputs: 8,
            max_batch: 8,
            pattern: crate::sparse::pattern::Pattern::Gs { b: 8, k: 8 },
            sparsity: 0.75,
            threads: 1,
            seed: 12,
            ..ModelSpec::default()
        })
        .unwrap()
        .model;
        let slot = Arc::new(ModelSlot::with_config(
            model,
            "inline",
            1,
            SlotConfig {
                quarantine_after: 1,
                quarantine_cooldown_ms: 1,
                ..SlotConfig::default()
            },
        ));
        slot.observe_execution(slot.version(), 0, 1, false);
        std::thread::sleep(Duration::from_millis(10));
        b.submit(routed(1, &slot, "m", &tx)).unwrap();
        let batch = b.next_batch().unwrap();
        assert!(batch[0].probe, "cool-down elapsed: the admitted request is the probe");
        // The slot stays quarantined until the probe outcome arrives,
        // and a clean probe closes the circuit.
        assert_eq!(slot.state_name(), "quarantined");
        let events = slot.observe_execution(slot.version(), batch.len() as u64, 0, true);
        assert_eq!(events, vec![SlotEvent::Recovered]);
        assert_eq!(slot.state_name(), "serving");
    }

    /// Fair shedding at the bound: an arrival for a model queuing less
    /// than the flooder sheds the flooder's newest request — the
    /// trickle model is admitted, the bound holds exactly, and the shed
    /// is charged to the flooder.
    #[test]
    fn fair_shedding_drops_the_longest_queue() {
        let b = batcher(8, 10, 4);
        let (flood_tx, flood_rx): (_, Rx) = channel();
        let (trickle_tx, trickle_rx): (_, Rx) = channel();
        let (flood, trickle) = (test_slot(8, 6), test_slot(8, 7));
        for i in 0..4 {
            b.submit(routed(i, &flood, "flood", &flood_tx)).unwrap();
        }
        // Trickle arrival at the bound: admitted by shedding flood's
        // newest request (id 3).
        b.submit(routed(10, &trickle, "trickle", &trickle_tx)).unwrap();
        assert_eq!(b.depth(), 4);
        let (id, result) = flood_rx.try_recv().expect("flood tail shed");
        assert_eq!(id, 3);
        assert!(result.unwrap_err().retry_after_ms.is_some());
        assert!(trickle_rx.try_recv().is_err(), "trickle request stays queued");
        // A further flood arrival cannot displace the trickle request
        // (flood's own queue is the longest → the arrival is shed).
        let err = b.submit(routed(4, &flood, "flood", &flood_tx)).unwrap_err();
        assert!(matches!(err, SubmitError::Overloaded { .. }));
        assert_eq!(b.queue_depths().1.get("trickle"), Some(&1));
        assert_eq!(b.metrics.shed.load(Ordering::Relaxed), 2);
        assert_eq!(
            b.metrics.model("flood").shed.load(Ordering::Relaxed),
            2,
            "both sheds are charged to the flooding model"
        );
        assert_eq!(b.metrics.model("trickle").shed.load(Ordering::Relaxed), 0);
        // FIFO across models still holds: flood (older) drains first.
        let ids: Vec<u64> = b.next_batch().unwrap().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        let ids: Vec<u64> = b.next_batch().unwrap().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![10]);
    }

    /// Deadline enforcement at batch formation: a request that outwaited
    /// its budget is failed with the structured reject (never executed),
    /// while a live request in the same sub-queue still forms a batch.
    #[test]
    fn expired_request_fails_at_formation_and_never_executes() {
        let b = batcher(8, 5, 0);
        let (tx, rx): (_, Rx) = channel();
        let mut stale = req(1, &tx);
        stale.deadline_ms = Some(10);
        b.submit(stale).unwrap();
        std::thread::sleep(Duration::from_millis(25));
        b.submit(req(2, &tx)).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        let (id, result) = rx.try_recv().expect("expired request failed during formation");
        assert_eq!(id, 1);
        let why = result.unwrap_err();
        assert_eq!(why.error, "deadline exceeded");
        assert!(why.waited_ms.unwrap() >= 10, "{:?}", why.waited_ms);
        assert!(why.retry_after_ms.is_none());
        assert_eq!(b.metrics.expired.load(Ordering::Relaxed), 1);
        assert_eq!(b.depth(), 0, "expired request left the queue");
        // Conservation: 2 requests = 1 batched (pending response) + 1
        // expired; nothing lost.
        assert_eq!(b.metrics.requests.load(Ordering::Relaxed), 2);
        assert_eq!(b.metrics.shed.load(Ordering::Relaxed), 0);
        assert_eq!(b.metrics.errors.load(Ordering::Relaxed), 0);
    }

    /// A sub-queue that expired in its entirety never yields an empty
    /// batch: the worker fails the stale requests and moves on (here to
    /// the shutdown drain → `None`).
    #[test]
    fn fully_expired_queue_drains_to_none_not_empty_batch() {
        let b = batcher(4, 1, 0);
        let (tx, rx): (_, Rx) = channel();
        let mut stale = req(7, &tx);
        stale.deadline_ms = Some(5);
        b.submit(stale).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        b.shutdown();
        assert!(b.next_batch().is_none());
        let (id, result) = rx.try_recv().expect("stale request was failed");
        assert_eq!(id, 7);
        assert_eq!(result.unwrap_err().error, "deadline exceeded");
        assert_eq!(b.metrics.expired.load(Ordering::Relaxed), 1);
        assert_eq!(b.depth(), 0);
    }

    /// Adaptive shedding, static path: before any latency sample exists
    /// the retry hint is the window × backlog estimate.
    #[test]
    fn retry_hint_is_static_before_latency_samples() {
        let b = batcher(2, 10, 3);
        let (tx, _rx) = channel();
        for i in 0..3 {
            b.submit(req(i, &tx)).unwrap();
        }
        let err = b.submit(req(3, &tx)).unwrap_err();
        let SubmitError::Overloaded { retry_after_ms } = err else {
            panic!("expected overload, got {err:?}");
        };
        // backlog 3, per-batch 2 → 2 batches × the 10 ms window.
        assert_eq!(retry_after_ms, 20);
    }

    /// Adaptive shedding, measured path: once the shed request's model
    /// has latency samples, the hint scales with the measured p50
    /// instead of the static window.
    #[test]
    fn retry_hint_adapts_to_measured_p50() {
        let b = batcher(2, 10, 3);
        let (tx, _rx) = channel();
        let s = test_slot(8, 9);
        // The model's responses so far took ~50 ms each.
        b.metrics.model("m").record_latency(0.05);
        b.metrics.model("m").record_latency(0.05);
        for i in 0..3 {
            b.submit(routed(i, &s, "m", &tx)).unwrap();
        }
        let err = b.submit(routed(3, &s, "m", &tx)).unwrap_err();
        let SubmitError::Overloaded { retry_after_ms } = err else {
            panic!("expected overload, got {err:?}");
        };
        // backlog 3, per-batch 2 → 2 batches × the measured 50 ms p50.
        assert_eq!(retry_after_ms, 100);
    }
}
