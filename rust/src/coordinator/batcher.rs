//! Dynamic batcher: requests queue until the batch fills or a latency
//! window expires (the vLLM-router-style admission loop, scaled to this
//! artifact's static batch).

use super::metrics::Metrics;
use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One in-flight inference request.
pub struct InferRequest {
    pub id: u64,
    pub input: Vec<f32>,
    pub enqueued: Instant,
    /// Where the result row goes (error as Err-string).
    pub tx: Sender<(u64, Result<Vec<f32>, String>)>,
}

struct QueueState {
    queue: VecDeque<InferRequest>,
    shutdown: bool,
}

/// MPMC request queue with batch-forming semantics.
pub struct Batcher {
    state: Mutex<QueueState>,
    nonempty: Condvar,
    pub max_batch: usize,
    /// How long the first request in a batch may wait for company.
    pub window: Duration,
    pub metrics: Arc<Metrics>,
}

impl Batcher {
    pub fn new(max_batch: usize, window: Duration, metrics: Arc<Metrics>) -> Batcher {
        Batcher {
            state: Mutex::new(QueueState { queue: VecDeque::new(), shutdown: false }),
            nonempty: Condvar::new(),
            max_batch,
            window,
            metrics,
        }
    }

    /// Enqueue a request (from server/router threads).
    pub fn submit(&self, req: InferRequest) {
        self.metrics
            .requests
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut st = self.state.lock().unwrap();
        st.queue.push_back(req);
        self.nonempty.notify_one();
    }

    /// Stop all workers after the queue drains.
    pub fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        self.nonempty.notify_all();
    }

    /// Block for the next batch: waits for a first request, then gives
    /// stragglers up to `window` to join, capped at `max_batch` rows.
    /// Returns `None` on shutdown with an empty queue.
    pub fn next_batch(&self) -> Option<Vec<InferRequest>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.queue.is_empty() {
                break;
            }
            if st.shutdown {
                return None;
            }
            st = self.nonempty.wait(st).unwrap();
        }
        // A first request exists; give the window a chance to fill the
        // batch (skip the wait if it is already full).
        let deadline = Instant::now() + self.window;
        while st.queue.len() < self.max_batch && !st.shutdown {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (next, timeout) = self
                .nonempty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = next;
            if timeout.timed_out() {
                break;
            }
        }
        let take = st.queue.len().min(self.max_batch);
        let batch: Vec<InferRequest> = st.queue.drain(..take).collect();
        self.metrics.record_batch(batch.len());
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(id: u64, tx: &Sender<(u64, Result<Vec<f32>, String>)>) -> InferRequest {
        InferRequest { id, input: vec![id as f32], enqueued: Instant::now(), tx: tx.clone() }
    }

    #[test]
    fn forms_full_batches_without_waiting() {
        let b = Batcher::new(4, Duration::from_millis(50), Arc::new(Metrics::new()));
        let (tx, _rx) = channel();
        for i in 0..4 {
            b.submit(req(i, &tx));
        }
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert!(t.elapsed() < Duration::from_millis(40), "full batch should not wait");
    }

    #[test]
    fn window_expiry_releases_partial_batch() {
        let b = Batcher::new(8, Duration::from_millis(20), Arc::new(Metrics::new()));
        let (tx, _rx) = channel();
        b.submit(req(1, &tx));
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn preserves_fifo_order() {
        let b = Batcher::new(3, Duration::from_millis(5), Arc::new(Metrics::new()));
        let (tx, _rx) = channel();
        for i in 0..5 {
            b.submit(req(i, &tx));
        }
        let first = b.next_batch().unwrap();
        let second = b.next_batch().unwrap();
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn shutdown_unblocks_workers() {
        let b = Arc::new(Batcher::new(4, Duration::from_millis(5), Arc::new(Metrics::new())));
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(10));
        b.shutdown();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn drains_queue_before_shutdown_none() {
        let b = Batcher::new(4, Duration::from_millis(1), Arc::new(Metrics::new()));
        let (tx, _rx) = channel();
        b.submit(req(7, &tx));
        b.shutdown();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(b.next_batch().is_none());
    }
}
