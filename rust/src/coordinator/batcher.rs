//! Dynamic batcher: requests queue until the batch fills or a latency
//! window expires (the vLLM-router-style admission loop, scaled to this
//! artifact's static batch).
//!
//! Multi-model routing: every request carries the slot it was admitted
//! against, and a formed batch is always **model-homogeneous** — the
//! oldest queued request picks the slot, and only requests for the same
//! slot join its batch (models have different input widths; a mixed
//! batch could not execute). Requests for other models stay queued in
//! arrival order and form their own batches (per-model FIFO is
//! preserved; each `next_batch` call serves the current queue head, so
//! no model can starve another indefinitely).

use super::metrics::Metrics;
use crate::model_store::ModelSlot;
use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One in-flight inference request.
pub struct InferRequest {
    pub id: u64,
    pub input: Vec<f32>,
    pub enqueued: Instant,
    /// Where the result row goes (error as Err-string).
    pub tx: Sender<(u64, Result<Vec<f32>, String>)>,
    /// Slot name this request routed to (metrics key; "" in factory
    /// mode, where there is exactly one anonymous model).
    pub model: String,
    /// The slot resolved at admission time. Holding the `Arc` here is
    /// what makes LRU eviction graceful: a request admitted before an
    /// eviction executes on its slot even after the registry dropped it.
    /// None in factory mode (workers own their model instance).
    pub slot: Option<Arc<ModelSlot>>,
    /// Per-model batch-size cap (the slot's serving-contract capacity);
    /// `usize::MAX` defers entirely to the batcher's global cap.
    pub cap: usize,
}

impl InferRequest {
    /// An unrouted request (factory mode, tests): no slot, no per-model
    /// cap.
    pub fn new(id: u64, input: Vec<f32>, tx: Sender<(u64, Result<Vec<f32>, String>)>) -> Self {
        InferRequest {
            id,
            input,
            enqueued: Instant::now(),
            tx,
            model: String::new(),
            slot: None,
            cap: usize::MAX,
        }
    }

    /// Batch-homogeneity key: the slot identity (requests admitted
    /// against the same slot `Arc` may share a batch). Keying on the
    /// `Arc` pointer rather than the name means a request admitted
    /// before a same-named slot was replaced never shares a batch with
    /// requests for the replacement.
    fn batch_key(&self) -> usize {
        self.slot.as_ref().map_or(0, |s| Arc::as_ptr(s) as usize)
    }
}

struct QueueState {
    queue: VecDeque<InferRequest>,
    shutdown: bool,
}

/// MPMC request queue with batch-forming semantics.
pub struct Batcher {
    state: Mutex<QueueState>,
    nonempty: Condvar,
    pub max_batch: usize,
    /// How long the first request in a batch may wait for company.
    pub window: Duration,
    pub metrics: Arc<Metrics>,
}

impl Batcher {
    pub fn new(max_batch: usize, window: Duration, metrics: Arc<Metrics>) -> Batcher {
        Batcher {
            state: Mutex::new(QueueState { queue: VecDeque::new(), shutdown: false }),
            nonempty: Condvar::new(),
            max_batch,
            window,
            metrics,
        }
    }

    /// Enqueue a request (from server/router threads).
    pub fn submit(&self, req: InferRequest) {
        self.metrics
            .requests
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut st = self.state.lock().unwrap();
        st.queue.push_back(req);
        // notify_all, not notify_one: a single wake could be consumed by
        // a worker window-waiting on a *different* model (it re-counts
        // its own matches and keeps waiting), leaving an idle worker
        // asleep while this request sits queued.
        self.nonempty.notify_all();
    }

    /// Stop all workers after the queue drains.
    pub fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        self.nonempty.notify_all();
    }

    /// Block for the next batch: waits for a first request, then gives
    /// stragglers *for the same model* up to `window` to join, capped at
    /// `max_batch` rows and the model's own batch capacity. Requests for
    /// other models are left queued, in order, for subsequent calls.
    /// Never returns an empty batch; returns `None` on shutdown with an
    /// empty queue.
    pub fn next_batch(&self) -> Option<Vec<InferRequest>> {
        let mut st = self.state.lock().unwrap();
        loop {
            loop {
                if !st.queue.is_empty() {
                    break;
                }
                if st.shutdown {
                    return None;
                }
                st = self.nonempty.wait(st).unwrap();
            }
            // The queue head picks the model; its cap bounds the batch.
            let head = st.queue.front().unwrap();
            let key = head.batch_key();
            let cap = self.max_batch.min(head.cap).max(1);
            // A first request exists; give the window a chance to fill
            // the batch with same-model company (skip the wait if
            // already full).
            let deadline = Instant::now() + self.window;
            loop {
                let matching = st.queue.iter().filter(|r| r.batch_key() == key).count();
                if matching >= cap || st.shutdown {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (next, timeout) = self
                    .nonempty
                    .wait_timeout(st, deadline - now)
                    .unwrap();
                st = next;
                if timeout.timed_out() {
                    break;
                }
            }
            // Extract up to `cap` same-model requests in FIFO order;
            // leave the rest queued in their original order.
            let mut batch = Vec::new();
            let mut rest = VecDeque::with_capacity(st.queue.len());
            while let Some(r) = st.queue.pop_front() {
                if batch.len() < cap && r.batch_key() == key {
                    batch.push(r);
                } else {
                    rest.push_back(r);
                }
            }
            st.queue = rest;
            if batch.is_empty() {
                // The window wait released the lock and another worker
                // drained this model's requests; go around — the head
                // (and its model) may have changed.
                continue;
            }
            if !st.queue.is_empty() {
                // Other-model requests stay queued; wake every waiter
                // (as in submit — a single wake could be consumed by a
                // worker window-waiting on a different model) so an
                // idle worker picks them up.
                self.nonempty.notify_all();
            }
            self.metrics.record_batch(batch.len());
            return Some(batch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::model::{build_random_model, ModelSpec};
    use std::sync::mpsc::channel;

    fn req(id: u64, tx: &Sender<(u64, Result<Vec<f32>, String>)>) -> InferRequest {
        InferRequest::new(id, vec![id as f32], tx.clone())
    }

    #[test]
    fn forms_full_batches_without_waiting() {
        let b = Batcher::new(4, Duration::from_millis(50), Arc::new(Metrics::new()));
        let (tx, _rx) = channel();
        for i in 0..4 {
            b.submit(req(i, &tx));
        }
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert!(t.elapsed() < Duration::from_millis(40), "full batch should not wait");
    }

    #[test]
    fn window_expiry_releases_partial_batch() {
        let b = Batcher::new(8, Duration::from_millis(20), Arc::new(Metrics::new()));
        let (tx, _rx) = channel();
        b.submit(req(1, &tx));
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn preserves_fifo_order() {
        let b = Batcher::new(3, Duration::from_millis(5), Arc::new(Metrics::new()));
        let (tx, _rx) = channel();
        for i in 0..5 {
            b.submit(req(i, &tx));
        }
        let first = b.next_batch().unwrap();
        let second = b.next_batch().unwrap();
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn shutdown_unblocks_workers() {
        let b = Arc::new(Batcher::new(4, Duration::from_millis(5), Arc::new(Metrics::new())));
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(10));
        b.shutdown();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn drains_queue_before_shutdown_none() {
        let b = Batcher::new(4, Duration::from_millis(1), Arc::new(Metrics::new()));
        let (tx, _rx) = channel();
        b.submit(req(7, &tx));
        b.shutdown();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(b.next_batch().is_none());
    }

    fn routed(
        id: u64,
        slot: &Arc<ModelSlot>,
        name: &str,
        tx: &Sender<(u64, Result<Vec<f32>, String>)>,
    ) -> InferRequest {
        InferRequest {
            model: name.to_string(),
            slot: Some(Arc::clone(slot)),
            cap: slot.batch_capacity(),
            ..InferRequest::new(id, vec![id as f32], tx.clone())
        }
    }

    fn test_slot(max_batch: usize, seed: u64) -> Arc<ModelSlot> {
        let model = build_random_model(&ModelSpec {
            inputs: 8,
            hidden: 32,
            outputs: 8,
            max_batch,
            pattern: crate::sparse::pattern::Pattern::Gs { b: 8, k: 8 },
            sparsity: 0.75,
            threads: 1,
            seed,
            ..ModelSpec::default()
        })
        .unwrap()
        .model;
        Arc::new(ModelSlot::new(model, "inline", 1))
    }

    #[test]
    fn batches_never_mix_models() {
        let b = Batcher::new(8, Duration::from_millis(1), Arc::new(Metrics::new()));
        let (tx, _rx) = channel();
        let (sa, sb) = (test_slot(8, 1), test_slot(8, 2));
        // Interleaved arrivals: a b a b a.
        let arrivals = [(&sa, "a"), (&sb, "b"), (&sa, "a"), (&sb, "b"), (&sa, "a")];
        for (i, (slot, name)) in arrivals.into_iter().enumerate() {
            b.submit(routed(i as u64, slot, name, &tx));
        }
        // Head is "a": its batch takes ids 0, 2, 4 (per-model FIFO).
        let first = b.next_batch().unwrap();
        assert!(first.iter().all(|r| r.model == "a"));
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 4]);
        // The "b" requests remained queued in order.
        let second = b.next_batch().unwrap();
        assert!(second.iter().all(|r| r.model == "b"));
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn per_model_cap_bounds_the_batch() {
        // Global max_batch 8, but the model's contract capacity is 2.
        let b = Batcher::new(8, Duration::from_millis(1), Arc::new(Metrics::new()));
        let (tx, _rx) = channel();
        let s = test_slot(2, 3);
        for i in 0..5 {
            b.submit(routed(i, &s, "m", &tx));
        }
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 1);
    }

    #[test]
    fn same_name_different_slot_does_not_mix() {
        // A replaced slot under the same name: older requests hold the
        // old Arc and must not share a batch with new ones.
        let b = Batcher::new(8, Duration::from_millis(1), Arc::new(Metrics::new()));
        let (tx, _rx) = channel();
        let (old, new) = (test_slot(8, 4), test_slot(8, 5));
        b.submit(routed(0, &old, "m", &tx));
        b.submit(routed(1, &new, "m", &tx));
        let first = b.next_batch().unwrap();
        assert_eq!(first.len(), 1);
        assert!(Arc::ptr_eq(first[0].slot.as_ref().unwrap(), &old));
        let second = b.next_batch().unwrap();
        assert!(Arc::ptr_eq(second[0].slot.as_ref().unwrap(), &new));
    }
}
