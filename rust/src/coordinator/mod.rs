//! Serving coordinator (Layer 3): router, dynamic batcher, worker pool.
//!
//! The request path is pure Rust: TCP connections speak a JSON-lines
//! protocol ([`server`]), requests flow into a [`batcher::Batcher`] that
//! forms batches up to the artifact's static batch size within a small
//! latency window, and worker threads execute the Pallas-backed
//! `mlp_forward` artifact through [`crate::runtime`]. The GS-compressed
//! output projection travels to the device as `value`/`index` tensors in
//! the uniform layout (see [`uniform`]), produced from a [`GsFormat`]
//! built by the pruner — the same format the cycle simulator executes.

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod uniform;

pub use batcher::{Batcher, InferRequest};
pub use metrics::Metrics;
pub use server::{serve, Client, ServerHandle};
pub use uniform::UniformGs;

use crate::runtime::{Executable, Manifest, Runtime, Tensor};
use anyhow::{ensure, Context, Result};
use std::sync::Arc;

/// The deployed sparse model: compiled forward artifact + resident weights.
pub struct SparseModel {
    exe: Executable,
    pub inputs: usize,
    pub hidden: usize,
    pub outputs: usize,
    pub max_batch: usize,
    w1: Tensor,
    b1: Tensor,
    gs_value: Tensor,
    gs_index: Tensor,
    b2: Tensor,
}

impl SparseModel {
    /// Load the `mlp_forward` artifact and install weights. `gs` must be
    /// the `GS(B,B)` compression of the `[outputs, hidden]` projection
    /// with exactly the manifest's static group count after padding.
    pub fn load(
        rt: &Runtime,
        manifest: &Manifest,
        w1: Vec<f32>,
        b1: Vec<f32>,
        gs: &UniformGs,
        b2: Vec<f32>,
    ) -> Result<SparseModel> {
        let cfg = &manifest.mlp;
        let (inputs, hidden, outputs, max_batch) = (
            cfg.cfg("inputs")?,
            cfg.cfg("hidden")?,
            cfg.cfg("outputs")?,
            cfg.cfg("batch")?,
        );
        ensure!(gs.nbands == outputs, "GS bands {} != outputs {outputs}", gs.nbands);
        ensure!(gs.b == cfg.cfg("gs_b")?, "GS B mismatch");
        ensure!(
            gs.groups == cfg.cfg("gs_groups")?,
            "GS group count {} != artifact static {}",
            gs.groups,
            cfg.cfg("gs_groups")?
        );
        let exe = rt
            .load_hlo(&cfg.forward_path)
            .context("load mlp_forward artifact")?;
        Ok(SparseModel {
            exe,
            inputs,
            hidden,
            outputs,
            max_batch,
            w1: Tensor::f32(&[inputs, hidden], w1),
            b1: Tensor::f32(&[hidden], b1),
            gs_value: gs.value_tensor(),
            gs_index: gs.index_tensor(),
            b2: Tensor::f32(&[outputs], b2),
        })
    }

    /// Run one padded batch; `rows` ≤ `max_batch` inputs of `inputs` f32.
    pub fn infer_batch(&self, rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        ensure!(rows.len() <= self.max_batch, "batch too large");
        let mut x = vec![0.0f32; self.max_batch * self.inputs];
        for (i, row) in rows.iter().enumerate() {
            ensure!(row.len() == self.inputs, "input width {} != {}", row.len(), self.inputs);
            x[i * self.inputs..(i + 1) * self.inputs].copy_from_slice(row);
        }
        let out = self.exe.run(&[
            Tensor::f32(&[self.max_batch, self.inputs], x),
            self.w1.clone(),
            self.b1.clone(),
            self.gs_value.clone(),
            self.gs_index.clone(),
            self.b2.clone(),
        ])?;
        ensure!(out.len() == 1, "forward output arity");
        let logits = out[0].as_f32()?;
        Ok(rows
            .iter()
            .enumerate()
            .map(|(i, _)| logits[i * self.outputs..(i + 1) * self.outputs].to_vec())
            .collect())
    }
}

/// Everything the serving loop needs, shareable across threads.
pub struct Engine {
    pub model: SparseModel,
    pub metrics: Arc<Metrics>,
}
