//! Serving coordinator (Layer 3): router, dynamic batcher, worker pool.
//!
//! The request path is pure Rust: TCP connections speak a JSON-lines
//! protocol with an opt-in negotiated binary framing for the infer
//! data plane ([`server`], [`wire`]; a readiness event loop multiplexes
//! every socket onto one thread — see [`crate::util::poll`]), requests
//! flow into a [`batcher::Batcher`]
//! holding per-model sub-queues behind a FIFO ready-list (idle workers
//! claim and drain *different* models concurrently; batches form up to
//! the model's batch capacity within a latency window anchored at the
//! head request's enqueue time; with a configured queue depth, overload
//! is shed — longest-queue-drop fair across models — with a
//! `retry_after_ms` hint instead of queueing without bound), and worker
//! threads execute the forward pass through a selectable
//! [`SparseModel`] backend:
//!
//! * **native** (default, always available) — the prepacked
//!   [`GsExecPlan`] engine from [`crate::kernels::exec`]: a cache-blocked
//!   batched dense input layer ([`crate::kernels::dense`]), then the
//!   GS-compressed output projection as a batched gather-scatter spMM
//!   with the output bias fused into the accumulation (no separate pass
//!   over the logits) — every stage runs on the kernel [`ThreadPool`]
//!   when one is configured, so the whole `infer_batch` is parallel, not
//!   just the spMM. Plan values are stored at f32 or the paper's f16
//!   resolution ([`PlanPrecision`]). No artifacts, no Python, no
//!   external runtime.
//! * **pjrt** (`pjrt` cargo feature) — the Pallas-backed `mlp_forward`
//!   AOT artifact executed through [`crate::runtime`], taking the GS
//!   weights as uniform `value`/`index` tensors (see [`uniform`]).
//!
//! Native serving goes through [`serve_store`] and an [`Engine`]
//! wrapping the whole [`crate::model_store::ModelStore`]: requests route
//! by an optional `"model"` field to named versioned slots (batches are
//! model-homogeneous; per-slot metrics; LRU eviction of cold models
//! under a capacity bound), workers snapshot the routed slot once per
//! batch, and `{"op":"swap"|"load","path":"model.gsm"}` hot-deploys new
//! prunings with zero downtime (see [`crate::model_store`]).
//!
//! The serving tier carries a **resilience layer**: per-request queue
//! deadlines enforced at batch formation (expired requests fail with a
//! structured reject and an `expired` metric — `requests == responses +
//! errors + shed + expired` holds exactly), connection hardening
//! (connection cap, idle timeouts, bounded frame reader), supervised
//! batch execution (a panicking kernel fails one batch, not a worker),
//! and a deterministic fault-injection harness ([`faults`], gated
//! behind the `fault-inject` cargo feature) that the chaos test suite
//! drives.
//!
//! On top of that sits a **deployment-safety layer** (store mode):
//! slots retain previous generations for `{"op":"rollback"}` and for
//! canary swaps (`{"op":"swap",...,"canary":{...}}` watches the new
//! generation's first N requests and auto-rolls-back past the error
//! budget), a quarantine circuit breaker fast-fails requests to a
//! repeatedly failing model until a half-open probe succeeds, and
//! `--store-dir` persists a crash-recoverable CRC-checked manifest of
//! the registry, replayed on startup (see
//! [`crate::model_store::manifest`]).
//!
//! An **observability layer** spans the whole pipeline: a [`trace`]
//! flight recorder captures structured request lifecycle events
//! (drained via `{"op":"trace"}`), [`metrics`] attributes per-request
//! time to pipeline stages with fixed-memory log-scale histograms
//! (surfaced in `stats.stages` and the `{"op":"metrics"}` Prometheus
//! exposition), and the kernel chunk profiler
//! ([`crate::kernels::profile`], `{"op":"profile"}`) measures whether
//! the GS plan's group-count-balanced chunks actually run balanced.
//!
//! Both backends compute the same forward graph
//! (`relu(x@W1+b1) → GS spMM → +b2`); each is checked against a dense
//! oracle of its own weights by integration tests. (A direct
//! native-vs-pjrt comparison on shared weights needs the real `xla`
//! crate — see ROADMAP.)

pub mod batcher;
pub mod faults;
pub mod metrics;
pub mod server;
pub mod trace;
pub mod uniform;
pub mod wire;

pub use batcher::{Batcher, InferRequest, Reject, SubmitError};
pub use metrics::{Metrics, ModelMetrics, Stage};
pub use trace::{EventKind, FlightRecorder, TraceEvent};
pub use server::{
    serve, serve_slot, serve_store, Client, InferOutcome, PipelinedClient, PipelinedReply,
    ServerHandle,
};
pub use uniform::UniformGs;

use crate::kernels::dense::{dense_matmul, dense_matmul_parallel};
use crate::kernels::dispatch::KernelVariant;
use crate::kernels::exec::{GsExecPlan, PlanPrecision};
use crate::sparse::format::GsFormat;
use crate::util::threadpool::{partition_spans, resolve_threads, ThreadPool};
use anyhow::{ensure, Result};
use std::sync::Arc;

#[cfg(feature = "pjrt")]
use crate::runtime::{Executable, Manifest, Runtime, Tensor};
#[cfg(feature = "pjrt")]
use anyhow::Context;

/// The deployed sparse model: resident weights + an execution backend.
pub struct SparseModel {
    pub inputs: usize,
    pub hidden: usize,
    pub outputs: usize,
    pub max_batch: usize,
    backend: Backend,
}

enum Backend {
    Native(NativeBackend),
    #[cfg(feature = "pjrt")]
    Pjrt(PjrtBackend),
}

/// Native execution state: prepacked GS plan + dense layer weights.
/// Weights are `Arc`-shared so the pool's `'static` jobs can borrow them
/// without copying per request.
struct NativeBackend {
    /// `[inputs, hidden]` row-major (the `x @ w1` layout).
    w1: Arc<Vec<f32>>,
    b1: Arc<Vec<f32>>,
    plan: Arc<GsExecPlan>,
    b2: Arc<Vec<f32>>,
    /// Worker pool for the parallel stages (None = serial).
    pool: Option<Arc<ThreadPool>>,
}

#[cfg(feature = "pjrt")]
struct PjrtBackend {
    exe: Executable,
    w1: Tensor,
    b1: Tensor,
    gs_value: Tensor,
    gs_index: Tensor,
    b2: Tensor,
}

impl SparseModel {
    /// Build the native-engine model. `gs` is the GS compression of the
    /// `[outputs, hidden]` projection (any `GS(B,k)` / scatter pattern);
    /// the plan is packed once here — at `precision` — and shared across
    /// requests. `threads` selects the kernel parallelism: `0`
    /// auto-detects the machine's available parallelism, `1` runs
    /// serial, `N > 1` uses `N` kernel threads for every stage of the
    /// forward pass. Results are bit-identical at any thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn native(
        w1: Vec<f32>,
        b1: Vec<f32>,
        gs: &GsFormat,
        b2: Vec<f32>,
        inputs: usize,
        max_batch: usize,
        threads: usize,
        precision: PlanPrecision,
    ) -> Result<SparseModel> {
        SparseModel::native_pinned(w1, b1, gs, b2, inputs, max_batch, threads, precision, None)
    }

    /// [`SparseModel::native`] with an optional dispatch-kernel pin —
    /// the variant an artifact's `.gsm` metadata carries
    /// ([`crate::model_store::ModelArtifact::kernel_variant`]). A pin
    /// that fits the packed plan's geometry overrides the pack-time
    /// classification; one that doesn't (different build, different
    /// chunking) is ignored and the plan serves on its classification —
    /// every variant is bit-identical, so the pin is purely a
    /// performance hint.
    #[allow(clippy::too_many_arguments)]
    pub fn native_pinned(
        w1: Vec<f32>,
        b1: Vec<f32>,
        gs: &GsFormat,
        b2: Vec<f32>,
        inputs: usize,
        max_batch: usize,
        threads: usize,
        precision: PlanPrecision,
        variant: Option<KernelVariant>,
    ) -> Result<SparseModel> {
        let threads = resolve_threads(threads);
        let hidden = gs.cols;
        let outputs = gs.rows;
        ensure!(max_batch > 0, "max_batch must be positive");
        ensure!(
            w1.len() == inputs * hidden,
            "w1 length {} != inputs*hidden {}",
            w1.len(),
            inputs * hidden
        );
        ensure!(b1.len() == hidden, "b1 length {} != hidden {hidden}", b1.len());
        ensure!(b2.len() == outputs, "b2 length {} != outputs {outputs}", b2.len());
        let mut plan = GsExecPlan::with_precision(gs, threads.max(1), precision)?;
        if let Some(v) = variant {
            if v.supports(&plan) {
                plan.set_kernel_variant(v)?;
            }
        }
        let plan = Arc::new(plan);
        let pool = if threads > 1 {
            Some(Arc::new(ThreadPool::new(threads)))
        } else {
            None
        };
        Ok(SparseModel {
            inputs,
            hidden,
            outputs,
            max_batch,
            backend: Backend::Native(NativeBackend {
                w1: Arc::new(w1),
                b1: Arc::new(b1),
                plan,
                b2: Arc::new(b2),
                pool,
            }),
        })
    }

    /// The packed-plan value precision of the native backend (None for
    /// pjrt).
    pub fn precision(&self) -> Option<PlanPrecision> {
        match &self.backend {
            Backend::Native(nb) => Some(nb.plan.precision),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => None,
        }
    }

    /// The dispatch-kernel variant the native backend's plan executes
    /// on (None for pjrt) — surfaced per-slot in `{"op":"models"}`,
    /// stats, and the Prometheus exposition.
    pub fn kernel_variant(&self) -> Option<KernelVariant> {
        match &self.backend {
            Backend::Native(nb) => Some(nb.plan.kernel_variant()),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => None,
        }
    }

    /// Load the `mlp_forward` PJRT artifact and install weights. `gs`
    /// must be the `GS(B,B)` compression of the `[outputs, hidden]`
    /// projection with exactly the manifest's static group count after
    /// padding.
    #[cfg(feature = "pjrt")]
    pub fn load(
        rt: &Runtime,
        manifest: &Manifest,
        w1: Vec<f32>,
        b1: Vec<f32>,
        gs: &UniformGs,
        b2: Vec<f32>,
    ) -> Result<SparseModel> {
        let cfg = &manifest.mlp;
        let (inputs, hidden, outputs, max_batch) = (
            cfg.cfg("inputs")?,
            cfg.cfg("hidden")?,
            cfg.cfg("outputs")?,
            cfg.cfg("batch")?,
        );
        ensure!(gs.nbands == outputs, "GS bands {} != outputs {outputs}", gs.nbands);
        ensure!(gs.b == cfg.cfg("gs_b")?, "GS B mismatch");
        ensure!(
            gs.groups == cfg.cfg("gs_groups")?,
            "GS group count {} != artifact static {}",
            gs.groups,
            cfg.cfg("gs_groups")?
        );
        let exe = rt
            .load_hlo(&cfg.forward_path)
            .context("load mlp_forward artifact")?;
        Ok(SparseModel {
            inputs,
            hidden,
            outputs,
            max_batch,
            backend: Backend::Pjrt(PjrtBackend {
                exe,
                w1: Tensor::f32(&[inputs, hidden], w1),
                b1: Tensor::f32(&[hidden], b1),
                gs_value: gs.value_tensor(),
                gs_index: gs.index_tensor(),
                b2: Tensor::f32(&[outputs], b2),
            }),
        })
    }

    /// Which backend executes requests ("native" or "pjrt").
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            Backend::Native(_) => "native",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Run one batch; `rows.len()` ≤ `max_batch` inputs of `inputs` f32.
    pub fn infer_batch(&self, rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        ensure!(rows.len() <= self.max_batch, "batch too large");
        for row in rows {
            ensure!(
                row.len() == self.inputs,
                "input width {} != {}",
                row.len(),
                self.inputs
            );
        }
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        match &self.backend {
            Backend::Native(nb) => Ok(self.infer_native(nb, rows)),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(pb) => self.infer_pjrt(pb, rows),
        }
    }

    /// Native forward: `h = relu(x @ w1 + b1)` through the cache-blocked
    /// batched dense kernel, then the GS projection through the packed
    /// plan with the output bias *fused* into the spMM (rows are seeded
    /// with `b2` before the gather-FMA sweep — no separate pass over the
    /// logits) — the same graph as the Pallas artifact. With a pool,
    /// every stage runs parallel: the dense layer over feature spans,
    /// the bias-fused spMM over balanced band chunks, the transpose over
    /// batch columns — and each stage is bit-identical to its serial
    /// form, so serial and parallel models agree exactly.
    fn infer_native(&self, nb: &NativeBackend, rows: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let batch = rows.len();
        // Hidden activations, feature-major [hidden][batch] for the spMM,
        // relu fused into the dense kernel's write-back.
        let h = match &nb.pool {
            // batch 1 is a GEMV: pool dispatch + the batch copy would
            // cost more than the serial kernel, so only fan out real
            // batches (mirrors the transpose stage's guard below).
            Some(pool) if batch > 1 => {
                // One batch-sized copy to satisfy the pool's 'static job
                // bound — small next to the batch×inputs×hidden GEMM it
                // unlocks.
                let xs = Arc::new(rows.to_vec());
                dense_matmul_parallel(&nb.w1, &nb.b1, &xs, self.inputs, self.hidden, true, pool)
            }
            _ => dense_matmul(&nb.w1, &nb.b1, rows, self.inputs, self.hidden, true),
        };
        // Single dispatch entry point: runs the plan's classified /
        // tuned / artifact-pinned kernel variant, pooled when the plan
        // has parallelism to exploit, serial otherwise.
        let h = Arc::new(h);
        let out_t =
            GsExecPlan::execute_bias(&nb.plan, &h, batch, Some(&nb.b2), nb.pool.as_deref());
        // Transpose to request-major (bias already folded into the spMM).
        // Parallel over contiguous batch spans — at most one job per
        // worker, so dispatch overhead never exceeds a handful of
        // submissions (a job per *row* would cost more synchronization
        // than the O(outputs) copies it does).
        match &nb.pool {
            Some(pool) if batch > 1 => {
                let out_t = Arc::new(out_t);
                let outputs = self.outputs;
                let spans = partition_spans(batch, pool.workers());
                let chunks = pool.map(spans, move |(lo, hi)| {
                    (lo..hi)
                        .map(|r| {
                            (0..outputs)
                                .map(|o| out_t[o * batch + r])
                                .collect::<Vec<f32>>()
                        })
                        .collect::<Vec<Vec<f32>>>()
                });
                chunks.into_iter().flatten().collect()
            }
            _ => (0..batch)
                .map(|r| {
                    (0..self.outputs)
                        .map(|o| out_t[o * batch + r])
                        .collect()
                })
                .collect(),
        }
    }

    /// PJRT forward: pad to the artifact's static batch and execute.
    #[cfg(feature = "pjrt")]
    fn infer_pjrt(&self, pb: &PjrtBackend, rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mut x = vec![0.0f32; self.max_batch * self.inputs];
        for (i, row) in rows.iter().enumerate() {
            x[i * self.inputs..(i + 1) * self.inputs].copy_from_slice(row);
        }
        let out = pb.exe.run(&[
            Tensor::f32(&[self.max_batch, self.inputs], x),
            pb.w1.clone(),
            pb.b1.clone(),
            pb.gs_value.clone(),
            pb.gs_index.clone(),
            pb.b2.clone(),
        ])?;
        ensure!(out.len() == 1, "forward output arity");
        let logits = out[0].as_f32()?;
        Ok(rows
            .iter()
            .enumerate()
            .map(|(i, _)| logits[i * self.outputs..(i + 1) * self.outputs].to_vec())
            .collect())
    }
}

/// Everything the serving loop shares across threads: the whole model
/// registry ([`crate::model_store::ModelStore`]) requests route through
/// — each slot a versioned [`crate::model_store::ModelSlot`] workers
/// snapshot once per batch, the handles live `{"op":"swap"}`/`"load"`
/// requests deploy through — the name unqualified requests default to,
/// and the metrics sink. `threads = 0` auto-detects the machine's
/// parallelism for the kernel pool (see
/// [`crate::util::threadpool::resolve_threads`]).
pub struct Engine {
    pub store: Arc<crate::model_store::ModelStore>,
    /// The slot requests without a `"model"` field route to (pinned —
    /// LRU eviction never removes it).
    pub default_model: String,
    pub metrics: Arc<Metrics>,
    /// Kernel-thread setting models deployed at runtime (`load`)
    /// instantiate with (0 = auto-detect).
    pub threads: usize,
}

impl Engine {
    /// Wrap `model` (deployment version 1, from `source`) as the pinned
    /// `"default"` slot of a fresh unbounded store + metrics. `threads`
    /// is recorded as the kernel-thread setting future artifact deploys
    /// (`swap`/`load`) instantiate with (0 = auto-detect).
    pub fn new(model: SparseModel, source: &str, threads: usize) -> Engine {
        let store = Arc::new(crate::model_store::ModelStore::new());
        store
            .register(
                "default",
                Arc::new(crate::model_store::ModelSlot::new(model, source, threads)),
            )
            .expect("fresh unbounded store cannot reject a registration");
        Engine {
            store,
            default_model: "default".to_string(),
            metrics: Arc::new(Metrics::new()),
            threads,
        }
    }

    /// Wrap an already-populated registry. `default` must name a
    /// registered slot (unqualified requests route to it) and be the
    /// store's pinned name — otherwise an unload or LRU eviction could
    /// remove the slot every unqualified request depends on.
    pub fn from_store(
        store: Arc<crate::model_store::ModelStore>,
        default: &str,
        threads: usize,
    ) -> Result<Engine> {
        ensure!(
            store.get(default).is_some(),
            "default model \"{default}\" is not registered in the store"
        );
        ensure!(
            store.pinned_name() == default,
            "default model \"{default}\" must be the store's pinned name \
             (the store pins \"{}\")",
            store.pinned_name()
        );
        Ok(Engine {
            store,
            default_model: default.to_string(),
            metrics: Arc::new(Metrics::new()),
            threads,
        })
    }

    /// The slot unqualified requests execute on.
    pub fn default_slot(&self) -> Arc<crate::model_store::ModelSlot> {
        self.store
            .get(&self.default_model)
            .expect("the default slot is pinned and cannot be evicted or unloaded")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::dense::Dense;
    use crate::sparse::pattern::Pattern;
    use crate::testing::model::{build_random_model, BuiltModel, ModelSpec};
    use crate::util::prng::Prng;

    fn fixture_spec(threads: usize, precision: PlanPrecision) -> ModelSpec {
        ModelSpec {
            inputs: 12,
            // > 2×FEAT_BLOCK so the parallel dense path really splits
            // into multiple feature spans (not the serial fallback).
            hidden: 160,
            outputs: 16,
            max_batch: 8,
            pattern: Pattern::Gs { b: 8, k: 8 },
            sparsity: 0.75,
            threads,
            precision,
            ..ModelSpec::default()
        }
    }

    /// `threads: 1` = serial (0 would auto-detect the machine).
    fn native_fixture(threads: usize) -> BuiltModel {
        build_random_model(&fixture_spec(threads, PlanPrecision::F32)).unwrap()
    }

    /// Reference forward pass straight off the dense matrices.
    fn oracle(
        proj: &Dense,
        w1: &[f32],
        b1: &[f32],
        b2: &[f32],
        inputs: usize,
        x: &[f32],
    ) -> Vec<f32> {
        let hidden = proj.cols;
        let mut h = vec![0.0f32; hidden];
        for j in 0..hidden {
            let mut acc = b1[j];
            for i in 0..inputs {
                acc += x[i] * w1[i * hidden + j];
            }
            h[j] = acc.max(0.0);
        }
        (0..proj.rows)
            .map(|r| {
                b2[r]
                    + proj
                        .row(r)
                        .iter()
                        .zip(&h)
                        .map(|(&w, &a)| w * a)
                        .sum::<f32>()
            })
            .collect()
    }

    #[test]
    fn native_backend_matches_dense_oracle() {
        let bm = native_fixture(1);
        assert_eq!(bm.model.backend_name(), "native");
        assert_eq!(bm.model.precision(), Some(PlanPrecision::F32));
        let mut rng = Prng::new(9);
        let rows: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(12, 1.0)).collect();
        let got = bm.model.infer_batch(&rows).unwrap();
        for (r, x) in rows.iter().enumerate() {
            let want = oracle(&bm.proj, &bm.w1, &bm.b1, &bm.b2, 12, x);
            for (o, (g, w)) in got[r].iter().zip(&want).enumerate() {
                assert!((g - w).abs() < 1e-3, "row {r} output {o}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn native_parallel_matches_serial() {
        // Every stage (dense, spMM, bias) is bit-identical serial vs
        // parallel, at both plan precisions.
        for precision in [PlanPrecision::F32, PlanPrecision::F16] {
            let serial = build_random_model(&fixture_spec(1, precision)).unwrap();
            let parallel = build_random_model(&fixture_spec(3, precision)).unwrap();
            let mut rng = Prng::new(17);
            let rows: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(12, 1.0)).collect();
            assert_eq!(
                serial.model.infer_batch(&rows).unwrap(),
                parallel.model.infer_batch(&rows).unwrap(),
                "{}",
                precision.name()
            );
        }
    }

    #[test]
    fn f16_model_tracks_f32_model() {
        let f32m = native_fixture(1);
        let f16m = build_random_model(&fixture_spec(1, PlanPrecision::F16)).unwrap();
        assert_eq!(f16m.model.precision(), Some(PlanPrecision::F16));
        let mut rng = Prng::new(23);
        let rows: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(12, 1.0)).collect();
        let a = f32m.model.infer_batch(&rows).unwrap();
        let b = f16m.model.infer_batch(&rows).unwrap();
        for (r, (ra, rb)) in a.iter().zip(&b).enumerate() {
            for (o, (x, y)) in ra.iter().zip(rb).enumerate() {
                // Only the projection weights are quantized; logits are
                // O(1), so a small absolute budget covers the 2^-11
                // per-weight rounding.
                assert!((x - y).abs() < 1e-2, "row {r} out {o}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn native_rejects_bad_shapes() {
        let bm = native_fixture(1);
        assert!(bm.model.infer_batch(&[vec![0.0; 5]]).is_err()); // wrong width
        let too_many: Vec<Vec<f32>> = (0..9).map(|_| vec![0.0; 12]).collect();
        assert!(bm.model.infer_batch(&too_many).is_err()); // over max_batch
        assert!(bm.model.infer_batch(&[]).unwrap().is_empty());
    }
}
