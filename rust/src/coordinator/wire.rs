//! Binary wire framing for the serving protocol.
//!
//! The server speaks two framings on the same TCP stream, discriminated
//! per frame by the first byte:
//!
//! * **JSON lines** (the default): one `{...}\n` object per request or
//!   reply. Always available; the entire control plane (load / swap /
//!   rollback / stats / trace / metrics / ...) stays JSON-only.
//! * **Binary frames** (opt-in, negotiated): a fixed 16-byte
//!   little-endian header followed by `len` payload bytes, used for the
//!   infer data plane so f32 input and logit vectors cross the wire as
//!   raw bits instead of base-10 text.
//!
//! The discriminator is sound because [`MAGIC`] (`0xF5`) is a UTF-8
//! continuation byte: it can never begin a JSON line, so a byte stream
//! position either starts a binary frame or a JSON line, never
//! ambiguously both.
//!
//! ## Frame layout (all integers little-endian)
//!
//! | offset | size | field   | meaning                                  |
//! |--------|------|---------|------------------------------------------|
//! | 0      | 1    | magic   | always `0xF5`                            |
//! | 1      | 1    | version | protocol version (currently 1)           |
//! | 2      | 1    | opcode  | see [`Opcode`]                           |
//! | 3      | 1    | flags   | reserved, must be 0                      |
//! | 4      | 8    | id      | client-chosen request id (echoed back)   |
//! | 12     | 4    | len     | payload byte length                      |
//! | 16     | len  | payload | opcode-specific                          |
//!
//! ## Negotiation
//!
//! A client that wants binary framing sends a `HELLO` frame followed by
//! a bare `\n` immediately after connecting. A binary-capable server
//! replies `HELLO_ACK` (carrying the version it will speak) and the
//! trailing newline parses as an empty JSON line, which the server
//! skips. An old JSON-only server instead reads the HELLO bytes + the
//! newline as one garbage line and replies with a `bad json: ...`
//! error object — the client takes any leading non-magic byte in the
//! reply as the signal to fall back to JSON framing. Either way the
//! connection stays usable without a reconnect.
//!
//! ## Infer payloads
//!
//! `INFER` (client → server): `model_len: u16` (0 = the server's
//! default model), `flags: u8` (bit 0 = deadline present), one reserved
//! byte, `deadline_ms: u32`, `model_len` bytes of UTF-8 model name,
//! then the input vector as raw f32 little-endian (payload remainder
//! must be a multiple of 4).
//!
//! `OUTPUT` (server → client): the logit vector as raw f32
//! little-endian. `ERROR` (server → client): a UTF-8 JSON object with
//! the same fields a JSON-framed error reply would carry (`error`, and
//! optionally `retry_after_ms` / `waited_ms` / `quarantined_for_ms`),
//! so structured reject semantics are identical across framings.

use std::collections::VecDeque;

/// First byte of every binary frame. A UTF-8 continuation byte, so no
/// JSON line can ever start with it.
pub const MAGIC: u8 = 0xF5;

/// The protocol version this build speaks.
pub const VERSION: u8 = 1;

/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 16;

/// Binary frame opcodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Opcode {
    /// Client → server: request binary framing.
    Hello = 1,
    /// Server → client: binary framing granted.
    HelloAck = 2,
    /// Client → server: infer request (raw f32 input).
    Infer = 3,
    /// Server → client: infer success (raw f32 logits).
    Output = 4,
    /// Server → client: structured error (UTF-8 JSON payload).
    Error = 5,
}

impl Opcode {
    pub fn from_u8(v: u8) -> Option<Opcode> {
        match v {
            1 => Some(Opcode::Hello),
            2 => Some(Opcode::HelloAck),
            3 => Some(Opcode::Infer),
            4 => Some(Opcode::Output),
            5 => Some(Opcode::Error),
            _ => None,
        }
    }
}

/// A parsed binary frame header.
#[derive(Clone, Copy, Debug)]
pub struct FrameHeader {
    pub version: u8,
    pub opcode: Opcode,
    pub flags: u8,
    pub id: u64,
    pub len: u32,
}

impl FrameHeader {
    /// Parse a 16-byte header. Rejects a bad magic byte or unknown
    /// opcode; version is carried through for the caller to judge
    /// (HELLO negotiates versions, so the parser cannot pre-reject).
    pub fn parse(bytes: &[u8; HEADER_LEN]) -> Result<FrameHeader, String> {
        if bytes[0] != MAGIC {
            return Err(format!("bad frame magic 0x{:02x}", bytes[0]));
        }
        let opcode = Opcode::from_u8(bytes[2])
            .ok_or_else(|| format!("unknown opcode {}", bytes[2]))?;
        Ok(FrameHeader {
            version: bytes[1],
            opcode,
            flags: bytes[3],
            id: u64::from_le_bytes(bytes[4..12].try_into().unwrap()),
            len: u32::from_le_bytes(bytes[12..16].try_into().unwrap()),
        })
    }

    pub fn encode(&self) -> [u8; HEADER_LEN] {
        let mut out = [0u8; HEADER_LEN];
        out[0] = MAGIC;
        out[1] = self.version;
        out[2] = self.opcode as u8;
        out[3] = self.flags;
        out[4..12].copy_from_slice(&self.id.to_le_bytes());
        out[12..16].copy_from_slice(&self.len.to_le_bytes());
        out
    }
}

/// Encode one complete frame (header + payload).
pub fn frame(opcode: Opcode, id: u64, payload: &[u8]) -> Vec<u8> {
    let header = FrameHeader {
        version: VERSION,
        opcode,
        flags: 0,
        id,
        len: payload.len() as u32,
    };
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&header.encode());
    out.extend_from_slice(payload);
    out
}

/// The client's opening negotiation bytes: a HELLO frame plus one bare
/// newline. A binary-capable server skips the newline as an empty JSON
/// line; an old JSON-only server reads everything as one garbage line
/// and replies `bad json: ...`, which is the client's fallback signal.
pub fn hello_frame() -> Vec<u8> {
    let mut out = frame(Opcode::Hello, 0, &[]);
    out.push(b'\n');
    out
}

/// The server's grant reply to a HELLO.
pub fn hello_ack_frame() -> Vec<u8> {
    frame(Opcode::HelloAck, 0, &[])
}

/// Serialize f32s as raw little-endian bytes.
pub fn f32s_le(values: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len() * 4);
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Deserialize raw little-endian bytes to f32s. `bytes.len()` must be a
/// multiple of 4.
pub fn le_f32s(bytes: &[u8]) -> Result<Vec<f32>, String> {
    if bytes.len() % 4 != 0 {
        return Err(format!(
            "f32 vector payload length {} is not a multiple of 4",
            bytes.len()
        ));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

const INFER_DEADLINE_FLAG: u8 = 0x01;
const INFER_PREFIX_LEN: usize = 8;

/// Encode an INFER payload (not the frame — see [`frame`]).
pub fn encode_infer(model: Option<&str>, deadline_ms: Option<u64>, input: &[f32]) -> Vec<u8> {
    let model = model.unwrap_or("");
    debug_assert!(model.len() <= u16::MAX as usize);
    let mut out = Vec::with_capacity(INFER_PREFIX_LEN + model.len() + input.len() * 4);
    out.extend_from_slice(&(model.len() as u16).to_le_bytes());
    out.push(if deadline_ms.is_some() { INFER_DEADLINE_FLAG } else { 0 });
    out.push(0); // reserved
    let deadline = deadline_ms.unwrap_or(0).min(u32::MAX as u64) as u32;
    out.extend_from_slice(&deadline.to_le_bytes());
    out.extend_from_slice(model.as_bytes());
    out.extend_from_slice(&f32s_le(input));
    out
}

/// A decoded INFER payload.
#[derive(Debug)]
pub struct InferPayload {
    /// `None` = route to the server's default model.
    pub model: Option<String>,
    /// `None` = use the server's configured deadline.
    pub deadline_ms: Option<u64>,
    pub input: Vec<f32>,
}

impl InferPayload {
    pub fn decode(payload: &[u8]) -> Result<InferPayload, String> {
        if payload.len() < INFER_PREFIX_LEN {
            return Err(format!(
                "infer payload too short: {} bytes < {INFER_PREFIX_LEN}-byte prefix",
                payload.len()
            ));
        }
        let model_len = u16::from_le_bytes(payload[0..2].try_into().unwrap()) as usize;
        let flags = payload[2];
        let deadline = u32::from_le_bytes(payload[4..8].try_into().unwrap());
        let rest = &payload[INFER_PREFIX_LEN..];
        if rest.len() < model_len {
            return Err(format!(
                "infer payload truncated: model_len {model_len} > {} remaining bytes",
                rest.len()
            ));
        }
        let (model_bytes, input_bytes) = rest.split_at(model_len);
        let model = if model_len == 0 {
            None
        } else {
            Some(
                std::str::from_utf8(model_bytes)
                    .map_err(|_| "model name is not valid UTF-8".to_string())?
                    .to_string(),
            )
        };
        let deadline_ms = if flags & INFER_DEADLINE_FLAG != 0 {
            Some(deadline as u64)
        } else {
            None
        };
        Ok(InferPayload {
            model,
            deadline_ms,
            input: le_f32s(input_bytes)?,
        })
    }
}

/// One frame off the wire, in either framing.
#[derive(Debug)]
pub enum WireFrame {
    /// A complete JSON line (newline stripped, may be empty/whitespace).
    Json(String),
    /// A complete binary frame.
    Binary(FrameHeader, Vec<u8>),
}

/// Why decoding stopped hard (the connection must close).
#[derive(Debug)]
pub enum DecodeError {
    /// A frame (either framing) declared or accumulated more bytes than
    /// the configured bound. Detected from the header's declared length
    /// *before* any payload is buffered.
    TooLarge { declared: usize, limit: usize },
    /// A malformed binary header (bad magic mid-stream, unknown opcode).
    Header(String),
}

/// Incremental dual-framing frame decoder with a hard size bound.
///
/// Feed raw bytes in, pull complete frames out. Each frame boundary
/// re-discriminates on the first byte, so binary frames and JSON lines
/// interleave freely on one stream (the control plane stays JSON even
/// after binary negotiation).
pub struct FrameDecoder {
    buf: VecDeque<u8>,
    max_frame_bytes: usize,
}

impl FrameDecoder {
    /// `max_frame_bytes = 0` means unbounded.
    pub fn new(max_frame_bytes: usize) -> FrameDecoder {
        FrameDecoder { buf: VecDeque::new(), max_frame_bytes }
    }

    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend(bytes);
    }

    fn over_limit(&self, n: usize) -> bool {
        self.max_frame_bytes > 0 && n > self.max_frame_bytes
    }

    /// Pull the next complete frame, if one is buffered.
    ///
    /// `Ok(None)` = need more bytes. The oversize check fires from the
    /// binary header's *declared* length (or the accumulated
    /// newline-less JSON bytes) before any oversized payload is
    /// buffered into a frame.
    pub fn next(&mut self) -> Result<Option<WireFrame>, DecodeError> {
        let first = match self.buf.front() {
            Some(&b) => b,
            None => return Ok(None),
        };
        if first == MAGIC {
            if self.buf.len() < HEADER_LEN {
                return Ok(None);
            }
            let mut header_bytes = [0u8; HEADER_LEN];
            for (i, slot) in header_bytes.iter_mut().enumerate() {
                *slot = self.buf[i];
            }
            let header = FrameHeader::parse(&header_bytes).map_err(DecodeError::Header)?;
            let len = header.len as usize;
            if self.over_limit(HEADER_LEN + len) {
                return Err(DecodeError::TooLarge {
                    declared: HEADER_LEN + len,
                    limit: self.max_frame_bytes,
                });
            }
            if self.buf.len() < HEADER_LEN + len {
                return Ok(None);
            }
            self.buf.drain(..HEADER_LEN);
            let payload: Vec<u8> = self.buf.drain(..len).collect();
            Ok(Some(WireFrame::Binary(header, payload)))
        } else {
            match self.buf.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if self.over_limit(pos + 1) {
                        return Err(DecodeError::TooLarge {
                            declared: pos + 1,
                            limit: self.max_frame_bytes,
                        });
                    }
                    let line: Vec<u8> = self.buf.drain(..pos + 1).take(pos).collect();
                    Ok(Some(WireFrame::Json(
                        String::from_utf8_lossy(&line).into_owned(),
                    )))
                }
                None => {
                    if self.over_limit(self.buf.len()) {
                        return Err(DecodeError::TooLarge {
                            declared: self.buf.len(),
                            limit: self.max_frame_bytes,
                        });
                    }
                    Ok(None)
                }
            }
        }
    }

    /// At EOF: the final unterminated JSON line, if the leftover bytes
    /// are JSON-framed (a torn binary frame yields `None` — raw bytes
    /// cut mid-frame are not a request).
    pub fn trailing_line(&mut self) -> Option<String> {
        if self.buf.is_empty() || self.buf.front() == Some(&MAGIC) {
            return None;
        }
        let line: Vec<u8> = self.buf.drain(..).collect();
        Some(String::from_utf8_lossy(&line).into_owned())
    }

    /// Whether a frame is partially buffered (bytes seen, no complete
    /// frame yet) — the idle reaper uses this to call out slowloris
    /// drip-feeding in the goodbye it sends.
    pub fn is_mid_frame(&self) -> bool {
        !self.buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(dec: &mut FrameDecoder) -> Vec<WireFrame> {
        let mut out = Vec::new();
        while let Some(f) = dec.next().unwrap() {
            out.push(f);
        }
        out
    }

    #[test]
    fn header_roundtrip() {
        let h = FrameHeader {
            version: VERSION,
            opcode: Opcode::Infer,
            flags: 0,
            id: 0xDEAD_BEEF_0123,
            len: 40,
        };
        let parsed = FrameHeader::parse(&h.encode()).unwrap();
        assert_eq!(parsed.version, VERSION);
        assert_eq!(parsed.opcode, Opcode::Infer);
        assert_eq!(parsed.id, 0xDEAD_BEEF_0123);
        assert_eq!(parsed.len, 40);
    }

    #[test]
    fn header_rejects_bad_magic_and_opcode() {
        let mut bytes = frame(Opcode::Infer, 1, &[]);
        bytes[0] = b'{';
        let arr: [u8; HEADER_LEN] = bytes[..HEADER_LEN].try_into().unwrap();
        assert!(FrameHeader::parse(&arr).unwrap_err().contains("magic"));
        let mut bytes = frame(Opcode::Infer, 1, &[]);
        bytes[2] = 99;
        let arr: [u8; HEADER_LEN] = bytes[..HEADER_LEN].try_into().unwrap();
        assert!(FrameHeader::parse(&arr).unwrap_err().contains("opcode"));
    }

    #[test]
    fn f32_bytes_roundtrip_bit_exact() {
        let values = [0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, 3.0e38, -7.25e-12];
        let back = le_f32s(&f32s_le(&values)).unwrap();
        for (a, b) in values.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(le_f32s(&[0, 1, 2]).is_err());
    }

    #[test]
    fn infer_payload_roundtrip() {
        let input = vec![1.0f32, -2.5, 0.125];
        let enc = encode_infer(Some("beta"), Some(250), &input);
        let dec = InferPayload::decode(&enc).unwrap();
        assert_eq!(dec.model.as_deref(), Some("beta"));
        assert_eq!(dec.deadline_ms, Some(250));
        assert_eq!(dec.input, input);

        let enc = encode_infer(None, None, &input);
        let dec = InferPayload::decode(&enc).unwrap();
        assert_eq!(dec.model, None);
        assert_eq!(dec.deadline_ms, None);
        assert_eq!(dec.input, input);
    }

    #[test]
    fn infer_payload_zero_deadline_is_explicit() {
        // deadline_ms=0 (no deadline, overriding the server default)
        // must survive: the flag bit, not the value, carries presence.
        let dec = InferPayload::decode(&encode_infer(None, Some(0), &[1.0])).unwrap();
        assert_eq!(dec.deadline_ms, Some(0));
    }

    #[test]
    fn infer_payload_rejects_malformed() {
        assert!(InferPayload::decode(&[0u8; 4]).unwrap_err().contains("too short"));
        // model_len claims more bytes than exist.
        let mut enc = encode_infer(Some("ab"), None, &[]);
        enc[0] = 200;
        assert!(InferPayload::decode(&enc).unwrap_err().contains("truncated"));
        // torn f32 tail
        let mut enc = encode_infer(None, None, &[1.0]);
        enc.pop();
        assert!(InferPayload::decode(&enc).unwrap_err().contains("multiple of 4"));
        // non-UTF-8 model name
        let mut enc = encode_infer(Some("ab"), None, &[]);
        enc[INFER_PREFIX_LEN] = 0xFF;
        assert!(InferPayload::decode(&enc).unwrap_err().contains("UTF-8"));
    }

    #[test]
    fn decoder_interleaves_framings() {
        let mut dec = FrameDecoder::new(1 << 20);
        let mut stream = Vec::new();
        stream.extend_from_slice(b"{\"op\":\"ping\"}\n");
        stream.extend_from_slice(&frame(Opcode::Infer, 7, &encode_infer(None, None, &[1.0])));
        stream.extend_from_slice(b"{\"op\":\"stats\"}\n");
        stream.extend_from_slice(&frame(Opcode::Infer, 8, &encode_infer(None, None, &[2.0])));
        dec.feed(&stream);
        let frames = drain(&mut dec);
        assert_eq!(frames.len(), 4);
        assert!(matches!(&frames[0], WireFrame::Json(l) if l.contains("ping")));
        assert!(matches!(&frames[1], WireFrame::Binary(h, _) if h.id == 7));
        assert!(matches!(&frames[2], WireFrame::Json(l) if l.contains("stats")));
        assert!(matches!(&frames[3], WireFrame::Binary(h, _) if h.id == 8));
    }

    #[test]
    fn decoder_handles_byte_at_a_time_delivery() {
        let mut stream = hello_frame();
        stream.extend_from_slice(&frame(Opcode::Infer, 42, &encode_infer(None, None, &[1.0, 2.0])));
        stream.extend_from_slice(b"{\"op\":\"ping\"}\n");
        let mut dec = FrameDecoder::new(1 << 20);
        let mut frames = Vec::new();
        for b in &stream {
            dec.feed(std::slice::from_ref(b));
            frames.extend(drain(&mut dec));
        }
        assert_eq!(frames.len(), 4); // HELLO, empty line, INFER, ping
        assert!(matches!(&frames[0], WireFrame::Binary(h, _) if h.opcode == Opcode::Hello));
        assert!(matches!(&frames[1], WireFrame::Json(l) if l.is_empty()));
        assert!(matches!(&frames[2], WireFrame::Binary(h, p)
            if h.opcode == Opcode::Infer && h.id == 42 && p.len() == 16));
        assert!(matches!(&frames[3], WireFrame::Json(l) if l.contains("ping")));
    }

    #[test]
    fn oversized_binary_frame_rejected_from_header_alone() {
        let mut dec = FrameDecoder::new(1024);
        let header = FrameHeader {
            version: VERSION,
            opcode: Opcode::Infer,
            flags: 0,
            id: 1,
            len: 1 << 30,
        };
        // Header only — no payload bytes ever arrive.
        dec.feed(&header.encode());
        match dec.next() {
            Err(DecodeError::TooLarge { declared, limit }) => {
                assert_eq!(declared, (1usize << 30) + HEADER_LEN);
                assert_eq!(limit, 1024);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn oversized_json_line_rejected_without_newline() {
        let mut dec = FrameDecoder::new(64);
        dec.feed(&vec![b'a'; 65]);
        assert!(matches!(dec.next(), Err(DecodeError::TooLarge { .. })));
    }

    #[test]
    fn unbounded_decoder_accepts_large_frames() {
        let mut dec = FrameDecoder::new(0);
        let payload = encode_infer(None, None, &vec![1.0f32; 100_000]);
        dec.feed(&frame(Opcode::Infer, 1, &payload));
        assert!(matches!(dec.next(), Ok(Some(WireFrame::Binary(..)))));
    }

    #[test]
    fn trailing_line_only_for_json_leftovers() {
        let mut dec = FrameDecoder::new(1 << 20);
        dec.feed(b"{\"op\":\"ping\"}");
        assert!(matches!(dec.next(), Ok(None)));
        assert_eq!(dec.trailing_line().unwrap(), "{\"op\":\"ping\"}");
        assert!(!dec.is_mid_frame());

        let mut dec = FrameDecoder::new(1 << 20);
        dec.feed(&frame(Opcode::Infer, 1, &encode_infer(None, None, &[1.0]))[..10]);
        assert!(matches!(dec.next(), Ok(None)));
        assert!(dec.is_mid_frame());
        assert_eq!(dec.trailing_line(), None);
    }

    #[test]
    fn crlf_line_keeps_carriage_return_for_caller_trim() {
        // The decoder strips only the newline; callers trim whitespace
        // (matching BufRead::read_line + trim in the old reader).
        let mut dec = FrameDecoder::new(1 << 20);
        dec.feed(b"{\"op\":\"ping\"}\r\n");
        match dec.next().unwrap().unwrap() {
            WireFrame::Json(l) => assert_eq!(l, "{\"op\":\"ping\"}\r"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hello_frame_ends_with_newline_sentinel() {
        let bytes = hello_frame();
        assert_eq!(bytes.len(), HEADER_LEN + 1);
        assert_eq!(*bytes.last().unwrap(), b'\n');
        assert_eq!(bytes[0], MAGIC);
    }
}
