//! TCP JSON-lines front-end + worker pool.
//!
//! Protocol (one JSON object per line):
//!   → `{"op":"infer","id":1,"input":[...f32 x inputs]}`
//!   ← `{"id":1,"output":[...f32 x outputs]}` or `{"id":1,"error":"..."}`
//!   → `{"op":"stats"}` ← `{"requests":N,"p50_ms":...,...}`
//!   → `{"op":"ping"}`  ← `{"ok":true}`

use super::batcher::{Batcher, InferRequest};
use super::metrics::Metrics;
use super::SparseModel;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Running server state; dropping does not stop it — call `stop()`.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
    pub metrics: Arc<Metrics>,
    workers: Vec<thread::JoinHandle<()>>,
    acceptor: Option<thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Stop accepting, drain the queue, join workers.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the acceptor loop out of `accept()`.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        self.batcher.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Server geometry. `input_width`/`max_batch` must match the artifact
/// (PJRT executables are not `Send`, so each worker thread builds its own
/// [`SparseModel`] through the factory closure).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub bind: String,
    pub workers: usize,
    pub input_width: usize,
    pub max_batch: usize,
    pub window_ms: u64,
}

/// Start serving on `cfg.bind` with `cfg.workers` execution threads, each
/// owning a model instance produced by `factory`.
pub fn serve<F>(factory: F, cfg: ServeConfig) -> Result<ServerHandle>
where
    F: Fn() -> Result<SparseModel> + Send + Sync + 'static,
{
    let listener = TcpListener::bind(&cfg.bind).context("bind")?;
    let addr = listener.local_addr()?;
    let metrics = Arc::new(Metrics::new());
    let batcher = Arc::new(Batcher::new(
        cfg.max_batch,
        Duration::from_millis(cfg.window_ms),
        Arc::clone(&metrics),
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let factory = Arc::new(factory);

    let workers: Vec<_> = (0..cfg.workers.max(1))
        .map(|wi| {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let factory = Arc::clone(&factory);
            thread::Builder::new()
                .name(format!("gs-serve-worker-{wi}"))
                .spawn(move || {
                    let model = match factory() {
                        Ok(m) => m,
                        Err(e) => {
                            eprintln!("worker {wi}: model load failed: {e:#}");
                            metrics.errors.fetch_add(1, Ordering::Relaxed);
                            return;
                        }
                    };
                    while let Some(batch) = batcher.next_batch() {
                        let inputs: Vec<Vec<f32>> =
                            batch.iter().map(|r| r.input.clone()).collect();
                        match model.infer_batch(&inputs) {
                            Ok(outputs) => {
                                for (req, out) in batch.into_iter().zip(outputs) {
                                    metrics.record_latency(req.enqueued.elapsed().as_secs_f64());
                                    let _ = req.tx.send((req.id, Ok(out)));
                                }
                            }
                            Err(e) => {
                                metrics.errors.fetch_add(1, Ordering::Relaxed);
                                let msg = format!("{e:#}");
                                for req in batch {
                                    let _ = req.tx.send((req.id, Err(msg.clone())));
                                }
                            }
                        }
                    }
                })
                .expect("spawn worker")
        })
        .collect();

    let acceptor = {
        let batcher = Arc::clone(&batcher);
        let metrics = Arc::clone(&metrics);
        let stop2 = Arc::clone(&stop);
        let inputs_width = cfg.input_width;
        thread::Builder::new()
            .name("gs-serve-acceptor".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(conn) = conn else { continue };
                    let _ = conn.set_nodelay(true); // JSON-lines RPC: Nagle hurts
                    let batcher = Arc::clone(&batcher);
                    let metrics = Arc::clone(&metrics);
                    thread::spawn(move || {
                        let _ = handle_connection(conn, &batcher, &metrics, inputs_width);
                    });
                }
            })
            .expect("spawn acceptor")
    };

    Ok(ServerHandle {
        addr,
        batcher,
        stop,
        metrics,
        workers,
        acceptor: Some(acceptor),
    })
}

fn handle_connection(
    conn: TcpStream,
    batcher: &Batcher,
    metrics: &Metrics,
    inputs_width: usize,
) -> Result<()> {
    let mut writer = conn.try_clone()?;
    let reader = BufReader::new(conn);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Json::parse(&line) {
            Err(e) => Json::obj(vec![("error", Json::Str(format!("bad json: {e}")))]),
            Ok(msg) => match msg.get("op").and_then(Json::as_str) {
                Some("ping") => Json::obj(vec![("ok", Json::Bool(true))]),
                Some("stats") => stats_json(metrics),
                Some("infer") => {
                    let id = msg.get("id").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                    match msg.get("input").and_then(Json::to_f32_vec) {
                        Some(input) if input.len() == inputs_width => {
                            let (tx, rx) = channel();
                            batcher.submit(InferRequest {
                                id,
                                input,
                                enqueued: Instant::now(),
                                tx,
                            });
                            match rx.recv() {
                                Ok((id, Ok(out))) => Json::obj(vec![
                                    ("id", Json::Num(id as f64)),
                                    ("output", Json::nums_f32(&out)),
                                ]),
                                Ok((id, Err(e))) => Json::obj(vec![
                                    ("id", Json::Num(id as f64)),
                                    ("error", Json::Str(e)),
                                ]),
                                Err(_) => Json::obj(vec![(
                                    "error",
                                    Json::Str("worker dropped".into()),
                                )]),
                            }
                        }
                        _ => Json::obj(vec![
                            ("id", Json::Num(id as f64)),
                            (
                                "error",
                                Json::Str(format!("input must be {inputs_width} floats")),
                            ),
                        ]),
                    }
                }
                _ => Json::obj(vec![("error", Json::Str("unknown op".into()))]),
            },
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

fn stats_json(metrics: &Metrics) -> Json {
    let mut fields = vec![
        (
            "requests",
            Json::Num(metrics.requests.load(Ordering::Relaxed) as f64),
        ),
        (
            "responses",
            Json::Num(metrics.responses.load(Ordering::Relaxed) as f64),
        ),
        (
            "batches",
            Json::Num(metrics.batches.load(Ordering::Relaxed) as f64),
        ),
        ("mean_batch", Json::Num(metrics.mean_batch_size())),
        (
            "errors",
            Json::Num(metrics.errors.load(Ordering::Relaxed) as f64),
        ),
    ];
    if let Some(s) = metrics.latency_summary() {
        fields.push(("p50_ms", Json::Num(s.p50 * 1e3)));
        fields.push(("p95_ms", Json::Num(s.p95 * 1e3)));
        fields.push(("mean_ms", Json::Num(s.mean * 1e3)));
    }
    Json::obj(fields)
}

/// Blocking JSON-lines client (tests, examples, bench harness).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    fn roundtrip(&mut self, msg: Json) -> Result<Json> {
        self.writer.write_all(msg.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(&line)?)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.roundtrip(Json::obj(vec![("op", "ping".into())]))?;
        Ok(r.get("ok").and_then(Json::as_bool).unwrap_or(false))
    }

    pub fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let id = self.next_id;
        self.next_id += 1;
        let r = self.roundtrip(Json::obj(vec![
            ("op", "infer".into()),
            ("id", Json::Num(id as f64)),
            ("input", Json::nums_f32(input)),
        ]))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        r.get("output")
            .and_then(Json::to_f32_vec)
            .ok_or_else(|| anyhow::anyhow!("malformed response"))
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.roundtrip(Json::obj(vec![("op", "stats".into())]))
    }
}
