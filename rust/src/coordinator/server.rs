//! TCP JSON-lines front-end + worker pool.
//!
//! Protocol (one JSON object per line):
//!   → `{"op":"infer","id":1,"input":[...f32 x inputs]}`
//!   ← `{"id":1,"output":[...f32 x outputs]}` or `{"id":1,"error":"..."}`
//!   → `{"op":"stats"}` ← `{"requests":N,"model_version":V,"p50_ms":...}`
//!   → `{"op":"ping"}`  ← `{"ok":true,"version":V}`
//!   → `{"op":"swap","path":"model.gsm"}`
//!   ← `{"ok":true,"version":V,"precision":"f32"}` or `{"error":"..."}`
//!
//! Two serving modes share the batcher/worker machinery:
//!
//! * [`serve_slot`] — workers execute through a versioned
//!   [`ModelSlot`] snapshot taken once per batch, so `swap` deploys a
//!   new model under live traffic with zero downtime: in-flight batches
//!   finish on the version they started with (a batch never mixes
//!   versions), queued requests ride the next snapshot, connections
//!   never drop. This is the native-engine path.
//! * [`serve`] — each worker builds its own model through a factory
//!   closure (PJRT executables are not `Send`, so the pjrt backend
//!   cannot share one instance). No hot swap: `swap` returns an error.
//!
//! **Trust model:** the protocol is unauthenticated, and `swap` lets any
//! connected client deploy a server-readable `.gsm` path — an operator
//! capability, not a public one. The default bind is loopback; exposing
//! the port beyond a trusted network requires fronting it with an
//! authenticating proxy (or using factory mode, which has no write op).

use super::batcher::{Batcher, InferRequest};
use super::metrics::Metrics;
use super::{Engine, SparseModel};
use crate::model_store::ModelSlot;
use crate::util::json::Json;
use crate::util::threadpool::resolve_threads;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Running server state; dropping does not stop it — call `stop()`.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
    pub metrics: Arc<Metrics>,
    /// The versioned model slot (None in factory mode — no hot swap).
    pub slot: Option<Arc<ModelSlot>>,
    workers: Vec<thread::JoinHandle<()>>,
    acceptor: Option<thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Stop accepting, drain the queue, join workers.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the acceptor loop out of `accept()`.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        self.batcher.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Server geometry. `input_width`/`max_batch` must match the model
/// (`workers: 0` auto-detects the machine's parallelism).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub bind: String,
    pub workers: usize,
    pub input_width: usize,
    pub max_batch: usize,
    pub window_ms: u64,
}

/// How serving workers obtain the model to execute a batch on.
enum Provider {
    /// Shared versioned slot, snapshotted once per batch (hot-swappable).
    Slot(Arc<ModelSlot>),
    /// Per-worker factory (PJRT executables are not `Send`).
    Factory(Arc<dyn Fn() -> Result<SparseModel> + Send + Sync>),
}

/// Start serving `engine`'s model slot on `cfg.bind`. All workers share
/// the slot; `{"op":"swap","path":...}` hot-deploys a new artifact.
pub fn serve_slot(engine: &Engine, cfg: ServeConfig) -> Result<ServerHandle> {
    serve_impl(
        Provider::Slot(Arc::clone(&engine.slot)),
        Arc::clone(&engine.metrics),
        cfg,
    )
}

/// Start serving with `cfg.workers` execution threads, each owning a
/// model instance produced by `factory`. No hot swap in this mode.
pub fn serve<F>(factory: F, cfg: ServeConfig) -> Result<ServerHandle>
where
    F: Fn() -> Result<SparseModel> + Send + Sync + 'static,
{
    serve_impl(
        Provider::Factory(Arc::new(factory)),
        Arc::new(Metrics::new()),
        cfg,
    )
}

/// Execute one formed batch on `model` and deliver each row's result.
fn run_batch(model: &SparseModel, batch: Vec<InferRequest>, metrics: &Metrics) {
    let inputs: Vec<Vec<f32>> = batch.iter().map(|r| r.input.clone()).collect();
    match model.infer_batch(&inputs) {
        Ok(outputs) => {
            for (req, out) in batch.into_iter().zip(outputs) {
                metrics.record_latency(req.enqueued.elapsed().as_secs_f64());
                let _ = req.tx.send((req.id, Ok(out)));
            }
        }
        Err(e) => {
            metrics.errors.fetch_add(1, Ordering::Relaxed);
            let msg = format!("{e:#}");
            for req in batch {
                let _ = req.tx.send((req.id, Err(msg.clone())));
            }
        }
    }
}

fn serve_impl(provider: Provider, metrics: Arc<Metrics>, cfg: ServeConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.bind).context("bind")?;
    let addr = listener.local_addr()?;
    let batcher = Arc::new(Batcher::new(
        cfg.max_batch,
        Duration::from_millis(cfg.window_ms),
        Arc::clone(&metrics),
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let slot = match &provider {
        Provider::Slot(slot) => Some(Arc::clone(slot)),
        Provider::Factory(_) => None,
    };

    let workers: Vec<_> = (0..resolve_threads(cfg.workers))
        .map(|wi| {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let worker_provider = match &provider {
                Provider::Slot(slot) => Provider::Slot(Arc::clone(slot)),
                Provider::Factory(f) => Provider::Factory(Arc::clone(f)),
            };
            thread::Builder::new()
                .name(format!("gs-serve-worker-{wi}"))
                .spawn(move || match worker_provider {
                    Provider::Slot(slot) => {
                        while let Some(batch) = batcher.next_batch() {
                            // One snapshot per batch: the whole batch runs
                            // on a single model generation even if a swap
                            // lands mid-execution.
                            let vm = slot.current();
                            run_batch(&vm.model, batch, &metrics);
                        }
                    }
                    Provider::Factory(factory) => {
                        let model = match factory() {
                            Ok(m) => m,
                            Err(e) => {
                                eprintln!("worker {wi}: model load failed: {e:#}");
                                metrics.errors.fetch_add(1, Ordering::Relaxed);
                                return;
                            }
                        };
                        while let Some(batch) = batcher.next_batch() {
                            run_batch(&model, batch, &metrics);
                        }
                    }
                })
                .expect("spawn worker")
        })
        .collect();

    let acceptor = {
        let batcher = Arc::clone(&batcher);
        let metrics = Arc::clone(&metrics);
        let stop2 = Arc::clone(&stop);
        let slot2 = slot.clone();
        let inputs_width = cfg.input_width;
        thread::Builder::new()
            .name("gs-serve-acceptor".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(conn) = conn else { continue };
                    let _ = conn.set_nodelay(true); // JSON-lines RPC: Nagle hurts
                    let batcher = Arc::clone(&batcher);
                    let metrics = Arc::clone(&metrics);
                    let slot = slot2.clone();
                    thread::spawn(move || {
                        let _ = handle_connection(conn, &batcher, &metrics, slot, inputs_width);
                    });
                }
            })
            .expect("spawn acceptor")
    };

    Ok(ServerHandle {
        addr,
        batcher,
        stop,
        metrics,
        slot,
        workers,
        acceptor: Some(acceptor),
    })
}

fn handle_connection(
    conn: TcpStream,
    batcher: &Batcher,
    metrics: &Metrics,
    slot: Option<Arc<ModelSlot>>,
    inputs_width: usize,
) -> Result<()> {
    let mut writer = conn.try_clone()?;
    let reader = BufReader::new(conn);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Json::parse(&line) {
            Err(e) => Json::obj(vec![("error", Json::Str(format!("bad json: {e}")))]),
            Ok(msg) => match msg.get("op").and_then(Json::as_str) {
                Some("ping") => {
                    let mut fields = vec![("ok", Json::Bool(true))];
                    if let Some(slot) = &slot {
                        fields.push(("version", Json::Num(slot.version() as f64)));
                    }
                    Json::obj(fields)
                }
                Some("stats") => stats_json(metrics, slot.as_deref()),
                Some("swap") => handle_swap(&msg, slot.as_deref(), metrics),
                Some("infer") => {
                    let id = msg.get("id").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                    match msg.get("input").and_then(Json::to_f32_vec) {
                        Some(input) if input.len() == inputs_width => {
                            let (tx, rx) = channel();
                            batcher.submit(InferRequest {
                                id,
                                input,
                                enqueued: Instant::now(),
                                tx,
                            });
                            match rx.recv() {
                                Ok((id, Ok(out))) => Json::obj(vec![
                                    ("id", Json::Num(id as f64)),
                                    ("output", Json::nums_f32(&out)),
                                ]),
                                Ok((id, Err(e))) => Json::obj(vec![
                                    ("id", Json::Num(id as f64)),
                                    ("error", Json::Str(e)),
                                ]),
                                Err(_) => Json::obj(vec![(
                                    "error",
                                    Json::Str("worker dropped".into()),
                                )]),
                            }
                        }
                        _ => Json::obj(vec![
                            ("id", Json::Num(id as f64)),
                            (
                                "error",
                                Json::Str(format!("input must be {inputs_width} floats")),
                            ),
                        ]),
                    }
                }
                _ => Json::obj(vec![("error", Json::Str("unknown op".into()))]),
            },
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

/// `{"op":"swap","path":...}`: load + validate the artifact, instantiate
/// it, and swap it into the slot. Traffic keeps flowing on the old
/// version until the new one is installed; nothing is interrupted on
/// failure (the error comes back on this connection, the slot keeps its
/// current generation, and the failure is counted in `errors`).
fn handle_swap(msg: &Json, slot: Option<&ModelSlot>, metrics: &Metrics) -> Json {
    let Some(slot) = slot else {
        return Json::obj(vec![(
            "error",
            Json::Str("hot swap unavailable: server runs factory-backed workers".into()),
        )]);
    };
    let Some(path) = msg.get("path").and_then(Json::as_str) else {
        return Json::obj(vec![(
            "error",
            Json::Str("swap requires a \"path\" to a .gsm artifact".into()),
        )]);
    };
    match slot.swap_path(path) {
        Ok(vm) => {
            metrics.swaps.fetch_add(1, Ordering::Relaxed);
            // Report the generation *this* request installed, not
            // whatever a concurrent later swap made current.
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("version", Json::Num(vm.version as f64)),
            ];
            if let Some(p) = vm.precision() {
                fields.push(("precision", Json::Str(p.name().into())));
            }
            Json::obj(fields)
        }
        Err(e) => {
            metrics.swap_failures.fetch_add(1, Ordering::Relaxed);
            Json::obj(vec![("error", Json::Str(format!("{e:#}")))])
        }
    }
}

fn stats_json(metrics: &Metrics, slot: Option<&ModelSlot>) -> Json {
    let mut fields = vec![
        (
            "requests",
            Json::Num(metrics.requests.load(Ordering::Relaxed) as f64),
        ),
        (
            "responses",
            Json::Num(metrics.responses.load(Ordering::Relaxed) as f64),
        ),
        (
            "batches",
            Json::Num(metrics.batches.load(Ordering::Relaxed) as f64),
        ),
        ("mean_batch", Json::Num(metrics.mean_batch_size())),
        (
            "errors",
            Json::Num(metrics.errors.load(Ordering::Relaxed) as f64),
        ),
        (
            "swaps",
            Json::Num(metrics.swaps.load(Ordering::Relaxed) as f64),
        ),
        (
            "swap_failures",
            Json::Num(metrics.swap_failures.load(Ordering::Relaxed) as f64),
        ),
    ];
    if let Some(slot) = slot {
        let vm = slot.current();
        fields.push(("model_version", Json::Num(vm.version as f64)));
        if let Some(p) = vm.precision() {
            fields.push(("precision", Json::Str(p.name().into())));
        }
    }
    if let Some(s) = metrics.latency_summary() {
        fields.push(("p50_ms", Json::Num(s.p50 * 1e3)));
        fields.push(("p95_ms", Json::Num(s.p95 * 1e3)));
        fields.push(("mean_ms", Json::Num(s.mean * 1e3)));
    }
    Json::obj(fields)
}

/// Blocking JSON-lines client (tests, examples, bench harness).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    fn roundtrip(&mut self, msg: Json) -> Result<Json> {
        self.writer.write_all(msg.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(&line)?)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.roundtrip(Json::obj(vec![("op", "ping".into())]))?;
        Ok(r.get("ok").and_then(Json::as_bool).unwrap_or(false))
    }

    pub fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let id = self.next_id;
        self.next_id += 1;
        let r = self.roundtrip(Json::obj(vec![
            ("op", "infer".into()),
            ("id", Json::Num(id as f64)),
            ("input", Json::nums_f32(input)),
        ]))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        r.get("output")
            .and_then(Json::to_f32_vec)
            .ok_or_else(|| anyhow::anyhow!("malformed response"))
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.roundtrip(Json::obj(vec![("op", "stats".into())]))
    }

    /// Hot-swap the served model to the artifact at `path`; returns the
    /// new deployment version.
    pub fn swap(&mut self, path: &str) -> Result<u64> {
        let r = self.roundtrip(Json::obj(vec![
            ("op", "swap".into()),
            ("path", Json::Str(path.into())),
        ]))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("swap failed: {err}");
        }
        r.get("version")
            .and_then(Json::as_f64)
            .map(|v| v as u64)
            .ok_or_else(|| anyhow::anyhow!("malformed swap response"))
    }
}
