//! TCP front-end + worker pool: JSON-lines protocol with an opt-in
//! binary framing for the infer data plane, multiplexed onto one
//! readiness event loop.
//!
//! Protocol (one JSON object per line; `"model"` is optional everywhere
//! and defaults to the server's default slot):
//!   → `{"op":"infer","id":1,"model":"resnet","input":[...f32 x inputs],
//!      "deadline_ms":N}` (optional queue-wait budget; 0 opts out of the
//!      server default)
//!   ← `{"id":1,"output":[...f32 x outputs]}` or `{"id":1,"error":"..."}`
//!     (overload shed: `{"id":1,"error":"overloaded...","retry_after_ms":N}`;
//!      deadline expiry: `{"id":1,"error":"deadline exceeded","waited_ms":N}`)
//!   → `{"op":"stats"}`
//!   ← `{"requests":N,"shed":S,"queue_depth":D,"model_version":V,
//!      "p50_ms":...,"models":{...per-slot...}}`
//!   → `{"op":"ping"}`  ← `{"ok":true,"version":V}`
//!   → `{"op":"swap","model":"resnet","path":"model.gsm"}`
//!   ← `{"ok":true,"model":"resnet","version":V,"precision":"f32"}`
//!     (with `"canary":{"requests":N,"max_error_rate":F}` the new
//!      generation installs in canary state — watched over its first N
//!      requests and auto-rolled-back past the error budget — and the
//!      reply carries `"state":"canary"`)
//!   → `{"op":"rollback","model":"resnet"}`
//!   ← `{"ok":true,"model":"resnet","version":V}` (restores the retained
//!      previous generation under live traffic)
//!   → `{"op":"load","model":"jasper","path":"j.gsm"}`
//!   ← `{"ok":true,"model":"jasper","version":1,"evicted":[...]}`
//!   → `{"op":"unload","model":"jasper"}` ← `{"ok":true,"model":"jasper"}`
//!   → `{"op":"models"}`
//!   ← `{"default":"...","max_models":N,"models":{name:{version,state,
//!      retained_versions,geometry,...}}}`
//!   → `{"op":"trace","model":...,"event":...,"id":N,"limit":N}` (all
//!      filters optional)
//!   ← `{"ok":true,"enabled":B,"capacity":N,"dropped":K,"events":[...]}`
//!     (the flight recorder's retained lifecycle events, oldest first)
//!   → `{"op":"metrics"}`
//!   ← `{"ok":true,"content_type":"text/plain; version=0.0.4",
//!      "text":"..."}` (Prometheus text exposition of every counter,
//!      gauge, and stage-latency summary)
//!   → `{"op":"profile","reset":bool}` (reset optional)
//!   ← `{"ok":true,"profiling":B,"plans":{fingerprint:{...}}}` (kernel
//!      chunk load-imbalance summaries; see [`crate::kernels::profile`])
//!
//! **Binary framing (opt-in):** a client may negotiate the
//! length-prefixed binary framing of [`super::wire`] for the infer data
//! plane — raw little-endian f32 input/logit vectors instead of base-10
//! JSON text. Negotiation is HELLO → HELLO_ACK on connect; the control
//! plane (every op above except `infer`) stays JSON-lines on the same
//! stream, interleaved per frame. JSON framing remains the default and
//! is always accepted, binary or not.
//!
//! **Connection tier:** one readiness event loop
//! ([`crate::util::poll`]) multiplexes every client socket instead of a
//! thread per connection. Requests are pipelined per connection: a
//! client may have many infers in flight (distinguished by its request
//! ids) and replies flush from a dedicated per-connection writer thread
//! as their batches complete — out of completion order, not arrival
//! order. Control-plane ops run on a small shared pool so a slow
//! `metrics` scrape never stalls the event loop; replies to
//! *concurrently in-flight* control ops on one connection are unordered
//! (a client that awaits each reply before the next op sees the
//! historical in-order behavior).
//!
//! Two serving modes share the batcher/worker machinery:
//!
//! * [`serve_store`] — the multi-model routed engine. Workers execute
//!   whatever slot each (model-homogeneous) batch was admitted against,
//!   through a versioned [`ModelSlot`] snapshot taken once per batch, so
//!   `swap`/`load` deploy under live traffic with zero downtime:
//!   in-flight batches finish on the version they started with (a batch
//!   never mixes versions or models), queued requests ride the next
//!   snapshot, connections never drop, and LRU eviction of a cold model
//!   never disrupts batches already admitted (they hold the slot `Arc`).
//!   [`serve_slot`] is the single-model entry to the same path.
//! * [`serve`] — each worker builds its own model through a factory
//!   closure (PJRT executables are not `Send`, so the pjrt backend
//!   cannot share one instance). No hot swap or routing: `swap`/`load`/
//!   `unload` return errors and `infer` takes no `"model"`.
//!
//! **Trust model:** the protocol is unauthenticated, and `swap`/`load`
//! let any connected client deploy a server-readable `.gsm` path — an
//! operator capability, not a public one. The default bind is loopback;
//! exposing the port beyond a trusted network requires fronting it with
//! an authenticating proxy (or using factory mode, which has no write
//! op).
//!
//! **Resilience:** the connection tier is hardened against misbehaving
//! clients — `max_conns` caps simultaneous connections (a structured
//! at-capacity reply, then close), `idle_timeout_ms` reaps a connection
//! that delivers no bytes within the budget (a slowloris client holds a
//! poller slot, not a thread), `max_frame_bytes` bounds the frame
//! decoder in both framings (an oversized binary frame is rejected from
//! its declared header length before any payload is buffered), and
//! `max_inflight` caps one connection's pipelined depth. Batch
//! execution runs under `catch_unwind`: a panicking kernel fails that
//! batch's requests per-request (counted in `panics` + `errors`) and
//! the worker survives. [`ServerHandle::stop`] drains connections:
//! writer threads flush every in-flight reply and are joined, so no
//! server thread outlives the handle.
//!
//! **Deployment safety (store mode):** slots retain previous
//! generations for `{"op":"rollback"}` and canary swaps
//! ([`SlotConfig::retain`]); batch outcomes feed each slot's canary
//! watch and quarantine circuit breaker
//! ([`ModelSlot::observe_execution`]), with auto-rollbacks counted in
//! `rollbacks` and quarantine fast-fails in `quarantined` (+ `errors`,
//! keeping conservation exact). With [`ServeConfig::store_dir`] set,
//! every accepted load/swap/unload/rollback atomically rewrites a
//! CRC-checked manifest so a restarted server resumes the exact
//! pre-crash registry.
//!
//! **Observability:** every request drops lifecycle events into the
//! flight recorder ([`ServeConfig::trace_capacity`]; drained via
//! `{"op":"trace"}`), per-request time is attributed to pipeline stages
//! (`stats.stages`, `{"op":"metrics"}`), and requests that exceed
//! [`ServeConfig::slow_request_ms`] log their full retained trace.
//! [`ServeConfig::log_json`] switches operational logging to one JSON
//! object per line.

use super::batcher::{Batcher, InferRequest, Reject};
use super::faults;
use super::metrics::{Metrics, ModelMetrics, Stage, StageSet};
use super::trace::{EventKind, TraceEvent};
use super::wire::{self, DecodeError, FrameDecoder, InferPayload, Opcode, WireFrame};
use super::{Engine, SparseModel};
use crate::kernels::profile as kernel_profile;
use crate::model_store::{
    ManifestWriter, ModelArtifact, ModelSlot, ModelStore, SlotConfig, SlotEvent,
};
use crate::util::json::Json;
use crate::util::poll::{self, Poller};
use crate::util::stats::Summary;
use crate::util::threadpool::resolve_threads;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Live-connection registry: backs the `connections` gauge and the
/// `max_conns` admission check, and holds the socket clones + thread
/// handles [`ServerHandle::stop`] drains.
struct ConnTracker {
    live: AtomicUsize,
    /// Connection id → socket clone. Shutting the read half on stop
    /// unblocks a parked reader while its final reply still flushes.
    socks: Mutex<HashMap<u64, TcpStream>>,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
    next_id: AtomicU64,
}

impl ConnTracker {
    fn new() -> ConnTracker {
        ConnTracker {
            live: AtomicUsize::new(0),
            socks: Mutex::new(HashMap::new()),
            handles: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(0),
        }
    }

    /// Register an accepted connection; returns its id for `release`.
    fn register(&self, conn: &TcpStream) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = conn.try_clone() {
            self.socks.lock().unwrap().insert(id, clone);
        }
        self.live.fetch_add(1, Ordering::SeqCst);
        id
    }

    fn release(&self, id: u64) {
        self.socks.lock().unwrap().remove(&id);
        self.live.fetch_sub(1, Ordering::SeqCst);
    }

    /// Track a connection thread, reaping already-finished handles so
    /// the vector stays bounded by the number of *live* connections on
    /// a long-running server.
    fn track(&self, handle: thread::JoinHandle<()>) {
        let mut handles = self.handles.lock().unwrap();
        handles.retain(|h| !h.is_finished());
        handles.push(handle);
    }

    /// Unblock every connection reader and join every connection
    /// thread. After this returns, no connection thread is running.
    fn drain(&self) {
        for sock in self.socks.lock().unwrap().values() {
            let _ = sock.shutdown(Shutdown::Read);
        }
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Drops the connection's tracker entry even if the handler panics or
/// errors out — the live gauge can never leak upward.
struct ConnGuard {
    tracker: Arc<ConnTracker>,
    id: u64,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.tracker.release(self.id);
    }
}

/// Running server state; dropping does not stop it — call `stop()`.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
    pub metrics: Arc<Metrics>,
    /// The model registry (None in factory mode — no hot swap/routing).
    pub store: Option<Arc<ModelStore>>,
    /// The slot name unqualified requests route to (store mode).
    pub default_model: Option<String>,
    workers: Vec<thread::JoinHandle<()>>,
    acceptor: Option<thread::JoinHandle<()>>,
    /// The control-plane op pool (stats/swap/metrics/... handlers).
    control: Vec<thread::JoinHandle<()>>,
    conns: Arc<ConnTracker>,
}

impl ServerHandle {
    /// The slot unqualified requests execute on (None in factory mode).
    pub fn default_slot(&self) -> Option<Arc<ModelSlot>> {
        let store = self.store.as_ref()?;
        store.get(self.default_model.as_deref()?)
    }

    /// Stop accepting, drain the queue, join workers, then unblock and
    /// join every connection thread. In-flight requests complete (or
    /// fail structurally) and their replies flush before the sockets
    /// are torn down; after this returns no server thread is running.
    /// Idempotent — a second call is a no-op.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the acceptor loop out of `accept()`.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // Drain queued work first: requests already admitted execute or
        // fail structurally, and connection threads blocked on reply
        // channels get their answers delivered...
        self.batcher.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // ...then release the connection tier: per-connection writer
        // threads flush the structured failures the batcher just issued
        // and exit once their reply channels drain; every one is joined
        // — none outlives stop(). The control pool goes last (its
        // channel closed when the event loop exited).
        self.conns.drain();
        for c in self.control.drain(..) {
            let _ = c.join();
        }
    }
}

/// Server geometry. In store mode `input_width` only describes the
/// default model (admission is checked per-request against the routed
/// slot); `max_batch` is the global batch cap — each batch is further
/// bounded by its model's contract capacity. `workers: 0` auto-detects
/// the machine's parallelism. Construct with struct-update syntax over
/// [`ServeConfig::default`] so new resilience knobs keep their
/// defaults: `ServeConfig { bind, ..ServeConfig::default() }`.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub bind: String,
    pub workers: usize,
    pub input_width: usize,
    pub max_batch: usize,
    pub window_ms: u64,
    /// Global bound on queued requests (0 = unbounded). At the bound,
    /// requests are shed with an `{"error":"overloaded...",
    /// "retry_after_ms":N}` reply — longest-queue-drop fair across
    /// models — instead of queueing without limit (protects tail
    /// latency under overload; see [`Batcher`]).
    pub queue_depth: usize,
    /// Default queue-wait budget in ms for requests that don't carry
    /// their own `"deadline_ms"` (0 = none). An expired request is
    /// failed with `{"error":"deadline exceeded","waited_ms":N}` at
    /// batch-formation time instead of executing; a request may send
    /// `"deadline_ms":0` to opt out of the server default.
    pub deadline_ms: u64,
    /// Cap on simultaneously open client connections (0 = unbounded).
    /// At capacity a new connection gets one structured
    /// `{"error":"...at connection capacity...","max_conns":N}` reply
    /// and is closed — no thread is spawned for it.
    pub max_conns: usize,
    /// Per-connection read/idle timeout in ms (0 = none). A connection
    /// that doesn't deliver a complete frame within the budget gets a
    /// structured goodbye and is closed — a slowloris client releases
    /// its thread instead of pinning it forever.
    pub idle_timeout_ms: u64,
    /// Largest accepted request frame in bytes (0 = unbounded), in
    /// either framing: one JSON line, or one binary frame including its
    /// 16-byte header. An oversized frame gets a structured
    /// `{"error":"frame too large...","max_frame_bytes":N}` reply and
    /// the connection closes. A binary frame is judged by its header's
    /// *declared* length, before any payload is buffered.
    pub max_frame_bytes: usize,
    /// Accept the negotiated binary wire framing of [`super::wire`]
    /// (HELLO → HELLO_ACK). When false, a HELLO gets a JSON error line
    /// — which binary-capable clients take as the fall-back-to-JSON
    /// signal — and the connection continues in JSON. JSON framing is
    /// always accepted either way.
    pub binary_wire: bool,
    /// Per-connection cap on admitted infers whose reply has not yet
    /// been written back (0 = unbounded). At the cap, further infers on
    /// that connection fail with a structured error instead of growing
    /// server-side reply state without bound under deep pipelining.
    pub max_inflight: usize,
    /// Deployment-safety contract applied to slots registered by
    /// `{"op":"load"}` (retention depth, quarantine circuit breaker).
    /// Slots created before the server started keep their own config.
    pub slot: SlotConfig,
    /// Store-mode only: directory for the crash-recoverable registry
    /// manifest. When set, the manifest is written at startup and
    /// atomically rewritten after every accepted load/swap/unload/
    /// rollback; replaying it at the next startup (see
    /// [`crate::model_store::manifest::restore`]) resumes the exact
    /// pre-crash registry. Ignored in factory mode (no registry).
    pub store_dir: Option<PathBuf>,
    /// Flight-recorder capacity in events (0 disables tracing). Memory
    /// is fixed at this many slots with overwrite-oldest semantics; the
    /// hot path never blocks on a full ring.
    pub trace_capacity: usize,
    /// Emit operational log lines (deployment events, slow requests) as
    /// one JSON object per line instead of prose.
    pub log_json: bool,
    /// Log the full retained lifecycle trace of any request whose total
    /// handle time exceeds this many ms (0 = off).
    pub slow_request_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            bind: "127.0.0.1:0".into(),
            workers: 1,
            input_width: 0,
            max_batch: 16,
            window_ms: 2,
            queue_depth: 0,
            deadline_ms: 0,
            max_conns: 0,
            idle_timeout_ms: 0,
            max_frame_bytes: 1 << 20,
            binary_wire: true,
            max_inflight: 0,
            slot: SlotConfig::default(),
            store_dir: None,
            trace_capacity: 4096,
            log_json: false,
            slow_request_ms: 0,
        }
    }
}

/// How serving workers obtain the model to execute a batch on.
enum Provider {
    /// Shared routed registry; each request resolves (and pins) its slot
    /// at admission, batches snapshot once per execution.
    Store {
        store: Arc<ModelStore>,
        default: String,
        /// Kernel threads for models instantiated by `load`.
        threads: usize,
    },
    /// Per-worker factory (PJRT executables are not `Send`).
    Factory(Arc<dyn Fn() -> Result<SparseModel> + Send + Sync>),
}

/// Start the multi-model routed server on `engine`'s model store. All
/// workers share the registry; `{"op":"infer","model":...}` routes,
/// `{"op":"swap"|"load"|"unload"}` hot-deploy.
pub fn serve_store(engine: &Engine, cfg: ServeConfig) -> Result<ServerHandle> {
    serve_impl(
        Provider::Store {
            store: Arc::clone(&engine.store),
            default: engine.default_model.clone(),
            threads: engine.threads,
        },
        Arc::clone(&engine.metrics),
        cfg,
    )
}

/// Single-model entry to the routed path (the engine's default slot is
/// the only registered model until a `load` arrives).
pub fn serve_slot(engine: &Engine, cfg: ServeConfig) -> Result<ServerHandle> {
    serve_store(engine, cfg)
}

/// Start serving with `cfg.workers` execution threads, each owning a
/// model instance produced by `factory`. No hot swap in this mode.
pub fn serve<F>(factory: F, cfg: ServeConfig) -> Result<ServerHandle>
where
    F: Fn() -> Result<SparseModel> + Send + Sync + 'static,
{
    serve_impl(
        Provider::Factory(Arc::new(factory)),
        Arc::new(Metrics::new()),
        cfg,
    )
}

/// Execute one formed batch on `model` and deliver each row's result.
/// Latency/errors are recorded globally and, when the batch was routed
/// (`mm`), in the model's own breakdown. Errors are counted **per
/// request**, not per batch — one error row is sent per request, so the
/// counters must match or `requests == responses + errors + shed +
/// expired` conservation breaks at batch size > 1.
///
/// Returns the per-request outcome counts `(ok, err)` so store-mode
/// workers can feed the batch's slot ([`ModelSlot::observe_execution`]
/// drives the canary watch and the quarantine circuit breaker).
fn run_batch(
    model: &SparseModel,
    batch: Vec<InferRequest>,
    metrics: &Metrics,
    mm: Option<&ModelMetrics>,
) -> (u64, u64) {
    let inputs: Vec<Vec<f32>> = batch.iter().map(|r| r.input.clone()).collect();
    let batch_id = batch[0].batch_id;
    let model_name = batch[0].model.clone();
    let trace_on = metrics.recorder.is_enabled();
    if trace_on {
        metrics.recorder.record(
            EventKind::ExecStart,
            &model_name,
            0,
            batch_id,
            &format!("n={}", batch.len()),
        );
    }
    let exec_end = |ok: u64, err: u64| {
        if trace_on {
            metrics.recorder.record(
                EventKind::ExecEnd,
                &model_name,
                0,
                batch_id,
                &format!("ok={ok} err={err}"),
            );
        }
    };
    let reply_event = |req: &InferRequest, detail: &str| {
        if trace_on {
            metrics
                .recorder
                .record(EventKind::Reply, &req.model, req.id, req.batch_id, detail);
        }
    };
    // Supervised execution: a panicking kernel fails THIS batch's
    // requests and the worker survives to take the next batch — one bad
    // input or kernel bug must not permanently shrink the worker pool.
    // The fault hook sits inside the guard so injected panics exercise
    // the real recovery path.
    let exec_started = Instant::now();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        faults::on_batch_execute();
        model.infer_batch(&inputs)
    }));
    let exec_secs = exec_started.elapsed().as_secs_f64();
    metrics.stages.record(Stage::Execute, exec_secs);
    if let Some(mm) = mm {
        mm.stages.record(Stage::Execute, exec_secs);
    }
    let n = batch.len() as u64;
    let result = match result {
        Ok(r) => r,
        Err(panic) => {
            metrics.panics.fetch_add(1, Ordering::Relaxed);
            metrics.count_errors(&batch[0].model, n);
            exec_end(0, n);
            let msg = panic
                .downcast_ref::<&'static str>()
                .copied()
                .or_else(|| panic.downcast_ref::<String>().map(String::as_str))
                .unwrap_or("<non-string panic payload>");
            let why = Reject::error(format!("internal error: worker panicked: {msg}"));
            for req in batch {
                reply_event(&req, "error: panic");
                let _ = req.tx.send((req.id, Err(why.clone())));
            }
            return (0, n);
        }
    };
    match result {
        Ok(outputs) => {
            exec_end(n, 0);
            for (req, out) in batch.into_iter().zip(outputs) {
                let secs = req.enqueued.elapsed().as_secs_f64();
                metrics.record_latency(secs);
                if let Some(mm) = mm {
                    mm.record_latency(secs);
                }
                reply_event(&req, "");
                let _ = req.tx.send((req.id, Ok(out)));
            }
            (n, 0)
        }
        Err(e) => {
            // Routed batches carry their model name; factory-mode
            // batches have "" and only count globally.
            metrics.count_errors(&batch[0].model, n);
            exec_end(0, n);
            let msg = format!("{e:#}");
            for req in batch {
                reply_event(&req, "error");
                let _ = req.tx.send((req.id, Err(Reject::error(msg.clone()))));
            }
            (0, n)
        }
    }
}

/// React to a slot's post-batch deployment events: count and log
/// auto-rollbacks (and re-persist the manifest — the live version
/// changed), log canary promotions, quarantine trips, and recoveries.
/// Runs on worker threads; everything here is advisory and must not
/// block batch execution beyond a manifest write.
fn apply_slot_events(
    events: &[SlotEvent],
    name: &str,
    metrics: &Metrics,
    manifest: Option<&ManifestWriter>,
    log_json: bool,
) {
    let log = |event: &str, detail: &str| {
        if log_json {
            eprintln!(
                "{}",
                Json::obj(vec![
                    ("event", Json::Str(event.into())),
                    ("model", Json::Str(name.into())),
                    ("detail", Json::Str(detail.into())),
                ])
            );
        } else {
            eprintln!("model \"{name}\": {detail}");
        }
    };
    for event in events {
        match event {
            SlotEvent::CanaryPromoted { version } => {
                metrics
                    .recorder
                    .record(EventKind::CanaryPromoted, name, 0, 0, &format!("v{version}"));
                log(
                    "canary_promoted",
                    &format!("canary v{version} promoted to serving"),
                );
            }
            SlotEvent::CanaryRolledBack { from, to, reason } => {
                metrics.count_rollback(name);
                metrics.recorder.record(
                    EventKind::CanaryRolledBack,
                    name,
                    0,
                    0,
                    &format!("v{from} -> v{to}: {reason}"),
                );
                log(
                    "canary_rolled_back",
                    &format!("canary v{from} auto-rolled back to v{to}: {reason}"),
                );
                if let Some(m) = manifest {
                    if let Err(e) = m.persist() {
                        log(
                            "manifest_error",
                            &format!("manifest persist after auto-rollback: {e:#}"),
                        );
                    }
                }
            }
            SlotEvent::Quarantined { reason } => {
                metrics
                    .recorder
                    .record(EventKind::Quarantined, name, 0, 0, reason);
                log("quarantined", &format!("quarantined: {reason}"));
            }
            SlotEvent::Recovered => {
                metrics.recorder.record(EventKind::Recovered, name, 0, 0, "");
                log("recovered", "probe succeeded; quarantine lifted");
            }
        }
    }
}

fn serve_impl(provider: Provider, metrics: Arc<Metrics>, cfg: ServeConfig) -> Result<ServerHandle> {
    if let Provider::Factory(factory) = &provider {
        // Preflight: build (and drop) one model before anything spawns.
        // A factory that cannot build fails `serve()` fast, instead of
        // every worker dying at startup and leaving a server that
        // accepts connections but never answers. Workers still build
        // their own instance (PJRT executables are not `Send`).
        drop(factory().context(
            "model factory preflight failed; refusing to start a server whose workers \
             cannot build their model",
        )?);
    }
    let listener = TcpListener::bind(&cfg.bind).context("bind")?;
    let addr = listener.local_addr()?;
    // Size the flight recorder before any traffic can record into it
    // (0 disables tracing entirely; see `--no-trace`).
    metrics.recorder.configure(cfg.trace_capacity);
    let batcher = Arc::new(Batcher::new(
        cfg.max_batch,
        Duration::from_millis(cfg.window_ms),
        cfg.queue_depth,
        Arc::clone(&metrics),
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let (store, default_model) = match &provider {
        Provider::Store { store, default, .. } => (Some(Arc::clone(store)), Some(default.clone())),
        Provider::Factory(_) => (None, None),
    };
    // Durable registry: write the starting state before taking traffic,
    // so a crash at any later point recovers to a manifest that exists.
    // A store dir that cannot be written fails startup fast rather than
    // silently serving without crash recovery.
    let manifest = match (&cfg.store_dir, &store, &default_model) {
        (Some(dir), Some(store), Some(default)) => {
            let writer = Arc::new(ManifestWriter::new(dir, Arc::clone(store), default));
            writer.persist()?;
            Some(writer)
        }
        _ => None,
    };

    let workers: Vec<_> = (0..resolve_threads(cfg.workers))
        .map(|wi| {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let manifest = manifest.clone();
            let log_json = cfg.log_json;
            let worker_provider = match &provider {
                Provider::Store { store, default, threads } => Provider::Store {
                    store: Arc::clone(store),
                    default: default.clone(),
                    threads: *threads,
                },
                Provider::Factory(f) => Provider::Factory(Arc::clone(f)),
            };
            thread::Builder::new()
                .name(format!("gs-serve-worker-{wi}"))
                .spawn(move || match worker_provider {
                    Provider::Store { .. } => {
                        while let Some(batch) = batcher.next_batch() {
                            // The whole (model-homogeneous) batch runs on
                            // the slot it was admitted against — pinned
                            // by the request's Arc, so neither a swap nor
                            // an LRU eviction landing mid-flight disturbs
                            // it — and on a single snapshot, so a batch
                            // never mixes versions.
                            let Some(slot) = batch.first().and_then(|r| r.slot.clone()) else {
                                // Per-request accounting (conservation),
                                // as in run_batch's error path.
                                let n = batch.len() as u64;
                                metrics.count_errors(&batch[0].model, n);
                                for req in batch {
                                    let why = Reject::error("request lost its slot");
                                    let _ = req.tx.send((req.id, Err(why)));
                                }
                                continue;
                            };
                            let vm = slot.current();
                            let name = batch[0].model.clone();
                            // Captured before execution: the batch that
                            // carries a half-open probe reports as one.
                            let probe = batch.iter().any(|r| r.probe);
                            let mm = metrics.model(&name);
                            let (ok, err) =
                                run_batch(&vm.model, batch, &metrics, Some(mm.as_ref()));
                            // Outcomes feed the slot's canary watch and
                            // circuit breaker, keyed by the snapshot
                            // version so stragglers from an older
                            // generation cannot judge the new one.
                            let events = slot.observe_execution(vm.version, ok, err, probe);
                            apply_slot_events(
                                &events,
                                &name,
                                &metrics,
                                manifest.as_deref(),
                                log_json,
                            );
                        }
                    }
                    Provider::Factory(factory) => {
                        let model = match factory() {
                            Ok(m) => m,
                            Err(e) => {
                                eprintln!("worker {wi}: model load failed: {e:#}");
                                metrics.errors.fetch_add(1, Ordering::Relaxed);
                                return;
                            }
                        };
                        while let Some(batch) = batcher.next_batch() {
                            run_batch(&model, batch, &metrics, None);
                        }
                    }
                })
                .expect("spawn worker")
        })
        .collect();

    let conns = Arc::new(ConnTracker::new());
    let ctx = Arc::new(ConnCtx {
        store: store.clone(),
        default_model: default_model.clone(),
        threads: match &provider {
            Provider::Store { threads, .. } => *threads,
            Provider::Factory(_) => 0,
        },
        input_width: cfg.input_width,
        deadline_ms: cfg.deadline_ms,
        idle_timeout_ms: cfg.idle_timeout_ms,
        max_frame_bytes: cfg.max_frame_bytes,
        binary_wire: cfg.binary_wire,
        max_inflight: cfg.max_inflight,
        slot_cfg: cfg.slot,
        manifest: manifest.clone(),
        conns: Arc::clone(&conns),
        log_json: cfg.log_json,
        slow_request_ms: cfg.slow_request_ms,
    });

    // Control-plane pool: ops other than infer run here, off the event
    // loop, so a slow metrics scrape or a swap's artifact load never
    // stalls frame dispatch. The sole Sender lives on the event loop;
    // when it exits, the pool drains and exits.
    let (control_tx, control_rx) = channel::<ControlTask>();
    let control_rx = Arc::new(Mutex::new(control_rx));
    let control: Vec<_> = (0..CONTROL_THREADS)
        .map(|i| {
            let rx = Arc::clone(&control_rx);
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let ctx = Arc::clone(&ctx);
            thread::Builder::new()
                .name(format!("gs-serve-control-{i}"))
                .spawn(move || control_loop(&rx, &batcher, &metrics, &ctx))
                .expect("spawn control worker")
        })
        .collect();

    // The event loop: nonblocking listener + every client socket on one
    // poller. Readiness setup failures abort startup (a server that
    // cannot watch sockets cannot serve).
    listener
        .set_nonblocking(true)
        .context("listener nonblocking")?;
    let poller = Poller::new().context("create readiness poller")?;
    poller
        .register_read(poll::raw_fd(&listener), LISTENER_TOKEN)
        .context("register listener")?;
    let acceptor = {
        let batcher = Arc::clone(&batcher);
        let metrics = Arc::clone(&metrics);
        let stop2 = Arc::clone(&stop);
        let ctx = Arc::clone(&ctx);
        let max_conns = cfg.max_conns;
        thread::Builder::new()
            .name("gs-serve-acceptor".into())
            .spawn(move || {
                front_end_loop(
                    &listener, &poller, &batcher, &metrics, &ctx, &stop2, max_conns, &control_tx,
                );
            })
            .expect("spawn acceptor")
    };

    Ok(ServerHandle {
        addr,
        batcher,
        stop,
        metrics,
        store,
        default_model,
        workers,
        acceptor: Some(acceptor),
        control,
        conns,
    })
}

/// Poller token reserved for the listening socket (connection ids are
/// sequential from 0 and can never collide with it).
const LISTENER_TOKEN: u64 = u64::MAX;

/// Control-plane worker count. Two is enough: control ops are rare
/// next to infers, and the second thread keeps one slow scrape from
/// head-of-line-blocking a deploy.
const CONTROL_THREADS: usize = 2;

/// Everything a connection needs to admit and route requests.
struct ConnCtx {
    /// None in factory mode.
    store: Option<Arc<ModelStore>>,
    default_model: Option<String>,
    /// Kernel threads for `load`-instantiated models.
    threads: usize,
    /// Factory-mode admission width (store mode checks per slot).
    input_width: usize,
    /// Server-default queue-wait budget (0 = none).
    deadline_ms: u64,
    /// Per-connection idle/reply-write budget (0 = none); also names
    /// itself in the structured idle goodbye.
    idle_timeout_ms: u64,
    /// Frame-size bound for the dual-framing decoder (0 = unbounded).
    max_frame_bytes: usize,
    /// Whether HELLO negotiation is granted (false = JSON-only server).
    binary_wire: bool,
    /// Per-connection pipelined-depth cap (0 = unbounded).
    max_inflight: usize,
    /// Deployment-safety contract for `load`-registered slots.
    slot_cfg: SlotConfig,
    /// Durable registry writer (`--store-dir`); None when persistence is
    /// off or in factory mode.
    manifest: Option<Arc<ManifestWriter>>,
    /// Live-connection registry (the `connections` stats gauge).
    conns: Arc<ConnTracker>,
    /// Operational log lines as JSON objects instead of prose.
    log_json: bool,
    /// Slow-request trace-logging threshold in ms (0 = off).
    slow_request_ms: u64,
}

/// Re-persist the durable registry after an accepted deploy op. The
/// in-memory registry already changed, so a failed write is logged
/// rather than failing the op — the next successful persist (or a
/// restart from the previous manifest generation) re-converges.
fn persist_manifest(ctx: &ConnCtx, op: &str) {
    if let Some(m) = &ctx.manifest {
        if let Err(e) = m.persist() {
            eprintln!("manifest persist after {op}: {e:#}");
        }
    }
}

fn err_json(msg: String) -> Json {
    Json::obj(vec![("error", Json::Str(msg))])
}

/// Resolve the request's `"model"` field (or the default) to a slot
/// name. Only called in store mode (factory mode rejects routed
/// requests before routing). A present-but-non-string field is an
/// error, never a silent fallthrough to the default model (that would
/// execute the request on the wrong model). Errors come back as plain
/// messages so each caller can shape the reply (infer attaches the
/// request id).
fn requested_model<'a>(msg: &'a Json, ctx: &'a ConnCtx) -> Result<&'a str, String> {
    match msg.get("model") {
        Some(Json::Str(name)) => Ok(name.as_str()),
        Some(_) => Err("\"model\" must be a string".into()),
        None => match &ctx.default_model {
            Some(default) => Ok(default.as_str()),
            None => Err("server has no default model".into()),
        },
    }
}

/// Which framing a reply must be serialized in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FrameMode {
    Json,
    Binary,
}

/// Per-connection state shared between the event loop, the connection's
/// writer thread, and the control pool.
struct ConnShared {
    /// The writer half. Writes from the writer thread and the control
    /// pool serialize on this lock, so frames never interleave
    /// mid-frame on the stream.
    sock: Mutex<TcpStream>,
    /// Replies owed, keyed by request id. Duplicate ids queue FIFO —
    /// the batcher replies per submission, so counts always match.
    pending: Mutex<HashMap<u64, VecDeque<PendingReply>>>,
    /// Admitted infers not yet written back (the `max_inflight` bound).
    inflight: AtomicUsize,
    /// Set when a write failed: the socket is gone, remaining replies
    /// drain as bookkeeping only, and the event loop reaps the entry.
    dead: AtomicBool,
}

/// One owed reply: the framing it was requested in, plus the accounting
/// baton for admitted infers (None for pre-admission rejects).
struct PendingReply {
    mode: FrameMode,
    meta: Option<ReplyMeta>,
}

/// Event-loop-side connection state.
struct Conn {
    /// The read half (nonblocking).
    stream: TcpStream,
    shared: Arc<ConnShared>,
    /// Reply channel consumed by this connection's writer thread. Every
    /// admitted request carries a clone; dropping the `Conn` closes the
    /// loop's copy so the writer exits once in-flight work resolves.
    tx: Sender<(u64, Result<Vec<f32>, Reject>)>,
    decoder: FrameDecoder,
    /// Negotiated (or first-INFER-implied) binary mode.
    binary: bool,
    last_activity: Instant,
    _guard: ConnGuard,
}

/// Work the event loop hands to the control pool.
enum ControlTask {
    /// A parsed control-plane op to execute and reply to (JSON line).
    Op { conn: Arc<ConnShared>, msg: Json },
    /// Pre-serialized bytes to write (bad-json replies, HELLO_ACKs).
    Raw { conn: Arc<ConnShared>, bytes: Vec<u8> },
}

/// What to do with a connection after servicing it.
enum ConnAction {
    Keep,
    /// Orderly close: stop reading, let the writer flush owed replies,
    /// and let the socket close when the last clone drops.
    CloseSoft,
    /// Protocol violation or reap: shut the socket down both ways now.
    CloseHard,
}

/// The connection front end: accepts, reads, decodes, and dispatches
/// every client socket from one thread via level-triggered readiness.
/// Infer replies leave through per-connection writer threads; control
/// replies through the control pool. Runs until `stop` is set (the
/// stop() poke connects, which wakes the listener token).
#[allow(clippy::too_many_arguments)]
fn front_end_loop(
    listener: &TcpListener,
    poller: &Poller,
    batcher: &Arc<Batcher>,
    metrics: &Arc<Metrics>,
    ctx: &Arc<ConnCtx>,
    stop: &AtomicBool,
    max_conns: usize,
    control_tx: &Sender<ControlTask>,
) {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut events: Vec<poll::Event> = Vec::new();
    let mut scratch = vec![0u8; 64 * 1024];
    // With an idle budget, wake at a fraction of it to reap on time;
    // without one, sleep until readiness (stop() wakes the listener).
    let tick = if ctx.idle_timeout_ms > 0 {
        Some(Duration::from_millis((ctx.idle_timeout_ms / 4).clamp(10, 250)))
    } else {
        None
    };
    while !stop.load(Ordering::SeqCst) {
        if poller.wait(&mut events, tick).is_err() {
            break;
        }
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let tokens: Vec<u64> = events.iter().map(|e| e.token).collect();
        for token in tokens {
            if token == LISTENER_TOKEN {
                accept_ready(listener, poller, &mut conns, metrics, ctx, max_conns);
            } else if let Some(conn) = conns.get_mut(&token) {
                match service_conn(conn, batcher, metrics, ctx, control_tx, &mut scratch) {
                    ConnAction::Keep => {}
                    ConnAction::CloseSoft => close_conn(&mut conns, poller, token, false, metrics),
                    ConnAction::CloseHard => close_conn(&mut conns, poller, token, true, metrics),
                }
            }
        }
        // Reap: idle connections (no bytes within the budget — covers a
        // slowloris stalled mid-frame) and ones whose writer found the
        // socket dead.
        let mut reap: Vec<(u64, bool)> = Vec::new();
        for (&id, conn) in &conns {
            if conn.shared.dead.load(Ordering::SeqCst) {
                reap.push((id, false));
            } else if ctx.idle_timeout_ms > 0
                && conn.last_activity.elapsed() >= Duration::from_millis(ctx.idle_timeout_ms)
            {
                reap.push((id, true));
            }
        }
        for (id, goodbye) in reap {
            if goodbye {
                if let Some(conn) = conns.get(&id) {
                    send_goodbye(
                        conn,
                        &err_json(format!(
                            "idle timeout: no complete frame within {} ms; closing connection",
                            ctx.idle_timeout_ms
                        )),
                    );
                }
            }
            close_conn(&mut conns, poller, id, true, metrics);
        }
    }
    // Orderly shutdown: drop every connection softly — writer threads
    // flush the structured failures batcher.shutdown() is about to
    // issue, then exit and are joined by ConnTracker::drain.
    let ids: Vec<u64> = conns.keys().copied().collect();
    for id in ids {
        close_conn(&mut conns, poller, id, false, metrics);
    }
}

/// Accept every connection the listener has ready. At `max_conns`, a
/// new connection gets one structured at-capacity reply and is closed —
/// no poller slot, no writer thread.
fn accept_ready(
    listener: &TcpListener,
    poller: &Poller,
    conns: &mut HashMap<u64, Conn>,
    metrics: &Arc<Metrics>,
    ctx: &Arc<ConnCtx>,
    max_conns: usize,
) {
    loop {
        let (conn, _) = match listener.accept() {
            Ok(accepted) => accepted,
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return,
        };
        let _ = conn.set_nodelay(true); // line/frame RPC: Nagle hurts
        let tracker = &ctx.conns;
        if max_conns > 0 && tracker.live.load(Ordering::SeqCst) >= max_conns {
            // Best-effort structured reply on a briefly-blocking socket
            // (nonblocking state is not portably inherited from the
            // listener, so set it explicitly).
            let reply = Json::obj(vec![
                (
                    "error",
                    Json::Str("server at connection capacity; retry later".into()),
                ),
                ("max_conns", Json::Num(max_conns as f64)),
            ]);
            let _ = conn.set_nonblocking(false);
            let _ = conn.set_write_timeout(Some(Duration::from_millis(500)));
            let mut w = &conn;
            let _ = w.write_all(reply.to_string().as_bytes());
            let _ = w.write_all(b"\n");
            continue; // drop = close
        }
        if conn.set_nonblocking(true).is_err() {
            continue;
        }
        let Ok(wsock) = conn.try_clone() else { continue };
        let id = tracker.register(&conn);
        let guard = ConnGuard { tracker: Arc::clone(tracker), id };
        let shared = Arc::new(ConnShared {
            sock: Mutex::new(wsock),
            pending: Mutex::new(HashMap::new()),
            inflight: AtomicUsize::new(0),
            dead: AtomicBool::new(false),
        });
        let (tx, rx) = channel();
        let writer_shared = Arc::clone(&shared);
        let writer_metrics = Arc::clone(metrics);
        let writer_ctx = Arc::clone(ctx);
        let handle = thread::Builder::new()
            .name(format!("gs-serve-writer-{id}"))
            .spawn(move || writer_loop(rx, &writer_shared, &writer_metrics, &writer_ctx))
            .expect("spawn connection writer");
        tracker.track(handle);
        if poller.register_read(poll::raw_fd(&conn), id).is_err() {
            // Cannot watch it — give up on this connection. Dropping tx
            // (with nothing in flight) ends its writer.
            shared.dead.store(true, Ordering::SeqCst);
            continue;
        }
        conns.insert(
            id,
            Conn {
                stream: conn,
                shared,
                tx,
                decoder: FrameDecoder::new(ctx.max_frame_bytes),
                binary: false,
                last_activity: Instant::now(),
                _guard: guard,
            },
        );
    }
}

/// Remove a connection from the loop. `hard` shuts the socket down both
/// ways immediately; a soft close drops the read half and lets the
/// writer thread flush owed replies before the stream closes.
fn close_conn(
    conns: &mut HashMap<u64, Conn>,
    poller: &Poller,
    id: u64,
    hard: bool,
    metrics: &Metrics,
) {
    let Some(conn) = conns.remove(&id) else { return };
    // Deregister before the read-half fd drops: the poller keys on the
    // open file description, which the writer clone keeps alive.
    let _ = poller.deregister(poll::raw_fd(&conn.stream));
    if hard {
        let _ = conn.stream.shutdown(Shutdown::Both);
    }
    if conn.binary {
        metrics.binary_connections.fetch_sub(1, Ordering::Relaxed);
    }
    // Dropping `conn` drops the loop's tx; once every in-flight
    // request's clone resolves, the writer drains and exits.
}

/// Drain every readable byte from one connection and dispatch the
/// complete frames. Returns what to do with the connection.
fn service_conn(
    conn: &mut Conn,
    batcher: &Arc<Batcher>,
    metrics: &Arc<Metrics>,
    ctx: &Arc<ConnCtx>,
    control_tx: &Sender<ControlTask>,
    scratch: &mut [u8],
) -> ConnAction {
    loop {
        let n = match (&conn.stream).read(scratch) {
            Ok(0) => {
                // EOF. A final unterminated JSON line is still served
                // (matching the old reader's lines() semantics); a torn
                // binary frame is not a request. Soft close either way
                // — owed replies flush before the stream closes.
                if let Some(line) = conn.decoder.trailing_line() {
                    let _ = dispatch_json_line(&line, conn, batcher, metrics, ctx, control_tx);
                }
                return ConnAction::CloseSoft;
            }
            Ok(n) => n,
            Err(e) if e.kind() == ErrorKind::WouldBlock => return ConnAction::Keep,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return ConnAction::CloseHard,
        };
        conn.last_activity = Instant::now();
        conn.decoder.feed(&scratch[..n]);
        loop {
            match conn.decoder.next() {
                Ok(Some(frame)) => {
                    match dispatch_frame(frame, conn, batcher, metrics, ctx, control_tx) {
                        ConnAction::Keep => {}
                        action => return action,
                    }
                }
                Ok(None) => break,
                Err(DecodeError::TooLarge { .. }) => {
                    // Mid-frame there is no way to resync on the
                    // stream, so reply structurally and close.
                    send_goodbye(
                        conn,
                        &Json::obj(vec![
                            (
                                "error",
                                Json::Str("frame too large; closing connection".into()),
                            ),
                            ("max_frame_bytes", Json::Num(ctx.max_frame_bytes as f64)),
                        ]),
                    );
                    return ConnAction::CloseHard;
                }
                Err(DecodeError::Header(e)) => {
                    send_goodbye(conn, &err_json(format!("bad frame: {e}; closing connection")));
                    return ConnAction::CloseHard;
                }
            }
        }
    }
}

/// Dispatch one decoded frame. Binary INFERs and all JSON infers go to
/// [`admit_infer`]; everything else rides the control pool.
fn dispatch_frame(
    frame: WireFrame,
    conn: &mut Conn,
    batcher: &Arc<Batcher>,
    metrics: &Arc<Metrics>,
    ctx: &Arc<ConnCtx>,
    control_tx: &Sender<ControlTask>,
) -> ConnAction {
    match frame {
        WireFrame::Json(line) => dispatch_json_line(&line, conn, batcher, metrics, ctx, control_tx),
        WireFrame::Binary(header, payload) => {
            metrics.frames_binary.fetch_add(1, Ordering::Relaxed);
            match header.opcode {
                Opcode::Hello => {
                    if !ctx.binary_wire {
                        // Declined: a JSON error line, which the client
                        // reads as the fall-back-to-JSON signal. The
                        // connection continues in JSON framing.
                        let _ = control_tx.send(ControlTask::Raw {
                            conn: Arc::clone(&conn.shared),
                            bytes: reply_line(&err_json(
                                "binary framing disabled on this server".into(),
                            )),
                        });
                        return ConnAction::Keep;
                    }
                    if header.version == 0 {
                        send_goodbye(
                            conn,
                            &err_json("unsupported wire protocol version 0".into()),
                        );
                        return ConnAction::CloseHard;
                    }
                    // Negotiate up: the ACK carries the version the
                    // server will speak (ours); a newer client is
                    // expected to downshift.
                    if !conn.binary {
                        enter_binary(conn, metrics, true);
                    }
                    let _ = control_tx.send(ControlTask::Raw {
                        conn: Arc::clone(&conn.shared),
                        bytes: wire::hello_ack_frame(),
                    });
                    ConnAction::Keep
                }
                Opcode::Infer => {
                    if header.version != wire::VERSION {
                        send_goodbye(
                            conn,
                            &err_json(format!(
                                "unsupported wire protocol version {}",
                                header.version
                            )),
                        );
                        return ConnAction::CloseHard;
                    }
                    if !conn.binary {
                        // A client may skip HELLO (it forgoes the
                        // fallback signal); the first INFER flips the
                        // connection's reply framing all the same.
                        enter_binary(conn, metrics, false);
                    }
                    match InferPayload::decode(&payload) {
                        Ok(p) => admit_infer(
                            InferArgs {
                                id: header.id,
                                model: Ok(p.model),
                                input: Some(p.input),
                                deadline: Ok(p.deadline_ms),
                            },
                            FrameMode::Binary,
                            conn,
                            batcher,
                            metrics,
                            ctx,
                        ),
                        Err(e) => reject_unadmitted(
                            conn,
                            FrameMode::Binary,
                            header.id,
                            format!("bad infer payload: {e}"),
                            metrics,
                        ),
                    }
                    ConnAction::Keep
                }
                Opcode::HelloAck | Opcode::Output | Opcode::Error => {
                    send_goodbye(
                        conn,
                        &err_json(format!(
                            "unexpected {:?} frame from a client; closing connection",
                            header.opcode
                        )),
                    );
                    ConnAction::CloseHard
                }
            }
        }
    }
}

/// Flip a connection to binary reply framing (idempotent by caller
/// check). `negotiated` distinguishes a real HELLO from an implied
/// first-INFER entry for the negotiation counter.
fn enter_binary(conn: &mut Conn, metrics: &Metrics, negotiated: bool) {
    conn.binary = true;
    metrics.binary_connections.fetch_add(1, Ordering::Relaxed);
    if negotiated {
        metrics.binary_negotiations.fetch_add(1, Ordering::Relaxed);
        if metrics.recorder.is_enabled() {
            metrics
                .recorder
                .record(EventKind::Negotiate, "", 0, 0, "binary framing");
        }
    }
}

/// Dispatch one JSON line: empty lines are keep-alive no-ops, malformed
/// lines get an error reply and the connection continues, infer is
/// admitted inline, and every other op rides the control pool.
fn dispatch_json_line(
    line: &str,
    conn: &mut Conn,
    batcher: &Arc<Batcher>,
    metrics: &Arc<Metrics>,
    ctx: &Arc<ConnCtx>,
    control_tx: &Sender<ControlTask>,
) -> ConnAction {
    let line = line.trim();
    if line.is_empty() {
        return ConnAction::Keep;
    }
    metrics.frames_json.fetch_add(1, Ordering::Relaxed);
    match Json::parse(line) {
        Err(e) => {
            let _ = control_tx.send(ControlTask::Raw {
                conn: Arc::clone(&conn.shared),
                bytes: reply_line(&err_json(format!("bad json: {e}"))),
            });
        }
        Ok(msg) => match msg.get("op").and_then(Json::as_str) {
            Some("infer") => {
                let id = msg.get("id").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                let model = match msg.get("model") {
                    None => Ok(None),
                    Some(Json::Str(name)) => Ok(Some(name.clone())),
                    Some(_) => Err("\"model\" must be a string".to_string()),
                };
                let input = msg.get("input").and_then(Json::to_f32_vec);
                // A present-but-invalid deadline is an error, never a
                // silent fallthrough (the client clearly wanted one).
                let deadline = match msg.get("deadline_ms") {
                    None => Ok(None),
                    Some(j) => match j.as_f64() {
                        Some(v) if v >= 0.0 && v.fract() == 0.0 => Ok(Some(v as u64)),
                        _ => Err("\"deadline_ms\" must be a non-negative integer".to_string()),
                    },
                };
                admit_infer(
                    InferArgs { id, model, input, deadline },
                    FrameMode::Json,
                    conn,
                    batcher,
                    metrics,
                    ctx,
                );
            }
            _ => {
                let _ = control_tx.send(ControlTask::Op {
                    conn: Arc::clone(&conn.shared),
                    msg,
                });
            }
        },
    }
    ConnAction::Keep
}

/// Serialize a JSON reply as one protocol line.
fn reply_line(reply: &Json) -> Vec<u8> {
    let mut bytes = reply.to_string().into_bytes();
    bytes.push(b'\n');
    bytes
}

/// Best-effort structured goodbye in the connection's framing, bounded
/// so a non-reading client cannot stall the event loop.
fn send_goodbye(conn: &Conn, msg: &Json) {
    let bytes = if conn.binary {
        wire::frame(Opcode::Error, 0, msg.to_string().as_bytes())
    } else {
        reply_line(msg)
    };
    let _ = write_shared(&conn.shared, &bytes, GOODBYE_BUDGET_MS);
}

/// Write budget for goodbyes off the event loop thread (ms).
const GOODBYE_BUDGET_MS: u64 = 500;

/// The control pool: executes control-plane ops and writes their
/// replies (plus pre-serialized raw replies) without blocking the event
/// loop. Exits when the event loop drops the task channel.
fn control_loop(
    rx: &Mutex<Receiver<ControlTask>>,
    batcher: &Arc<Batcher>,
    metrics: &Arc<Metrics>,
    ctx: &Arc<ConnCtx>,
) {
    loop {
        // Hold the receiver lock only for the blocking recv, never
        // while executing an op — the other pool thread must be able to
        // pick up the next task meanwhile.
        let task = match rx.lock().unwrap().recv() {
            Ok(task) => task,
            Err(_) => return,
        };
        let (conn, bytes) = match task {
            ControlTask::Raw { conn, bytes } => (conn, bytes),
            ControlTask::Op { conn, msg } => {
                let reply = dispatch_control(&msg, batcher, metrics, ctx);
                (conn, reply_line(&reply))
            }
        };
        if write_shared(&conn, &bytes, ctx.idle_timeout_ms).is_err() {
            mark_dead(&conn);
        }
    }
}

/// Execute one control-plane op (anything but infer). The infer arm is
/// defensive: the event loop never routes infer here.
fn dispatch_control(msg: &Json, batcher: &Batcher, metrics: &Metrics, ctx: &ConnCtx) -> Json {
    match msg.get("op").and_then(Json::as_str) {
        Some("ping") => {
            let mut fields = vec![("ok", Json::Bool(true))];
            if let Some(slot) = default_slot(ctx) {
                fields.push(("version", Json::Num(slot.version() as f64)));
            }
            Json::obj(fields)
        }
        Some("stats") => stats_json(metrics, batcher, ctx),
        Some("models") => models_json(ctx),
        Some("swap") => handle_swap(msg, ctx, metrics),
        Some("load") => handle_load(msg, ctx, metrics),
        Some("unload") => handle_unload(msg, ctx),
        Some("rollback") => handle_rollback(msg, ctx, metrics),
        Some("trace") => handle_trace(msg, metrics),
        Some("metrics") => Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "content_type",
                Json::Str("text/plain; version=0.0.4".into()),
            ),
            ("text", Json::Str(prometheus_text(metrics, batcher, ctx))),
        ]),
        Some("profile") => profile_json(msg),
        Some("infer") => err_json("internal error: infer routed to the control plane".into()),
        _ => err_json("unknown op".into()),
    }
}

/// Mark a connection's socket failed and tear it down; the event loop
/// reaps the entry on its next tick.
fn mark_dead(shared: &ConnShared) {
    shared.dead.store(true, Ordering::SeqCst);
    let _ = shared.sock.lock().unwrap().shutdown(Shutdown::Both);
}

/// Write a full buffer on the (nonblocking) shared writer half, parking
/// on writability up to `budget_ms` total (0 = no budget — parity with
/// the historical blocking writes of an idle-timeout-less server).
fn write_shared(shared: &ConnShared, bytes: &[u8], budget_ms: u64) -> std::io::Result<()> {
    let sock = shared.sock.lock().unwrap();
    write_all_nb(&sock, bytes, budget_ms)
}

fn write_all_nb(sock: &TcpStream, buf: &[u8], budget_ms: u64) -> std::io::Result<()> {
    let fd = poll::raw_fd(sock);
    let started = Instant::now();
    let mut writer: &TcpStream = sock;
    let mut off = 0;
    while off < buf.len() {
        match writer.write(&buf[off..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::WriteZero,
                    "socket write returned 0",
                ))
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if budget_ms > 0 && started.elapsed() >= Duration::from_millis(budget_ms) {
                    return Err(std::io::Error::new(
                        ErrorKind::TimedOut,
                        "reply write outwaited the connection's write budget",
                    ));
                }
                poll::wait_writable(fd, 100)?;
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// The per-connection writer thread: consumes the connection's reply
/// channel and flushes each reply in its requested framing **as it
/// completes** — batch completion order, not request arrival order.
/// Exits when every sender (the event loop's copy + each in-flight
/// request's clone) is gone, which guarantees the owed-reply books
/// drain to zero.
fn writer_loop(
    rx: Receiver<(u64, Result<Vec<f32>, Reject>)>,
    shared: &Arc<ConnShared>,
    metrics: &Arc<Metrics>,
    ctx: &Arc<ConnCtx>,
) {
    for (id, result) in rx {
        let owed = {
            let mut pending = shared.pending.lock().unwrap();
            match pending.get_mut(&id) {
                Some(queue) => {
                    let owed = queue.pop_front();
                    if queue.is_empty() {
                        pending.remove(&id);
                    }
                    owed
                }
                None => None,
            }
        };
        let Some(PendingReply { mode, meta }) = owed else {
            // A reply with no owed entry (cannot happen via admit_infer;
            // tolerated so a logic slip never wedges the writer).
            continue;
        };
        shared.inflight.fetch_sub(1, Ordering::SeqCst);
        metrics.inflight.fetch_sub(1, Ordering::Relaxed);
        if shared.dead.load(Ordering::SeqCst) {
            continue; // socket already failed: bookkeeping-only drain
        }
        let bytes = match mode {
            FrameMode::Json => reply_line(&infer_reply_json(id, &result)),
            FrameMode::Binary => match &result {
                Ok(out) => wire::frame(Opcode::Output, id, &wire::f32s_le(out)),
                Err(why) => wire::frame(
                    Opcode::Error,
                    id,
                    reject_json(id, why).to_string().as_bytes(),
                ),
            },
        };
        let write_started = Instant::now();
        if write_shared(shared, &bytes, ctx.idle_timeout_ms).is_err() {
            mark_dead(shared);
            continue;
        }
        // An admitted infer finishes its stage accounting only once its
        // reply actually hit the socket.
        if let Some(meta) = meta {
            let wsecs = write_started.elapsed().as_secs_f64();
            metrics.stages.record(Stage::ReplyWrite, wsecs);
            if let Some(mm) = &meta.mm {
                mm.stages.record(Stage::ReplyWrite, wsecs);
            }
            let total_ms = meta.started.elapsed().as_secs_f64() * 1e3;
            if ctx.slow_request_ms > 0 && total_ms > ctx.slow_request_ms as f64 {
                log_slow_request(metrics, &meta, total_ms, ctx.log_json);
            }
        }
    }
}

/// Shape one infer reply (success or structured failure) as JSON.
fn infer_reply_json(id: u64, result: &Result<Vec<f32>, Reject>) -> Json {
    match result {
        Ok(out) => Json::obj(vec![
            ("id", Json::Num(id as f64)),
            ("output", Json::nums_f32(out)),
        ]),
        Err(why) => reject_json(id, why),
    }
}

/// Shape a structured failure; also the payload of binary ERROR frames,
/// so reject semantics (retry/expiry/quarantine hints) are identical
/// across framings.
fn reject_json(id: u64, why: &Reject) -> Json {
    let mut fields = vec![
        ("id", Json::Num(id as f64)),
        ("error", Json::Str(why.error.clone())),
    ];
    if let Some(ms) = why.retry_after_ms {
        fields.push(("retry_after_ms", Json::Num(ms as f64)));
    }
    if let Some(ms) = why.waited_ms {
        fields.push(("waited_ms", Json::Num(ms as f64)));
    }
    if let Some(ms) = why.quarantined_for_ms {
        fields.push(("quarantined_for_ms", Json::Num(ms as f64)));
    }
    Json::obj(fields)
}

/// What the reply path needs to finish an admitted infer's accounting
/// after its reply hits the socket: the reply-write stage sample and
/// the slow-request check. Requests rejected before admission never
/// produce one.
struct ReplyMeta {
    id: u64,
    model: String,
    /// The routed model's breakdown (None in factory mode).
    mm: Option<Arc<ModelMetrics>>,
    /// When the connection thread started handling this request.
    started: Instant,
}

/// A request outlived `slow_request_ms`: log one line carrying its full
/// retained lifecycle from the flight recorder — its request-scoped
/// events plus the batch-scoped events of any batch it rode. Request
/// ids are client-chosen correlation hints, so a shared id merges the
/// traces of requests using it (documented in [`super::trace`]).
fn log_slow_request(metrics: &Metrics, meta: &ReplyMeta, total_ms: f64, log_json: bool) {
    let events = metrics.recorder.snapshot();
    let batch_ids: Vec<u64> = events
        .iter()
        .filter(|e| e.request_id == meta.id && e.batch_id != 0)
        .map(|e| e.batch_id)
        .collect();
    let mine: Vec<&TraceEvent> = events
        .iter()
        .filter(|e| {
            e.request_id == meta.id || (e.batch_id != 0 && batch_ids.contains(&e.batch_id))
        })
        .collect();
    if log_json {
        eprintln!(
            "{}",
            Json::obj(vec![
                ("event", Json::Str("slow_request".into())),
                ("id", Json::Num(meta.id as f64)),
                ("model", Json::Str(meta.model.clone())),
                ("total_ms", Json::Num(total_ms)),
                ("trace", Json::Arr(mine.iter().map(|e| e.to_json()).collect())),
            ])
        );
    } else {
        eprintln!(
            "slow request id={} model=\"{}\": {total_ms:.1} ms; {} trace events:",
            meta.id,
            meta.model,
            mine.len()
        );
        for e in &mine {
            eprintln!("  {}", e.to_json());
        }
    }
}

/// `{"op":"trace"}`: the flight recorder's retained events, oldest
/// first, optionally narrowed by `"model"`, `"event"` (wire spelling,
/// e.g. `"batch_formed"`), `"id"` (request id), and `"limit"` (keep
/// only the newest N after filtering).
fn handle_trace(msg: &Json, metrics: &Metrics) -> Json {
    let rec = &metrics.recorder;
    let mut events = rec.snapshot();
    if let Some(model) = msg.get("model").and_then(Json::as_str) {
        events.retain(|e| e.model == model);
    }
    if let Some(kind) = msg.get("event").and_then(Json::as_str) {
        events.retain(|e| e.kind.name() == kind);
    }
    if let Some(id) = msg.get("id").and_then(Json::as_f64) {
        events.retain(|e| e.request_id == id as u64);
    }
    if let Some(limit) = msg.get("limit").and_then(Json::as_f64) {
        let keep = limit.max(0.0) as usize;
        if events.len() > keep {
            events.drain(..events.len() - keep);
        }
    }
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("enabled", Json::Bool(rec.is_enabled())),
        ("capacity", Json::Num(rec.capacity() as f64)),
        ("dropped", Json::Num(rec.dropped() as f64)),
        (
            "events",
            Json::Arr(events.iter().map(TraceEvent::to_json).collect()),
        ),
    ])
}

/// `{"op":"profile"}`: kernel chunk load-imbalance summaries keyed by
/// plan geometry fingerprint (see [`crate::kernels::profile`]). With
/// `"reset":true` the aggregates are cleared after being reported.
fn profile_json(msg: &Json) -> Json {
    let plans = kernel_profile::snapshot_json();
    if msg.get("reset").and_then(Json::as_bool) == Some(true) {
        kernel_profile::reset();
    }
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("profiling", Json::Bool(kernel_profile::enabled())),
        ("plans", plans),
    ])
}

fn default_slot(ctx: &ConnCtx) -> Option<Arc<ModelSlot>> {
    ctx.store.as_ref()?.get(ctx.default_model.as_deref()?)
}

/// One infer request, parsed out of either framing into a common
/// shape. The `Err` legs carry the exact validation message the parse
/// produced, so both framings reject with identical text.
struct InferArgs {
    id: u64,
    model: Result<Option<String>, String>,
    input: Option<Vec<f32>>,
    deadline: Result<Option<u64>, String>,
}

/// Validate, route, and admit one infer into the batcher — or reject it
/// pre-admission. Either way exactly one reply becomes owed on the
/// connection and later flushes through its writer thread; this
/// function never blocks on the result, which is what lets one event
/// loop carry every connection.
fn admit_infer(
    args: InferArgs,
    mode: FrameMode,
    conn: &Conn,
    batcher: &Arc<Batcher>,
    metrics: &Arc<Metrics>,
    ctx: &Arc<ConnCtx>,
) {
    let started = Instant::now();
    let id = args.id;
    // Pipelining depth gate, before any routing work: a client flooding
    // unanswered requests is refused structurally per request.
    if ctx.max_inflight > 0 && conn.shared.inflight.load(Ordering::SeqCst) >= ctx.max_inflight {
        reject_unadmitted(
            conn,
            mode,
            id,
            format!(
                "too many in-flight requests on this connection (max {})",
                ctx.max_inflight
            ),
            metrics,
        );
        return;
    }
    // Resolve the route. Factory mode admits only unrouted requests.
    // This lookup is a plain `get` — recency is only bumped further
    // down, once the request has actually been validated and admitted
    // (a stream of rejected requests must not keep a cold model warm).
    let (mut slot, model_name) = match &ctx.store {
        Some(store) => {
            let name = match &args.model {
                Ok(Some(name)) => name.clone(),
                Ok(None) => match &ctx.default_model {
                    Some(default) => default.clone(),
                    None => {
                        reject_unadmitted(
                            conn,
                            mode,
                            id,
                            "server has no default model".into(),
                            metrics,
                        );
                        return;
                    }
                },
                Err(e) => {
                    reject_unadmitted(conn, mode, id, e.clone(), metrics);
                    return;
                }
            };
            match store.get(&name) {
                Some(slot) => (Some(slot), name),
                None => {
                    reject_unadmitted(
                        conn,
                        mode,
                        id,
                        format!("unknown model \"{name}\""),
                        metrics,
                    );
                    return;
                }
            }
        }
        None => {
            if !matches!(args.model, Ok(None)) {
                reject_unadmitted(
                    conn,
                    mode,
                    id,
                    "model routing unavailable: server runs factory-backed workers".into(),
                    metrics,
                );
                return;
            }
            (None, String::new())
        }
    };
    let width = slot.as_ref().map_or(ctx.input_width, |s| s.input_width());
    let input = match args.input {
        Some(input) if input.len() == width => input,
        _ => {
            let suffix = if model_name.is_empty() {
                String::new()
            } else {
                format!(" (model \"{model_name}\")")
            };
            reject_unadmitted(
                conn,
                mode,
                id,
                format!("input must be {width} floats{suffix}"),
                metrics,
            );
            return;
        }
    };
    let mut route_mm = None;
    if let Some(store) = &ctx.store {
        // Touch-on-admit: the validated request bumps LRU recency (and
        // re-resolves the slot in case a concurrent load replaced it —
        // the freshest generation should serve).
        match store.acquire(&model_name) {
            Some(s) => {
                // The name may have been re-registered with different
                // geometry between validation and admission; re-check
                // against the slot that will actually execute, so a
                // stale-width request can never join (and fail) a batch
                // of valid requests on the new slot.
                if s.input_width() != input.len() {
                    reject_unadmitted(
                        conn,
                        mode,
                        id,
                        format!(
                            "input must be {} floats (model \"{model_name}\")",
                            s.input_width()
                        ),
                        metrics,
                    );
                    return;
                }
                slot = Some(s);
            }
            None => {
                reject_unadmitted(
                    conn,
                    mode,
                    id,
                    format!("unknown model \"{model_name}\""),
                    metrics,
                );
                return;
            }
        }
        let mm = metrics.model(&model_name);
        mm.requests.fetch_add(1, Ordering::Relaxed);
        mm.touch();
        route_mm = Some(mm);
    }
    // Queue-wait budget: the request's own deadline wins over the
    // server default; an explicit 0 opts out.
    let deadline_ms = match args.deadline {
        Ok(None) => ctx.deadline_ms,
        Ok(Some(v)) => v,
        Err(e) => {
            reject_unadmitted(conn, mode, id, e, metrics);
            return;
        }
    };
    let cap = slot.as_ref().map_or(usize::MAX, |s| s.batch_capacity());
    if metrics.recorder.is_enabled() {
        metrics
            .recorder
            .record(EventKind::Admit, &model_name, id, 0, "");
    }
    push_pending(
        conn,
        mode,
        id,
        Some(ReplyMeta {
            id,
            model: model_name.clone(),
            mm: route_mm,
            started,
        }),
        metrics,
    );
    // A refused submit (overload shed, shutdown) has already failed the
    // request's tx with a structured Reject, so the writer-side reply
    // path is uniform — the Result here is deliberately not consulted.
    let _ = batcher.submit(InferRequest {
        id,
        input,
        enqueued: Instant::now(),
        tx: conn.tx.clone(),
        model: model_name,
        slot,
        cap,
        batch_id: 0,
        deadline_ms: if deadline_ms == 0 { None } else { Some(deadline_ms) },
        probe: false,
    });
}

/// Refuse an infer before admission: book the owed reply, then fail it
/// through the connection's own reply channel, so the writer thread is
/// the single reply path for both framings and rejects serialize in
/// submission order relative to earlier same-connection requests only
/// as batches allow — exactly like any other pipelined reply.
fn reject_unadmitted(conn: &Conn, mode: FrameMode, id: u64, msg: String, metrics: &Metrics) {
    push_pending(conn, mode, id, None, metrics);
    let _ = conn.tx.send((id, Err(Reject::error(msg))));
}

/// Book one owed reply on the connection (bumps both in-flight gauges;
/// the writer thread decrements them as replies flush).
fn push_pending(conn: &Conn, mode: FrameMode, id: u64, meta: Option<ReplyMeta>, metrics: &Metrics) {
    conn.shared
        .pending
        .lock()
        .unwrap()
        .entry(id)
        .or_default()
        .push_back(PendingReply { mode, meta });
    conn.shared.inflight.fetch_add(1, Ordering::SeqCst);
    metrics.inflight.fetch_add(1, Ordering::Relaxed);
}

/// Parse the optional `"canary":{"requests":N,"max_error_rate":F}`
/// block of a swap. `Ok(None)` = no canary requested; a present but
/// malformed block is an error, never a silent plain swap (the operator
/// clearly wanted a watched deploy).
fn canary_spec(msg: &Json) -> Result<Option<(u64, f64)>, String> {
    let Some(canary) = msg.get("canary") else {
        return Ok(None);
    };
    let requests = canary.get("requests").and_then(Json::as_f64);
    let rate = canary.get("max_error_rate").and_then(Json::as_f64);
    match (requests, rate) {
        (Some(n), Some(f)) if n >= 1.0 && n.fract() == 0.0 && (0.0..=1.0).contains(&f) => {
            Ok(Some((n as u64, f)))
        }
        _ => Err("\"canary\" requires an integer \"requests\" >= 1 and a \"max_error_rate\" \
                  between 0 and 1"
            .into()),
    }
}

/// `{"op":"swap","model":...,"path":...}`: load + validate the artifact,
/// instantiate it, and swap it into the named (or default) slot. Traffic
/// keeps flowing on the old version until the new one is installed;
/// nothing is interrupted on failure (the error comes back on this
/// connection, the slot keeps its current generation, and the failure is
/// counted in `swap_failures` globally and per model). With a
/// `"canary"` block the new generation installs under a canary watch
/// (auto-rollback past the error budget) and the reply carries
/// `"state":"canary"`.
fn handle_swap(msg: &Json, ctx: &ConnCtx, metrics: &Metrics) -> Json {
    let Some(store) = &ctx.store else {
        return err_json("hot swap unavailable: server runs factory-backed workers".into());
    };
    let name = match requested_model(msg, ctx) {
        Ok(n) => n,
        Err(e) => return err_json(e),
    };
    let Some(path) = msg.get("path").and_then(Json::as_str) else {
        return err_json("swap requires a \"path\" to a .gsm artifact".into());
    };
    let canary = match canary_spec(msg) {
        Ok(c) => c,
        Err(e) => return err_json(e),
    };
    let Some(slot) = store.get(name) else {
        // A typo'd deploy is still a failed deploy: surface it on the
        // global counter (no per-model entry — never-registered names
        // must not grow the metrics map).
        metrics.swap_failures.fetch_add(1, Ordering::Relaxed);
        return err_json(format!("unknown model \"{name}\""));
    };
    let mm = metrics.model(name);
    let swapped = match canary {
        None => slot.swap_path(path),
        Some((requests, max_error_rate)) => slot.swap_path_canary(path, requests, max_error_rate),
    };
    match swapped {
        Ok(vm) => {
            metrics.swaps.fetch_add(1, Ordering::Relaxed);
            mm.swaps.fetch_add(1, Ordering::Relaxed);
            persist_manifest(ctx, "swap");
            let variant = vm
                .kernel_variant()
                .map(|v| format!(" {}", v.name()))
                .unwrap_or_default();
            metrics.recorder.record(
                EventKind::Swap,
                name,
                0,
                0,
                &format!(
                    "v{}{}{}",
                    vm.version,
                    variant,
                    if canary.is_some() { " canary" } else { "" }
                ),
            );
            // Report the generation *this* request installed, not
            // whatever a concurrent later swap made current.
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(name.into())),
                ("version", Json::Num(vm.version as f64)),
                (
                    "state",
                    Json::Str(if canary.is_some() { "canary" } else { "serving" }.into()),
                ),
            ];
            if let Some(p) = vm.precision() {
                fields.push(("precision", Json::Str(p.name().into())));
            }
            if let Some(v) = vm.kernel_variant() {
                fields.push(("kernel_variant", Json::Str(v.name().into())));
            }
            Json::obj(fields)
        }
        Err(e) => {
            metrics.swap_failures.fetch_add(1, Ordering::Relaxed);
            mm.swap_failures.fetch_add(1, Ordering::Relaxed);
            err_json(format!("{e:#}"))
        }
    }
}

/// `{"op":"load","model":...,"path":...}`: make a named model resident.
/// An existing name is hot-swapped in place (same zero-downtime path as
/// `swap`; the slot's serving contract still applies). A new name
/// registers a fresh slot at version 1, LRU-evicting the coldest
/// non-pinned model(s) if the store is at capacity — gracefully:
/// admitted requests hold their slot `Arc` and finish undisturbed.
fn handle_load(msg: &Json, ctx: &ConnCtx, metrics: &Metrics) -> Json {
    let Some(store) = &ctx.store else {
        return err_json("load unavailable: server runs factory-backed workers".into());
    };
    let Some(name) = msg.get("model").and_then(Json::as_str) else {
        return err_json("load requires a \"model\" name".into());
    };
    let Some(path) = msg.get("path").and_then(Json::as_str) else {
        return err_json("load requires a \"path\" to a .gsm artifact".into());
    };
    if msg.get("canary").is_some() {
        return err_json(
            "canary deploys are only supported on \"swap\": a freshly loaded model has no \
             previous generation to roll back to"
                .into(),
        );
    }
    // Load + instantiate exactly once, before any registry decision.
    let model = match ModelArtifact::load(path).and_then(|a| {
        a.instantiate(ctx.threads)
            .with_context(|| format!("instantiate artifact {path}"))
    }) {
        Ok(m) => m,
        Err(e) => {
            // Global counter only: a failed load of a never-registered
            // name must not mint a permanent per-model metrics entry
            // (typo'd names would grow `stats` without bound).
            metrics.swap_failures.fetch_add(1, Ordering::Relaxed);
            return err_json(format!("{e:#}"));
        }
    };
    let precision = model.precision();
    let variant = model.kernel_variant();
    if let Some(existing) = store.get(name) {
        // Resident name: swap the instantiated model into the captured
        // slot handle (contract-checked, zero-downtime, no second
        // artifact read). Operating on the handle rather than looking
        // the name up again means a concurrent unload cannot turn this
        // legitimate load into an "unknown model" failure — concurrent
        // admin ops are last-writer-wins at the registry.
        let mm = metrics.model(name);
        return match existing.swap(model, path) {
            Ok(vm) => {
                metrics.swaps.fetch_add(1, Ordering::Relaxed);
                mm.swaps.fetch_add(1, Ordering::Relaxed);
                persist_manifest(ctx, "load");
                let mut fields = vec![
                    ("ok", Json::Bool(true)),
                    ("model", Json::Str(name.into())),
                    ("version", Json::Num(vm.version as f64)),
                ];
                if let Some(p) = vm.precision() {
                    fields.push(("precision", Json::Str(p.name().into())));
                }
                if let Some(v) = vm.kernel_variant() {
                    fields.push(("kernel_variant", Json::Str(v.name().into())));
                }
                Json::obj(fields)
            }
            Err(e) => {
                metrics.swap_failures.fetch_add(1, Ordering::Relaxed);
                mm.swap_failures.fetch_add(1, Ordering::Relaxed);
                err_json(format!("{e:#}"))
            }
        };
    }
    let slot = Arc::new(ModelSlot::with_config(model, path, ctx.threads, ctx.slot_cfg));
    match store.register_new(name, slot) {
        Ok(Some(evicted)) => {
            metrics
                .evictions
                .fetch_add(evicted.len() as u64, Ordering::Relaxed);
            persist_manifest(ctx, "load");
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(name.into())),
                ("version", Json::Num(1.0)),
                (
                    "evicted",
                    Json::Arr(evicted.into_iter().map(Json::Str).collect()),
                ),
            ];
            if let Some(p) = precision {
                fields.push(("precision", Json::Str(p.name().into())));
            }
            if let Some(v) = variant {
                fields.push(("kernel_variant", Json::Str(v.name().into())));
            }
            Json::obj(fields)
        }
        // A concurrent load registered this name first: swap into that
        // slot so the contract check applies and neither deploy is
        // silently dropped.
        Ok(None) => handle_swap(msg, ctx, metrics),
        Err(e) => {
            metrics.swap_failures.fetch_add(1, Ordering::Relaxed);
            err_json(format!("{e:#}"))
        }
    }
}

/// `{"op":"unload","model":...}`: drop a model from the registry. The
/// pinned default cannot be unloaded; in-flight batches on the dropped
/// slot finish undisturbed (they hold the `Arc`).
fn handle_unload(msg: &Json, ctx: &ConnCtx) -> Json {
    let Some(store) = &ctx.store else {
        return err_json("unload unavailable: server runs factory-backed workers".into());
    };
    let Some(name) = msg.get("model").and_then(Json::as_str) else {
        return err_json("unload requires a \"model\" name".into());
    };
    match store.unload(name) {
        Ok(()) => {
            persist_manifest(ctx, "unload");
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(name.into())),
            ])
        }
        Err(e) => err_json(format!("{e:#}")),
    }
}

/// `{"op":"rollback","model":...}`: restore the named (or default)
/// slot's previous retained generation under live traffic — the same
/// zero-downtime path as swap, in reverse. In-flight batches finish on
/// the generation they snapshotted; queued requests ride the restored
/// one. Fails (without touching the slot) when nothing is retained.
fn handle_rollback(msg: &Json, ctx: &ConnCtx, metrics: &Metrics) -> Json {
    let Some(store) = &ctx.store else {
        return err_json("rollback unavailable: server runs factory-backed workers".into());
    };
    let name = match requested_model(msg, ctx) {
        Ok(n) => n,
        Err(e) => return err_json(e),
    };
    let Some(slot) = store.get(name) else {
        return err_json(format!("unknown model \"{name}\""));
    };
    match slot.rollback("operator rollback") {
        Ok(vm) => {
            metrics.count_rollback(name);
            persist_manifest(ctx, "rollback");
            metrics
                .recorder
                .record(EventKind::Rollback, name, 0, 0, &format!("v{}", vm.version));
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(name.into())),
                ("version", Json::Num(vm.version as f64)),
            ];
            if let Some(p) = vm.precision() {
                fields.push(("precision", Json::Str(p.name().into())));
            }
            if let Some(v) = vm.kernel_variant() {
                fields.push(("kernel_variant", Json::Str(v.name().into())));
            }
            Json::obj(fields)
        }
        Err(e) => err_json(format!("{e:#}")),
    }
}

/// `{"op":"models"}`: every resident slot with
/// version/precision/geometry plus the active dispatch kernel variant.
fn models_json(ctx: &ConnCtx) -> Json {
    let Some(store) = &ctx.store else {
        return err_json("model registry unavailable: server runs factory-backed workers".into());
    };
    let default = ctx.default_model.clone().unwrap_or_default();
    let mut models = Vec::new();
    for name in store.names() {
        let Some(slot) = store.get(&name) else { continue };
        let vm = slot.current();
        let mut fields = vec![
            ("version", Json::Num(vm.version as f64)),
            ("source", Json::Str(vm.source.clone())),
            ("inputs", Json::Num(vm.model.inputs as f64)),
            ("hidden", Json::Num(vm.model.hidden as f64)),
            ("outputs", Json::Num(vm.model.outputs as f64)),
            ("max_batch", Json::Num(vm.model.max_batch as f64)),
            ("default", Json::Bool(name == default)),
            ("state", Json::Str(slot.state_name().into())),
            ("retained_versions", Json::Num(slot.retained() as f64)),
        ];
        if let Some(p) = vm.precision() {
            fields.push(("precision", Json::Str(p.name().into())));
        }
        if let Some(v) = vm.kernel_variant() {
            fields.push(("kernel_variant", Json::Str(v.name().into())));
        }
        if let Some(r) = slot.last_rollback() {
            fields.push(("last_rollback", Json::Str(r)));
        }
        models.push((name, Json::obj(fields)));
    }
    Json::obj(vec![
        ("default", Json::Str(default)),
        ("max_models", Json::Num(store.max_models() as f64)),
        ("models", Json::Obj(models.into_iter().collect())),
    ])
}

/// The per-stage latency breakdown (`stats.stages`): sample count and
/// p50/p95/p99/mean (ms) per pipeline stage; stages with no samples
/// yet are omitted.
fn stages_json(stages: &StageSet) -> Json {
    let mut fields = Vec::new();
    for stage in Stage::ALL {
        if let Some(s) = stages.summary(stage) {
            fields.push((
                stage.name(),
                Json::obj(vec![
                    ("n", Json::Num(s.n as f64)),
                    ("p50_ms", Json::Num(s.p50 * 1e3)),
                    ("p95_ms", Json::Num(s.p95 * 1e3)),
                    ("p99_ms", Json::Num(s.p99 * 1e3)),
                    ("mean_ms", Json::Num(s.mean * 1e3)),
                ]),
            ));
        }
    }
    Json::obj(fields)
}

/// `{"op":"metrics"}`: the whole metrics surface in Prometheus text
/// exposition format 0.0.4 — counters (global series plus one
/// `{model="..."}` series per touched model), gauges, and
/// quantile-labelled summaries for request latency, per-stage latency,
/// and batch occupancy. Emitted by hand: the format is line-oriented
/// text and the crate takes no dependencies.
fn prometheus_text(metrics: &Metrics, batcher: &Batcher, ctx: &ConnCtx) -> String {
    use std::fmt::Write as _;

    fn esc(v: &str) -> String {
        v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
    }

    fn labels(pairs: &[(&str, &str)]) -> String {
        if pairs.is_empty() {
            return String::new();
        }
        let body: Vec<String> = pairs
            .iter()
            .map(|(k, v)| format!("{k}=\"{}\"", esc(v)))
            .collect();
        format!("{{{}}}", body.join(","))
    }

    /// One summary-typed series: quantile samples + `_sum`/`_count`.
    /// The sum is reconstructed as `mean * n` (the histogram keeps the
    /// exact sum, but only the summary crosses this interface).
    fn summary_lines(out: &mut String, name: &str, base: &[(&str, &str)], s: &Summary) {
        for (q, v) in [("0.5", s.p50), ("0.95", s.p95), ("0.99", s.p99)] {
            let mut pairs = base.to_vec();
            pairs.push(("quantile", q));
            let _ = writeln!(out, "{name}{} {v}", labels(&pairs));
        }
        let _ = writeln!(out, "{name}_sum{} {}", labels(base), s.mean * s.n as f64);
        let _ = writeln!(out, "{name}_count{} {}", labels(base), s.n);
    }

    let (queue_depth, queue_depths) = batcher.queue_depths();
    let models = metrics.model_snapshot();
    let mut out = String::new();

    type PerModel = fn(&ModelMetrics) -> &AtomicU64;
    let counters: [(&str, &str, u64, Option<PerModel>); 13] = [
        (
            "gs_requests_total",
            "Inference requests admitted.",
            metrics.requests.load(Ordering::Relaxed),
            Some(|m| &m.requests),
        ),
        (
            "gs_responses_total",
            "Successful inference replies.",
            metrics.responses.load(Ordering::Relaxed),
            Some(|m| &m.responses),
        ),
        (
            "gs_errors_total",
            "Requests failed with an error reply.",
            metrics.errors.load(Ordering::Relaxed),
            Some(|m| &m.errors),
        ),
        (
            "gs_shed_total",
            "Requests shed by bounded admission.",
            metrics.shed.load(Ordering::Relaxed),
            Some(|m| &m.shed),
        ),
        (
            "gs_expired_total",
            "Requests failed on their queue-wait deadline.",
            metrics.expired.load(Ordering::Relaxed),
            Some(|m| &m.expired),
        ),
        (
            "gs_panics_total",
            "Batch executions that panicked (caught).",
            metrics.panics.load(Ordering::Relaxed),
            None,
        ),
        (
            "gs_swaps_total",
            "Successful model hot swaps.",
            metrics.swaps.load(Ordering::Relaxed),
            Some(|m| &m.swaps),
        ),
        (
            "gs_swap_failures_total",
            "Rejected or failed swap attempts.",
            metrics.swap_failures.load(Ordering::Relaxed),
            Some(|m| &m.swap_failures),
        ),
        (
            "gs_evictions_total",
            "Models LRU-evicted from the store.",
            metrics.evictions.load(Ordering::Relaxed),
            None,
        ),
        (
            "gs_rollbacks_total",
            "Slot rollbacks (manual and canary).",
            metrics.rollbacks.load(Ordering::Relaxed),
            Some(|m| &m.rollbacks),
        ),
        (
            "gs_quarantined_total",
            "Requests fast-failed under quarantine.",
            metrics.quarantined.load(Ordering::Relaxed),
            Some(|m| &m.quarantined),
        ),
        (
            "gs_batches_total",
            "Batches formed.",
            metrics.batches.load(Ordering::Relaxed),
            None,
        ),
        (
            "gs_batched_rows_total",
            "Requests carried by formed batches.",
            metrics.batched_rows.load(Ordering::Relaxed),
            None,
        ),
    ];
    for (name, help, global, per) in counters {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {global}");
        if let Some(f) = per {
            for (model, m) in &models {
                let _ = writeln!(
                    out,
                    "{name}{} {}",
                    labels(&[("model", model)]),
                    f(m).load(Ordering::Relaxed)
                );
            }
        }
    }

    let _ = writeln!(out, "# HELP gs_queue_depth Requests waiting in the batcher.");
    let _ = writeln!(out, "# TYPE gs_queue_depth gauge");
    let _ = writeln!(out, "gs_queue_depth {queue_depth}");
    for (model, depth) in &queue_depths {
        let _ = writeln!(out, "gs_queue_depth{} {depth}", labels(&[("model", model)]));
    }
    let _ = writeln!(out, "# HELP gs_connections Open client connections.");
    let _ = writeln!(out, "# TYPE gs_connections gauge");
    let _ = writeln!(
        out,
        "gs_connections {}",
        ctx.conns.live.load(Ordering::SeqCst)
    );
    let _ = writeln!(
        out,
        "# HELP gs_frames_total Complete request frames decoded, by framing."
    );
    let _ = writeln!(out, "# TYPE gs_frames_total counter");
    let _ = writeln!(
        out,
        "gs_frames_total{} {}",
        labels(&[("framing", "json")]),
        metrics.frames_json.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        out,
        "gs_frames_total{} {}",
        labels(&[("framing", "binary")]),
        metrics.frames_binary.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        out,
        "# HELP gs_binary_negotiations_total Connections that negotiated binary framing \
         (HELLO handshakes granted)."
    );
    let _ = writeln!(out, "# TYPE gs_binary_negotiations_total counter");
    let _ = writeln!(
        out,
        "gs_binary_negotiations_total {}",
        metrics.binary_negotiations.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        out,
        "# HELP gs_binary_connections Open connections currently speaking binary framing."
    );
    let _ = writeln!(out, "# TYPE gs_binary_connections gauge");
    let _ = writeln!(
        out,
        "gs_binary_connections {}",
        metrics.binary_connections.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        out,
        "# HELP gs_inflight_requests Requests accepted off a socket whose reply has not \
         yet been written back."
    );
    let _ = writeln!(out, "# TYPE gs_inflight_requests gauge");
    let _ = writeln!(
        out,
        "gs_inflight_requests {}",
        metrics.inflight.load(Ordering::Relaxed)
    );
    let _ = writeln!(out, "# HELP gs_uptime_seconds Seconds since server start.");
    let _ = writeln!(out, "# TYPE gs_uptime_seconds gauge");
    let _ = writeln!(out, "gs_uptime_seconds {}", metrics.uptime_ms() as f64 / 1e3);

    // Info-style series: the dispatch kernel variant each resident model
    // is serving on. The value is always 1; the payload is the labels.
    if let Some(store) = &ctx.store {
        let mut active = Vec::new();
        for name in store.names() {
            let Some(slot) = store.get(&name) else { continue };
            if let Some(v) = slot.current().kernel_variant() {
                active.push((name, v.name()));
            }
        }
        if !active.is_empty() {
            let _ = writeln!(
                out,
                "# HELP gs_kernel_variant Active dispatch kernel variant per resident model \
                 (info-style gauge: value is always 1)."
            );
            let _ = writeln!(out, "# TYPE gs_kernel_variant gauge");
            for (name, variant) in &active {
                let _ = writeln!(
                    out,
                    "gs_kernel_variant{} 1",
                    labels(&[("model", name.as_str()), ("variant", variant)])
                );
            }
        }
    }

    let _ = writeln!(
        out,
        "# HELP gs_request_latency_seconds End-to-end request latency (enqueue to result)."
    );
    let _ = writeln!(out, "# TYPE gs_request_latency_seconds summary");
    if let Some(s) = metrics.latency_summary() {
        summary_lines(&mut out, "gs_request_latency_seconds", &[], &s);
    }
    for (model, m) in &models {
        if let Some(s) = m.latency_summary() {
            summary_lines(
                &mut out,
                "gs_request_latency_seconds",
                &[("model", model)],
                &s,
            );
        }
    }

    let _ = writeln!(
        out,
        "# HELP gs_stage_seconds Request latency attributed to one pipeline stage."
    );
    let _ = writeln!(out, "# TYPE gs_stage_seconds summary");
    for stage in Stage::ALL {
        if let Some(s) = metrics.stages.summary(stage) {
            summary_lines(&mut out, "gs_stage_seconds", &[("stage", stage.name())], &s);
        }
    }
    for (model, m) in &models {
        for stage in Stage::ALL {
            if let Some(s) = m.stages.summary(stage) {
                summary_lines(
                    &mut out,
                    "gs_stage_seconds",
                    &[("model", model), ("stage", stage.name())],
                    &s,
                );
            }
        }
    }

    let _ = writeln!(out, "# HELP gs_batch_occupancy Rows per formed batch.");
    let _ = writeln!(out, "# TYPE gs_batch_occupancy summary");
    if let Some(s) = metrics.batch_occupancy.summary() {
        summary_lines(&mut out, "gs_batch_occupancy", &[], &s);
    }
    out
}

fn stats_json(metrics: &Metrics, batcher: &Batcher, ctx: &ConnCtx) -> Json {
    // One lock hold: the global and per-model queue depths in a single
    // stats reply are mutually consistent.
    let (queue_depth, queue_depths) = batcher.queue_depths();
    let mut fields = vec![
        (
            "requests",
            Json::Num(metrics.requests.load(Ordering::Relaxed) as f64),
        ),
        (
            "responses",
            Json::Num(metrics.responses.load(Ordering::Relaxed) as f64),
        ),
        (
            "batches",
            Json::Num(metrics.batches.load(Ordering::Relaxed) as f64),
        ),
        ("mean_batch", Json::Num(metrics.mean_batch_size())),
        (
            "errors",
            Json::Num(metrics.errors.load(Ordering::Relaxed) as f64),
        ),
        (
            "shed",
            Json::Num(metrics.shed.load(Ordering::Relaxed) as f64),
        ),
        (
            "expired",
            Json::Num(metrics.expired.load(Ordering::Relaxed) as f64),
        ),
        (
            "panics",
            Json::Num(metrics.panics.load(Ordering::Relaxed) as f64),
        ),
        ("queue_depth", Json::Num(queue_depth as f64)),
        (
            "connections",
            Json::Num(ctx.conns.live.load(Ordering::SeqCst) as f64),
        ),
        (
            "inflight",
            Json::Num(metrics.inflight.load(Ordering::Relaxed) as f64),
        ),
        (
            "binary_connections",
            Json::Num(metrics.binary_connections.load(Ordering::Relaxed) as f64),
        ),
        (
            "frames_json",
            Json::Num(metrics.frames_json.load(Ordering::Relaxed) as f64),
        ),
        (
            "frames_binary",
            Json::Num(metrics.frames_binary.load(Ordering::Relaxed) as f64),
        ),
        (
            "swaps",
            Json::Num(metrics.swaps.load(Ordering::Relaxed) as f64),
        ),
        (
            "swap_failures",
            Json::Num(metrics.swap_failures.load(Ordering::Relaxed) as f64),
        ),
        (
            "evictions",
            Json::Num(metrics.evictions.load(Ordering::Relaxed) as f64),
        ),
        (
            "rollbacks",
            Json::Num(metrics.rollbacks.load(Ordering::Relaxed) as f64),
        ),
        (
            "quarantined",
            Json::Num(metrics.quarantined.load(Ordering::Relaxed) as f64),
        ),
        ("uptime_ms", Json::Num(metrics.uptime_ms() as f64)),
    ];
    if let Some(slot) = default_slot(ctx) {
        let vm = slot.current();
        fields.push(("model_version", Json::Num(vm.version as f64)));
        if let Some(p) = vm.precision() {
            fields.push(("precision", Json::Str(p.name().into())));
        }
        if let Some(v) = vm.kernel_variant() {
            fields.push(("kernel_variant", Json::Str(v.name().into())));
        }
    }
    if let Some(s) = metrics.latency_summary() {
        fields.push(("p50_ms", Json::Num(s.p50 * 1e3)));
        fields.push(("p95_ms", Json::Num(s.p95 * 1e3)));
        fields.push(("mean_ms", Json::Num(s.mean * 1e3)));
    }
    fields.push(("stages", stages_json(&metrics.stages)));
    if let Some(s) = metrics.batch_occupancy.summary() {
        fields.push((
            "batch_occupancy",
            Json::obj(vec![
                ("n", Json::Num(s.n as f64)),
                ("p50", Json::Num(s.p50)),
                ("p95", Json::Num(s.p95)),
                ("min", Json::Num(s.min)),
                ("max", Json::Num(s.max)),
                ("mean", Json::Num(s.mean)),
            ]),
        ));
    }
    // Per-slot breakdown: every resident model plus every model that
    // ever took traffic (counters are history — an eviction or unload
    // must not erase a model's request/latency record from `stats`).
    // Reads go through the snapshot, never `metrics.model()` — a stats
    // poll must not mint permanent entries for untouched models. The
    // top-level keys above keep their historical global meaning.
    if let Some(store) = &ctx.store {
        let history: std::collections::BTreeMap<String, Arc<ModelMetrics>> =
            metrics.model_snapshot().into_iter().collect();
        let mut names = store.names();
        for name in history.keys() {
            if !names.contains(name) {
                names.push(name.clone());
            }
        }
        names.sort();
        let mut models = Vec::new();
        for name in names {
            let mm = history.get(&name);
            let counter = |f: fn(&ModelMetrics) -> &std::sync::atomic::AtomicU64| {
                mm.map_or(0.0, |m| f(m).load(Ordering::Relaxed) as f64)
            };
            let mut mf = vec![
                ("requests", Json::Num(counter(|m| &m.requests))),
                ("responses", Json::Num(counter(|m| &m.responses))),
                ("errors", Json::Num(counter(|m| &m.errors))),
                ("shed", Json::Num(counter(|m| &m.shed))),
                ("expired", Json::Num(counter(|m| &m.expired))),
                (
                    "queue_depth",
                    Json::Num(queue_depths.get(&name).copied().unwrap_or(0) as f64),
                ),
                ("swaps", Json::Num(counter(|m| &m.swaps))),
                ("swap_failures", Json::Num(counter(|m| &m.swap_failures))),
                ("rollbacks", Json::Num(counter(|m| &m.rollbacks))),
                ("quarantined", Json::Num(counter(|m| &m.quarantined))),
            ];
            match store.get(&name) {
                Some(slot) => {
                    let vm = slot.current();
                    mf.push(("resident", Json::Bool(true)));
                    mf.push(("version", Json::Num(vm.version as f64)));
                    mf.push(("state", Json::Str(slot.state_name().into())));
                    mf.push(("retained_versions", Json::Num(slot.retained() as f64)));
                    if let Some(p) = vm.precision() {
                        mf.push(("precision", Json::Str(p.name().into())));
                    }
                    if let Some(v) = vm.kernel_variant() {
                        mf.push(("kernel_variant", Json::Str(v.name().into())));
                    }
                }
                None => mf.push(("resident", Json::Bool(false))),
            }
            if let Some(m) = mm {
                if let Some(idle) = m.idle_secs() {
                    mf.push(("last_used_s", Json::Num(idle)));
                }
                if let Some(s) = m.latency_summary() {
                    mf.push(("p50_ms", Json::Num(s.p50 * 1e3)));
                    mf.push(("p95_ms", Json::Num(s.p95 * 1e3)));
                    mf.push(("mean_ms", Json::Num(s.mean * 1e3)));
                }
                mf.push(("stages", stages_json(&m.stages)));
            }
            models.push((name, Json::obj(mf)));
        }
        fields.push(("models", Json::Obj(models.into_iter().collect())));
    }
    Json::obj(fields)
}

/// Map a timed-out client read/write to a clear error (the raw io error
/// kind differs by platform: `WouldBlock` on unix, `TimedOut` on
/// windows). Shared by [`Client`] and [`PipelinedClient`].
fn io_ctx<T>(r: std::io::Result<T>) -> Result<T> {
    r.map_err(|e| match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => anyhow::anyhow!(
            "server timed out: no reply within the configured timeout \
             (server wedged or overloaded)"
        ),
        _ => e.into(),
    })
}

/// Outcome of a single infer attempt where an overload shed is an
/// expected, retryable state rather than a hard failure (see
/// [`Client::try_infer`]).
#[derive(Clone, Debug, PartialEq)]
pub enum InferOutcome {
    Output(Vec<f32>),
    /// The server shed this request under overload; back off for the
    /// hinted milliseconds and retry.
    Overloaded { retry_after_ms: u64 },
    /// The request outwaited its deadline in the server queue and was
    /// failed at batch formation — it never executed.
    Expired { waited_ms: u64 },
}

/// Blocking JSON-lines client (tests, examples, bench harness).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        Self::from_stream(TcpStream::connect(addr)?)
    }

    /// Connect with a bound on how long to wait for the server to
    /// accept — an unreachable or wedged server fails fast instead of
    /// hanging the caller on the OS connect timeout.
    pub fn connect_timeout(addr: std::net::SocketAddr, timeout: Duration) -> Result<Client> {
        Self::from_stream(TcpStream::connect_timeout(&addr, timeout)?)
    }

    fn from_stream(stream: TcpStream) -> Result<Client> {
        let _ = stream.set_nodelay(true);
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    /// Bound every subsequent read and write on this connection
    /// (`None` clears the bound). With a timeout set, a wedged server
    /// surfaces as a clear "server timed out" error instead of hanging
    /// the calling thread forever.
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(timeout)?;
        self.writer.set_write_timeout(timeout)?;
        Ok(())
    }

    fn roundtrip(&mut self, msg: Json) -> Result<Json> {
        io_ctx(self.writer.write_all(msg.to_string().as_bytes()))?;
        io_ctx(self.writer.write_all(b"\n"))?;
        let mut line = String::new();
        // 0 bytes = orderly EOF: surface it as what it is instead of
        // feeding the empty string to the JSON parser (which used to
        // produce a baffling "bad json" error).
        if io_ctx(self.reader.read_line(&mut line))? == 0 {
            anyhow::bail!("connection closed by server");
        }
        Ok(Json::parse(&line)?)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.roundtrip(Json::obj(vec![("op", "ping".into())]))?;
        Ok(r.get("ok").and_then(Json::as_bool).unwrap_or(false))
    }

    /// One infer attempt with overload and deadline expiry surfaced
    /// structurally: a shed reply (`retry_after_ms` present) returns
    /// [`InferOutcome::Overloaded`] and an expired reply (`waited_ms`
    /// present) returns [`InferOutcome::Expired`] instead of an error,
    /// so callers implementing back-pressure need not parse error
    /// strings. Hard failures (bad input, unknown model, transport)
    /// still `Err`.
    pub fn try_infer(&mut self, model: Option<&str>, input: &[f32]) -> Result<InferOutcome> {
        self.try_infer_deadline(model, input, None)
    }

    /// [`Client::try_infer`] with a queue-wait budget: the server fails
    /// the request with a structured expiry instead of executing it
    /// once it has queued longer than `deadline_ms`. `Some(0)`
    /// explicitly opts out of the server's default deadline.
    pub fn try_infer_deadline(
        &mut self,
        model: Option<&str>,
        input: &[f32],
        deadline_ms: Option<u64>,
    ) -> Result<InferOutcome> {
        let id = self.next_id;
        self.next_id += 1;
        let mut fields = vec![
            ("op", "infer".into()),
            ("id", Json::Num(id as f64)),
            ("input", Json::nums_f32(input)),
        ];
        if let Some(model) = model {
            fields.push(("model", Json::Str(model.into())));
        }
        if let Some(ms) = deadline_ms {
            fields.push(("deadline_ms", Json::Num(ms as f64)));
        }
        let r = self.roundtrip(Json::obj(fields))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            if let Some(ms) = r.get("retry_after_ms").and_then(Json::as_f64) {
                return Ok(InferOutcome::Overloaded { retry_after_ms: ms as u64 });
            }
            if let Some(ms) = r.get("waited_ms").and_then(Json::as_f64) {
                return Ok(InferOutcome::Expired { waited_ms: ms as u64 });
            }
            anyhow::bail!("server error: {err}");
        }
        r.get("output")
            .and_then(Json::to_f32_vec)
            .map(InferOutcome::Output)
            .ok_or_else(|| anyhow::anyhow!("malformed response"))
    }

    fn infer_inner(&mut self, model: Option<&str>, input: &[f32]) -> Result<Vec<f32>> {
        match self.try_infer(model, input)? {
            InferOutcome::Output(out) => Ok(out),
            // For the plain-infer API an overload shed is still an
            // error, with the hint in the message.
            InferOutcome::Overloaded { retry_after_ms } => anyhow::bail!(
                "server overloaded (retry after {retry_after_ms} ms): request shed, \
                 back off and retry"
            ),
            InferOutcome::Expired { waited_ms } => anyhow::bail!(
                "deadline exceeded: request expired after {waited_ms} ms in the server \
                 queue without executing"
            ),
        }
    }

    /// Infer on the server's default model.
    pub fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        self.infer_inner(None, input)
    }

    /// Infer on a named model.
    pub fn infer_model(&mut self, model: &str, input: &[f32]) -> Result<Vec<f32>> {
        self.infer_inner(Some(model), input)
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.roundtrip(Json::obj(vec![("op", "stats".into())]))
    }

    /// The flight recorder's retained lifecycle events
    /// (`{"op":"trace"}`). `filter` entries are passed through as
    /// protocol fields, e.g. `&[("model", Json::Str("m".into())),
    /// ("limit", Json::Num(50.0))]`; empty = everything retained.
    pub fn trace(&mut self, filter: &[(&str, Json)]) -> Result<Json> {
        let mut fields = vec![("op", Json::Str("trace".into()))];
        fields.extend(filter.iter().map(|(k, v)| (*k, v.clone())));
        let r = self.roundtrip(Json::obj(fields))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("trace failed: {err}");
        }
        Ok(r)
    }

    /// The Prometheus text exposition (`{"op":"metrics"}`), unwrapped
    /// from its JSON envelope.
    pub fn metrics_text(&mut self) -> Result<String> {
        let r = self.roundtrip(Json::obj(vec![("op", "metrics".into())]))?;
        r.get("text")
            .and_then(Json::as_str)
            .map(String::from)
            .ok_or_else(|| anyhow::anyhow!("malformed metrics response"))
    }

    /// Kernel chunk load-imbalance profiles (`{"op":"profile"}`).
    pub fn profile(&mut self) -> Result<Json> {
        let r = self.roundtrip(Json::obj(vec![("op", "profile".into())]))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("profile failed: {err}");
        }
        Ok(r)
    }

    /// The model registry listing (`{"op":"models"}`).
    pub fn models(&mut self) -> Result<Json> {
        let r = self.roundtrip(Json::obj(vec![("op", "models".into())]))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("models failed: {err}");
        }
        Ok(r)
    }

    fn deploy(&mut self, op: &str, model: Option<&str>, path: &str) -> Result<Json> {
        let mut fields = vec![("op", Json::Str(op.into())), ("path", Json::Str(path.into()))];
        if let Some(model) = model {
            fields.push(("model", Json::Str(model.into())));
        }
        let r = self.roundtrip(Json::obj(fields))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("{op} failed: {err}");
        }
        Ok(r)
    }

    fn version_of(r: &Json, op: &str) -> Result<u64> {
        r.get("version")
            .and_then(Json::as_f64)
            .map(|v| v as u64)
            .ok_or_else(|| anyhow::anyhow!("malformed {op} response"))
    }

    /// Hot-swap the default model to the artifact at `path`; returns the
    /// new deployment version.
    pub fn swap(&mut self, path: &str) -> Result<u64> {
        let r = self.deploy("swap", None, path)?;
        Self::version_of(&r, "swap")
    }

    /// Hot-swap a named model's slot; returns the new version.
    pub fn swap_model(&mut self, model: &str, path: &str) -> Result<u64> {
        let r = self.deploy("swap", Some(model), path)?;
        Self::version_of(&r, "swap")
    }

    /// Canary-swap a named model: install the artifact at `path` under a
    /// watch over its first `requests` requests, auto-rolling back if
    /// more than `max_error_rate` of them fail. Returns the canary's
    /// version (the server reply also carries `"state":"canary"`).
    pub fn swap_canary(
        &mut self,
        model: &str,
        path: &str,
        requests: u64,
        max_error_rate: f64,
    ) -> Result<u64> {
        let r = self.roundtrip(Json::obj(vec![
            ("op", "swap".into()),
            ("model", Json::Str(model.into())),
            ("path", Json::Str(path.into())),
            (
                "canary",
                Json::obj(vec![
                    ("requests", Json::Num(requests as f64)),
                    ("max_error_rate", Json::Num(max_error_rate)),
                ]),
            ),
        ]))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("swap failed: {err}");
        }
        Self::version_of(&r, "swap")
    }

    /// Roll the named (or default) model back to its retained previous
    /// generation; returns the restored version.
    pub fn rollback(&mut self, model: Option<&str>) -> Result<u64> {
        let mut fields = vec![("op", Json::Str("rollback".into()))];
        if let Some(model) = model {
            fields.push(("model", Json::Str(model.into())));
        }
        let r = self.roundtrip(Json::obj(fields))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("rollback failed: {err}");
        }
        Self::version_of(&r, "rollback")
    }

    /// Make `model` resident from the artifact at `path`; returns the
    /// deployed version (1 for a fresh slot) and any evicted model names.
    pub fn load(&mut self, model: &str, path: &str) -> Result<(u64, Vec<String>)> {
        let r = self.deploy("load", Some(model), path)?;
        let evicted = r
            .get("evicted")
            .and_then(Json::as_arr)
            .map(|xs| {
                xs.iter()
                    .filter_map(|j| j.as_str().map(String::from))
                    .collect()
            })
            .unwrap_or_default();
        Ok((Self::version_of(&r, "load")?, evicted))
    }

    /// Drop `model` from the registry (the pinned default is refused).
    pub fn unload(&mut self, model: &str) -> Result<()> {
        let r = self.roundtrip(Json::obj(vec![
            ("op", "unload".into()),
            ("model", Json::Str(model.into())),
        ]))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("unload failed: {err}");
        }
        Ok(())
    }
}

/// One reply from a [`PipelinedClient`], tagged with the id of the
/// request it answers — replies arrive in the server's batch-completion
/// order, not submission order.
#[derive(Debug)]
pub struct PipelinedReply {
    pub id: u64,
    /// The infer outcome, or the transport/server failure that ended
    /// this request (a request stranded in flight by a dead connection
    /// fails here, structurally — it never hangs).
    pub outcome: Result<InferOutcome, String>,
}

/// Why reading one reply stopped.
enum RecvError {
    /// The server closed the connection (orderly EOF, or EOF mid-frame).
    Eof,
    /// A transport or protocol failure worth surfacing as-is.
    Other(anyhow::Error),
}

fn map_recv_io(e: std::io::Error) -> RecvError {
    match e.kind() {
        // A reset or aborted connection is a dead server the same as a
        // clean EOF: fail the in-flight ids structurally, don't bubble
        // a bare io error that strands them.
        ErrorKind::UnexpectedEof
        | ErrorKind::ConnectionReset
        | ErrorKind::ConnectionAborted
        | ErrorKind::BrokenPipe => RecvError::Eof,
        ErrorKind::WouldBlock | ErrorKind::TimedOut => RecvError::Other(anyhow::anyhow!(
            "server timed out: no reply within the configured timeout \
             (server wedged or overloaded)"
        )),
        _ => RecvError::Other(e.into()),
    }
}

/// Shape a JSON error reply into an [`InferOutcome`] (shed and expiry
/// are expected states) or the server's error text. Exactly the
/// [`Client::try_infer`] mapping.
fn json_error_outcome(r: &Json) -> Result<InferOutcome, String> {
    let err = r
        .get("error")
        .and_then(Json::as_str)
        .unwrap_or("malformed response");
    if let Some(ms) = r.get("retry_after_ms").and_then(Json::as_f64) {
        return Ok(InferOutcome::Overloaded { retry_after_ms: ms as u64 });
    }
    if let Some(ms) = r.get("waited_ms").and_then(Json::as_f64) {
        return Ok(InferOutcome::Expired { waited_ms: ms as u64 });
    }
    Err(format!("server error: {err}"))
}

/// Shape one JSON infer reply (success or error) into an outcome.
fn json_reply_outcome(r: &Json) -> Result<InferOutcome, String> {
    if r.get("error").and_then(Json::as_str).is_some() {
        return json_error_outcome(r);
    }
    match r.get("output").and_then(Json::to_f32_vec) {
        Some(out) => Ok(InferOutcome::Output(out)),
        None => Err("malformed response".into()),
    }
}

/// Shape one binary reply frame into an outcome. OUTPUT carries raw
/// little-endian f32 logits; ERROR carries the same JSON object the
/// JSON framing would have sent, so reject semantics are identical.
fn decode_binary_reply(
    header: &wire::FrameHeader,
    payload: &[u8],
) -> Result<Result<InferOutcome, String>> {
    match header.opcode {
        Opcode::Output => match wire::le_f32s(payload) {
            Ok(out) => Ok(Ok(InferOutcome::Output(out))),
            Err(e) => anyhow::bail!("malformed OUTPUT payload: {e}"),
        },
        Opcode::Error => {
            let text = String::from_utf8_lossy(payload).into_owned();
            let r = Json::parse(&text)?;
            Ok(json_error_outcome(&r))
        }
        other => anyhow::bail!("unexpected {other:?} reply frame"),
    }
}

/// Blocking client with pipelined infers: many requests in flight on
/// one connection, replies matched to requests by id in whatever order
/// the server's batches complete. On connect it offers the binary wire
/// framing of [`super::wire`] (HELLO) and falls back to JSON lines
/// transparently when the server declines or predates it — the
/// submit/recv API is identical either way.
pub struct PipelinedClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    binary: bool,
    next_id: u64,
    /// Ids submitted and not yet answered, oldest first.
    inflight: VecDeque<u64>,
    /// Infer replies that arrived while waiting for a control reply.
    queued: VecDeque<PipelinedReply>,
    /// The server closed the connection; in-flight ids fail one by one
    /// through [`PipelinedClient::recv`].
    closed: bool,
}

impl PipelinedClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<PipelinedClient> {
        Self::from_stream(TcpStream::connect(addr)?)
    }

    /// Connect with a bound on how long to wait for the server to
    /// accept (the framing handshake itself then runs unbounded).
    pub fn connect_timeout(
        addr: std::net::SocketAddr,
        timeout: Duration,
    ) -> Result<PipelinedClient> {
        Self::from_stream(TcpStream::connect_timeout(&addr, timeout)?)
    }

    fn from_stream(stream: TcpStream) -> Result<PipelinedClient> {
        let _ = stream.set_nodelay(true);
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        io_ctx(writer.write_all(&wire::hello_frame()))?;
        // The first reply byte decides the framing. A binary HELLO_ACK
        // grants it. Any JSON line — an old server's "bad json"
        // complaint about the HELLO bytes, or a binary-disabled
        // server's structured error — is the fall-back-to-JSON signal
        // (the HELLO frame's trailing newline makes it read as exactly
        // one garbage line to a JSON-only server).
        let first = {
            let buf = io_ctx(reader.fill_buf())?;
            match buf.first() {
                Some(&b) => b,
                None => anyhow::bail!("connection closed by server"),
            }
        };
        let binary = if first == wire::MAGIC {
            let mut header = [0u8; wire::HEADER_LEN];
            io_ctx(reader.read_exact(&mut header))?;
            let header = wire::FrameHeader::parse(&header)
                .map_err(|e| anyhow::anyhow!("handshake failed: {e}"))?;
            let mut payload = vec![0u8; header.len as usize];
            io_ctx(reader.read_exact(&mut payload))?;
            if header.opcode != Opcode::HelloAck {
                anyhow::bail!(
                    "handshake failed: expected HELLO_ACK, got {:?}",
                    header.opcode
                );
            }
            if header.version != wire::VERSION {
                anyhow::bail!(
                    "handshake failed: server speaks wire version {}, this client speaks {}",
                    header.version,
                    wire::VERSION
                );
            }
            true
        } else {
            let mut line = String::new();
            if io_ctx(reader.read_line(&mut line))? == 0 {
                anyhow::bail!("connection closed by server");
            }
            false
        };
        Ok(PipelinedClient {
            reader,
            writer,
            binary,
            next_id: 1,
            inflight: VecDeque::new(),
            queued: VecDeque::new(),
            closed: false,
        })
    }

    /// Whether the connection negotiated binary framing (false = JSON
    /// fallback, same API).
    pub fn is_binary(&self) -> bool {
        self.binary
    }

    /// Ids submitted and not yet answered.
    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    /// Bound every subsequent read and write on this connection
    /// (`None` clears the bound). A timed-out [`PipelinedClient::recv`]
    /// errors without failing in-flight ids — they stay receivable.
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(timeout)?;
        self.writer.set_write_timeout(timeout)?;
        Ok(())
    }

    /// Submit one infer without waiting for its reply; returns the id
    /// that [`PipelinedClient::recv`] will eventually answer.
    pub fn submit(
        &mut self,
        model: Option<&str>,
        input: &[f32],
        deadline_ms: Option<u64>,
    ) -> Result<u64> {
        if self.closed {
            anyhow::bail!("connection closed by server");
        }
        let id = self.next_id;
        self.next_id += 1;
        if self.binary {
            let payload = wire::encode_infer(model, deadline_ms, input);
            io_ctx(self.writer.write_all(&wire::frame(Opcode::Infer, id, &payload)))?;
        } else {
            let mut fields = vec![
                ("op", "infer".into()),
                ("id", Json::Num(id as f64)),
                ("input", Json::nums_f32(input)),
            ];
            if let Some(model) = model {
                fields.push(("model", Json::Str(model.into())));
            }
            if let Some(ms) = deadline_ms {
                fields.push(("deadline_ms", Json::Num(ms as f64)));
            }
            io_ctx(self.writer.write_all(Json::obj(fields).to_string().as_bytes()))?;
            io_ctx(self.writer.write_all(b"\n"))?;
        }
        self.inflight.push_back(id);
        Ok(id)
    }

    /// Receive the next reply, in server completion order. Once the
    /// server closes the connection, every id still in flight is failed
    /// with one structured reply each (a dead writer half never hangs
    /// the reader); only after those drain does `recv` itself error.
    pub fn recv(&mut self) -> Result<PipelinedReply> {
        if let Some(r) = self.queued.pop_front() {
            return Ok(r);
        }
        if self.closed {
            return self.fail_next_inflight();
        }
        match self.read_reply() {
            Ok(reply) => Ok(reply),
            Err(RecvError::Eof) => {
                self.closed = true;
                self.fail_next_inflight()
            }
            Err(RecvError::Other(e)) => Err(e),
        }
    }

    fn fail_next_inflight(&mut self) -> Result<PipelinedReply> {
        match self.inflight.pop_front() {
            Some(id) => Ok(PipelinedReply {
                id,
                outcome: Err("connection closed by server with the request in flight".into()),
            }),
            None => anyhow::bail!("connection closed by server"),
        }
    }

    /// Read one reply off the socket in whichever framing it arrives.
    fn read_reply(&mut self) -> std::result::Result<PipelinedReply, RecvError> {
        let first = {
            let buf = self.reader.fill_buf().map_err(map_recv_io)?;
            match buf.first() {
                Some(&b) => b,
                None => return Err(RecvError::Eof),
            }
        };
        let (id, outcome) = if first == wire::MAGIC {
            let mut header = [0u8; wire::HEADER_LEN];
            self.reader.read_exact(&mut header).map_err(map_recv_io)?;
            let header = wire::FrameHeader::parse(&header)
                .map_err(|e| RecvError::Other(anyhow::anyhow!("malformed reply frame: {e}")))?;
            let mut payload = vec![0u8; header.len as usize];
            self.reader.read_exact(&mut payload).map_err(map_recv_io)?;
            let outcome = decode_binary_reply(&header, &payload).map_err(RecvError::Other)?;
            (header.id, outcome)
        } else {
            let mut line = String::new();
            if self.reader.read_line(&mut line).map_err(map_recv_io)? == 0 {
                return Err(RecvError::Eof);
            }
            let r = Json::parse(&line).map_err(|e| RecvError::Other(e.into()))?;
            let id = r.get("id").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            (id, json_reply_outcome(&r))
        };
        if let Some(pos) = self.inflight.iter().position(|&x| x == id) {
            self.inflight.remove(pos);
        }
        Ok(PipelinedReply { id, outcome })
    }

    /// Run one control-plane op (always a JSON line, in both framings).
    /// In binary framing, infer replies landing while the control reply
    /// is awaited are queued for later [`PipelinedClient::recv`]; in
    /// JSON framing the two reply kinds share the line framing, so
    /// control ops require an empty pipeline.
    fn control(&mut self, msg: Json) -> Result<Json> {
        if self.closed {
            anyhow::bail!("connection closed by server");
        }
        if !self.binary && !self.inflight.is_empty() {
            anyhow::bail!(
                "control ops on a JSON-framed pipelined connection require no infers in \
                 flight (drain with recv() first)"
            );
        }
        io_ctx(self.writer.write_all(msg.to_string().as_bytes()))?;
        io_ctx(self.writer.write_all(b"\n"))?;
        loop {
            let first = {
                let buf = io_ctx(self.reader.fill_buf())?;
                match buf.first() {
                    Some(&b) => b,
                    None => {
                        self.closed = true;
                        anyhow::bail!("connection closed by server");
                    }
                }
            };
            if first == wire::MAGIC {
                match self.read_reply() {
                    Ok(r) => self.queued.push_back(r),
                    Err(RecvError::Eof) => {
                        self.closed = true;
                        anyhow::bail!("connection closed by server");
                    }
                    Err(RecvError::Other(e)) => return Err(e),
                }
                continue;
            }
            let mut line = String::new();
            if io_ctx(self.reader.read_line(&mut line))? == 0 {
                self.closed = true;
                anyhow::bail!("connection closed by server");
            }
            return Ok(Json::parse(&line)?);
        }
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.control(Json::obj(vec![("op", "stats".into())]))
    }

    /// The Prometheus text exposition, unwrapped from its envelope.
    pub fn metrics_text(&mut self) -> Result<String> {
        let r = self.control(Json::obj(vec![("op", "metrics".into())]))?;
        r.get("text")
            .and_then(Json::as_str)
            .map(String::from)
            .ok_or_else(|| anyhow::anyhow!("malformed metrics response"))
    }
}
