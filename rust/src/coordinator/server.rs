//! TCP JSON-lines front-end + worker pool.
//!
//! Protocol (one JSON object per line; `"model"` is optional everywhere
//! and defaults to the server's default slot):
//!   → `{"op":"infer","id":1,"model":"resnet","input":[...f32 x inputs],
//!      "deadline_ms":N}` (optional queue-wait budget; 0 opts out of the
//!      server default)
//!   ← `{"id":1,"output":[...f32 x outputs]}` or `{"id":1,"error":"..."}`
//!     (overload shed: `{"id":1,"error":"overloaded...","retry_after_ms":N}`;
//!      deadline expiry: `{"id":1,"error":"deadline exceeded","waited_ms":N}`)
//!   → `{"op":"stats"}`
//!   ← `{"requests":N,"shed":S,"queue_depth":D,"model_version":V,
//!      "p50_ms":...,"models":{...per-slot...}}`
//!   → `{"op":"ping"}`  ← `{"ok":true,"version":V}`
//!   → `{"op":"swap","model":"resnet","path":"model.gsm"}`
//!   ← `{"ok":true,"model":"resnet","version":V,"precision":"f32"}`
//!     (with `"canary":{"requests":N,"max_error_rate":F}` the new
//!      generation installs in canary state — watched over its first N
//!      requests and auto-rolled-back past the error budget — and the
//!      reply carries `"state":"canary"`)
//!   → `{"op":"rollback","model":"resnet"}`
//!   ← `{"ok":true,"model":"resnet","version":V}` (restores the retained
//!      previous generation under live traffic)
//!   → `{"op":"load","model":"jasper","path":"j.gsm"}`
//!   ← `{"ok":true,"model":"jasper","version":1,"evicted":[...]}`
//!   → `{"op":"unload","model":"jasper"}` ← `{"ok":true,"model":"jasper"}`
//!   → `{"op":"models"}`
//!   ← `{"default":"...","max_models":N,"models":{name:{version,state,
//!      retained_versions,geometry,...}}}`
//!   → `{"op":"trace","model":...,"event":...,"id":N,"limit":N}` (all
//!      filters optional)
//!   ← `{"ok":true,"enabled":B,"capacity":N,"dropped":K,"events":[...]}`
//!     (the flight recorder's retained lifecycle events, oldest first)
//!   → `{"op":"metrics"}`
//!   ← `{"ok":true,"content_type":"text/plain; version=0.0.4",
//!      "text":"..."}` (Prometheus text exposition of every counter,
//!      gauge, and stage-latency summary)
//!   → `{"op":"profile","reset":bool}` (reset optional)
//!   ← `{"ok":true,"profiling":B,"plans":{fingerprint:{...}}}` (kernel
//!      chunk load-imbalance summaries; see [`crate::kernels::profile`])
//!
//! Two serving modes share the batcher/worker machinery:
//!
//! * [`serve_store`] — the multi-model routed engine. Workers execute
//!   whatever slot each (model-homogeneous) batch was admitted against,
//!   through a versioned [`ModelSlot`] snapshot taken once per batch, so
//!   `swap`/`load` deploy under live traffic with zero downtime:
//!   in-flight batches finish on the version they started with (a batch
//!   never mixes versions or models), queued requests ride the next
//!   snapshot, connections never drop, and LRU eviction of a cold model
//!   never disrupts batches already admitted (they hold the slot `Arc`).
//!   [`serve_slot`] is the single-model entry to the same path.
//! * [`serve`] — each worker builds its own model through a factory
//!   closure (PJRT executables are not `Send`, so the pjrt backend
//!   cannot share one instance). No hot swap or routing: `swap`/`load`/
//!   `unload` return errors and `infer` takes no `"model"`.
//!
//! **Trust model:** the protocol is unauthenticated, and `swap`/`load`
//! let any connected client deploy a server-readable `.gsm` path — an
//! operator capability, not a public one. The default bind is loopback;
//! exposing the port beyond a trusted network requires fronting it with
//! an authenticating proxy (or using factory mode, which has no write
//! op).
//!
//! **Resilience:** the connection tier is hardened against misbehaving
//! clients — `max_conns` caps simultaneous connections (a structured
//! at-capacity reply, then close), `idle_timeout_ms` releases the
//! thread a slowloris client would pin, and `max_frame_bytes` bounds
//! the line reader so an unterminated frame cannot grow a buffer
//! without limit. Batch execution runs under `catch_unwind`: a
//! panicking kernel fails that batch's requests per-request (counted in
//! `panics` + `errors`) and the worker survives. [`ServerHandle::stop`]
//! drains connections: every connection thread is tracked and joined,
//! so no thread outlives the handle.
//!
//! **Deployment safety (store mode):** slots retain previous
//! generations for `{"op":"rollback"}` and canary swaps
//! ([`SlotConfig::retain`]); batch outcomes feed each slot's canary
//! watch and quarantine circuit breaker
//! ([`ModelSlot::observe_execution`]), with auto-rollbacks counted in
//! `rollbacks` and quarantine fast-fails in `quarantined` (+ `errors`,
//! keeping conservation exact). With [`ServeConfig::store_dir`] set,
//! every accepted load/swap/unload/rollback atomically rewrites a
//! CRC-checked manifest so a restarted server resumes the exact
//! pre-crash registry.
//!
//! **Observability:** every request drops lifecycle events into the
//! flight recorder ([`ServeConfig::trace_capacity`]; drained via
//! `{"op":"trace"}`), per-request time is attributed to pipeline stages
//! (`stats.stages`, `{"op":"metrics"}`), and requests that exceed
//! [`ServeConfig::slow_request_ms`] log their full retained trace.
//! [`ServeConfig::log_json`] switches operational logging to one JSON
//! object per line.

use super::batcher::{Batcher, InferRequest, Reject};
use super::faults;
use super::metrics::{Metrics, ModelMetrics, Stage, StageSet};
use super::trace::{EventKind, TraceEvent};
use super::{Engine, SparseModel};
use crate::kernels::profile as kernel_profile;
use crate::model_store::{
    ManifestWriter, ModelArtifact, ModelSlot, ModelStore, SlotConfig, SlotEvent,
};
use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::threadpool::resolve_threads;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Live-connection registry: backs the `connections` gauge and the
/// `max_conns` admission check, and holds the socket clones + thread
/// handles [`ServerHandle::stop`] drains.
struct ConnTracker {
    live: AtomicUsize,
    /// Connection id → socket clone. Shutting the read half on stop
    /// unblocks a parked reader while its final reply still flushes.
    socks: Mutex<HashMap<u64, TcpStream>>,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
    next_id: AtomicU64,
}

impl ConnTracker {
    fn new() -> ConnTracker {
        ConnTracker {
            live: AtomicUsize::new(0),
            socks: Mutex::new(HashMap::new()),
            handles: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(0),
        }
    }

    /// Register an accepted connection; returns its id for `release`.
    fn register(&self, conn: &TcpStream) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = conn.try_clone() {
            self.socks.lock().unwrap().insert(id, clone);
        }
        self.live.fetch_add(1, Ordering::SeqCst);
        id
    }

    fn release(&self, id: u64) {
        self.socks.lock().unwrap().remove(&id);
        self.live.fetch_sub(1, Ordering::SeqCst);
    }

    /// Track a connection thread, reaping already-finished handles so
    /// the vector stays bounded by the number of *live* connections on
    /// a long-running server.
    fn track(&self, handle: thread::JoinHandle<()>) {
        let mut handles = self.handles.lock().unwrap();
        handles.retain(|h| !h.is_finished());
        handles.push(handle);
    }

    /// Unblock every connection reader and join every connection
    /// thread. After this returns, no connection thread is running.
    fn drain(&self) {
        for sock in self.socks.lock().unwrap().values() {
            let _ = sock.shutdown(Shutdown::Read);
        }
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Drops the connection's tracker entry even if the handler panics or
/// errors out — the live gauge can never leak upward.
struct ConnGuard {
    tracker: Arc<ConnTracker>,
    id: u64,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.tracker.release(self.id);
    }
}

/// Running server state; dropping does not stop it — call `stop()`.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
    pub metrics: Arc<Metrics>,
    /// The model registry (None in factory mode — no hot swap/routing).
    pub store: Option<Arc<ModelStore>>,
    /// The slot name unqualified requests route to (store mode).
    pub default_model: Option<String>,
    workers: Vec<thread::JoinHandle<()>>,
    acceptor: Option<thread::JoinHandle<()>>,
    conns: Arc<ConnTracker>,
}

impl ServerHandle {
    /// The slot unqualified requests execute on (None in factory mode).
    pub fn default_slot(&self) -> Option<Arc<ModelSlot>> {
        let store = self.store.as_ref()?;
        store.get(self.default_model.as_deref()?)
    }

    /// Stop accepting, drain the queue, join workers, then unblock and
    /// join every connection thread. In-flight requests complete (or
    /// fail structurally) and their replies flush before the sockets
    /// are torn down; after this returns no server thread is running.
    /// Idempotent — a second call is a no-op.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the acceptor loop out of `accept()`.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // Drain queued work first: requests already admitted execute or
        // fail structurally, and connection threads blocked on reply
        // channels get their answers delivered...
        self.batcher.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // ...then release the connection tier: shutting the read half
        // wakes parked readers with EOF while final writes still flush,
        // and every connection thread is joined — none outlives stop().
        self.conns.drain();
    }
}

/// Server geometry. In store mode `input_width` only describes the
/// default model (admission is checked per-request against the routed
/// slot); `max_batch` is the global batch cap — each batch is further
/// bounded by its model's contract capacity. `workers: 0` auto-detects
/// the machine's parallelism. Construct with struct-update syntax over
/// [`ServeConfig::default`] so new resilience knobs keep their
/// defaults: `ServeConfig { bind, ..ServeConfig::default() }`.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub bind: String,
    pub workers: usize,
    pub input_width: usize,
    pub max_batch: usize,
    pub window_ms: u64,
    /// Global bound on queued requests (0 = unbounded). At the bound,
    /// requests are shed with an `{"error":"overloaded...",
    /// "retry_after_ms":N}` reply — longest-queue-drop fair across
    /// models — instead of queueing without limit (protects tail
    /// latency under overload; see [`Batcher`]).
    pub queue_depth: usize,
    /// Default queue-wait budget in ms for requests that don't carry
    /// their own `"deadline_ms"` (0 = none). An expired request is
    /// failed with `{"error":"deadline exceeded","waited_ms":N}` at
    /// batch-formation time instead of executing; a request may send
    /// `"deadline_ms":0` to opt out of the server default.
    pub deadline_ms: u64,
    /// Cap on simultaneously open client connections (0 = unbounded).
    /// At capacity a new connection gets one structured
    /// `{"error":"...at connection capacity...","max_conns":N}` reply
    /// and is closed — no thread is spawned for it.
    pub max_conns: usize,
    /// Per-connection read/idle timeout in ms (0 = none). A connection
    /// that doesn't deliver a complete frame within the budget gets a
    /// structured goodbye and is closed — a slowloris client releases
    /// its thread instead of pinning it forever.
    pub idle_timeout_ms: u64,
    /// Largest accepted request frame (one JSON line) in bytes
    /// (0 = unbounded). An oversized frame gets a structured
    /// `{"error":"frame too large...","max_frame_bytes":N}` reply and
    /// the connection closes, instead of the reader buffering an
    /// unterminated line without limit.
    pub max_frame_bytes: usize,
    /// Deployment-safety contract applied to slots registered by
    /// `{"op":"load"}` (retention depth, quarantine circuit breaker).
    /// Slots created before the server started keep their own config.
    pub slot: SlotConfig,
    /// Store-mode only: directory for the crash-recoverable registry
    /// manifest. When set, the manifest is written at startup and
    /// atomically rewritten after every accepted load/swap/unload/
    /// rollback; replaying it at the next startup (see
    /// [`crate::model_store::manifest::restore`]) resumes the exact
    /// pre-crash registry. Ignored in factory mode (no registry).
    pub store_dir: Option<PathBuf>,
    /// Flight-recorder capacity in events (0 disables tracing). Memory
    /// is fixed at this many slots with overwrite-oldest semantics; the
    /// hot path never blocks on a full ring.
    pub trace_capacity: usize,
    /// Emit operational log lines (deployment events, slow requests) as
    /// one JSON object per line instead of prose.
    pub log_json: bool,
    /// Log the full retained lifecycle trace of any request whose total
    /// handle time exceeds this many ms (0 = off).
    pub slow_request_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            bind: "127.0.0.1:0".into(),
            workers: 1,
            input_width: 0,
            max_batch: 16,
            window_ms: 2,
            queue_depth: 0,
            deadline_ms: 0,
            max_conns: 0,
            idle_timeout_ms: 0,
            max_frame_bytes: 1 << 20,
            slot: SlotConfig::default(),
            store_dir: None,
            trace_capacity: 4096,
            log_json: false,
            slow_request_ms: 0,
        }
    }
}

/// How serving workers obtain the model to execute a batch on.
enum Provider {
    /// Shared routed registry; each request resolves (and pins) its slot
    /// at admission, batches snapshot once per execution.
    Store {
        store: Arc<ModelStore>,
        default: String,
        /// Kernel threads for models instantiated by `load`.
        threads: usize,
    },
    /// Per-worker factory (PJRT executables are not `Send`).
    Factory(Arc<dyn Fn() -> Result<SparseModel> + Send + Sync>),
}

/// Start the multi-model routed server on `engine`'s model store. All
/// workers share the registry; `{"op":"infer","model":...}` routes,
/// `{"op":"swap"|"load"|"unload"}` hot-deploy.
pub fn serve_store(engine: &Engine, cfg: ServeConfig) -> Result<ServerHandle> {
    serve_impl(
        Provider::Store {
            store: Arc::clone(&engine.store),
            default: engine.default_model.clone(),
            threads: engine.threads,
        },
        Arc::clone(&engine.metrics),
        cfg,
    )
}

/// Single-model entry to the routed path (the engine's default slot is
/// the only registered model until a `load` arrives).
pub fn serve_slot(engine: &Engine, cfg: ServeConfig) -> Result<ServerHandle> {
    serve_store(engine, cfg)
}

/// Start serving with `cfg.workers` execution threads, each owning a
/// model instance produced by `factory`. No hot swap in this mode.
pub fn serve<F>(factory: F, cfg: ServeConfig) -> Result<ServerHandle>
where
    F: Fn() -> Result<SparseModel> + Send + Sync + 'static,
{
    serve_impl(
        Provider::Factory(Arc::new(factory)),
        Arc::new(Metrics::new()),
        cfg,
    )
}

/// Execute one formed batch on `model` and deliver each row's result.
/// Latency/errors are recorded globally and, when the batch was routed
/// (`mm`), in the model's own breakdown. Errors are counted **per
/// request**, not per batch — one error row is sent per request, so the
/// counters must match or `requests == responses + errors + shed +
/// expired` conservation breaks at batch size > 1.
///
/// Returns the per-request outcome counts `(ok, err)` so store-mode
/// workers can feed the batch's slot ([`ModelSlot::observe_execution`]
/// drives the canary watch and the quarantine circuit breaker).
fn run_batch(
    model: &SparseModel,
    batch: Vec<InferRequest>,
    metrics: &Metrics,
    mm: Option<&ModelMetrics>,
) -> (u64, u64) {
    let inputs: Vec<Vec<f32>> = batch.iter().map(|r| r.input.clone()).collect();
    let batch_id = batch[0].batch_id;
    let model_name = batch[0].model.clone();
    let trace_on = metrics.recorder.is_enabled();
    if trace_on {
        metrics.recorder.record(
            EventKind::ExecStart,
            &model_name,
            0,
            batch_id,
            &format!("n={}", batch.len()),
        );
    }
    let exec_end = |ok: u64, err: u64| {
        if trace_on {
            metrics.recorder.record(
                EventKind::ExecEnd,
                &model_name,
                0,
                batch_id,
                &format!("ok={ok} err={err}"),
            );
        }
    };
    let reply_event = |req: &InferRequest, detail: &str| {
        if trace_on {
            metrics
                .recorder
                .record(EventKind::Reply, &req.model, req.id, req.batch_id, detail);
        }
    };
    // Supervised execution: a panicking kernel fails THIS batch's
    // requests and the worker survives to take the next batch — one bad
    // input or kernel bug must not permanently shrink the worker pool.
    // The fault hook sits inside the guard so injected panics exercise
    // the real recovery path.
    let exec_started = Instant::now();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        faults::on_batch_execute();
        model.infer_batch(&inputs)
    }));
    let exec_secs = exec_started.elapsed().as_secs_f64();
    metrics.stages.record(Stage::Execute, exec_secs);
    if let Some(mm) = mm {
        mm.stages.record(Stage::Execute, exec_secs);
    }
    let n = batch.len() as u64;
    let result = match result {
        Ok(r) => r,
        Err(panic) => {
            metrics.panics.fetch_add(1, Ordering::Relaxed);
            metrics.count_errors(&batch[0].model, n);
            exec_end(0, n);
            let msg = panic
                .downcast_ref::<&'static str>()
                .copied()
                .or_else(|| panic.downcast_ref::<String>().map(String::as_str))
                .unwrap_or("<non-string panic payload>");
            let why = Reject::error(format!("internal error: worker panicked: {msg}"));
            for req in batch {
                reply_event(&req, "error: panic");
                let _ = req.tx.send((req.id, Err(why.clone())));
            }
            return (0, n);
        }
    };
    match result {
        Ok(outputs) => {
            exec_end(n, 0);
            for (req, out) in batch.into_iter().zip(outputs) {
                let secs = req.enqueued.elapsed().as_secs_f64();
                metrics.record_latency(secs);
                if let Some(mm) = mm {
                    mm.record_latency(secs);
                }
                reply_event(&req, "");
                let _ = req.tx.send((req.id, Ok(out)));
            }
            (n, 0)
        }
        Err(e) => {
            // Routed batches carry their model name; factory-mode
            // batches have "" and only count globally.
            metrics.count_errors(&batch[0].model, n);
            exec_end(0, n);
            let msg = format!("{e:#}");
            for req in batch {
                reply_event(&req, "error");
                let _ = req.tx.send((req.id, Err(Reject::error(msg.clone()))));
            }
            (0, n)
        }
    }
}

/// React to a slot's post-batch deployment events: count and log
/// auto-rollbacks (and re-persist the manifest — the live version
/// changed), log canary promotions, quarantine trips, and recoveries.
/// Runs on worker threads; everything here is advisory and must not
/// block batch execution beyond a manifest write.
fn apply_slot_events(
    events: &[SlotEvent],
    name: &str,
    metrics: &Metrics,
    manifest: Option<&ManifestWriter>,
    log_json: bool,
) {
    let log = |event: &str, detail: &str| {
        if log_json {
            eprintln!(
                "{}",
                Json::obj(vec![
                    ("event", Json::Str(event.into())),
                    ("model", Json::Str(name.into())),
                    ("detail", Json::Str(detail.into())),
                ])
            );
        } else {
            eprintln!("model \"{name}\": {detail}");
        }
    };
    for event in events {
        match event {
            SlotEvent::CanaryPromoted { version } => {
                metrics
                    .recorder
                    .record(EventKind::CanaryPromoted, name, 0, 0, &format!("v{version}"));
                log(
                    "canary_promoted",
                    &format!("canary v{version} promoted to serving"),
                );
            }
            SlotEvent::CanaryRolledBack { from, to, reason } => {
                metrics.count_rollback(name);
                metrics.recorder.record(
                    EventKind::CanaryRolledBack,
                    name,
                    0,
                    0,
                    &format!("v{from} -> v{to}: {reason}"),
                );
                log(
                    "canary_rolled_back",
                    &format!("canary v{from} auto-rolled back to v{to}: {reason}"),
                );
                if let Some(m) = manifest {
                    if let Err(e) = m.persist() {
                        log(
                            "manifest_error",
                            &format!("manifest persist after auto-rollback: {e:#}"),
                        );
                    }
                }
            }
            SlotEvent::Quarantined { reason } => {
                metrics
                    .recorder
                    .record(EventKind::Quarantined, name, 0, 0, reason);
                log("quarantined", &format!("quarantined: {reason}"));
            }
            SlotEvent::Recovered => {
                metrics.recorder.record(EventKind::Recovered, name, 0, 0, "");
                log("recovered", "probe succeeded; quarantine lifted");
            }
        }
    }
}

fn serve_impl(provider: Provider, metrics: Arc<Metrics>, cfg: ServeConfig) -> Result<ServerHandle> {
    if let Provider::Factory(factory) = &provider {
        // Preflight: build (and drop) one model before anything spawns.
        // A factory that cannot build fails `serve()` fast, instead of
        // every worker dying at startup and leaving a server that
        // accepts connections but never answers. Workers still build
        // their own instance (PJRT executables are not `Send`).
        drop(factory().context(
            "model factory preflight failed; refusing to start a server whose workers \
             cannot build their model",
        )?);
    }
    let listener = TcpListener::bind(&cfg.bind).context("bind")?;
    let addr = listener.local_addr()?;
    // Size the flight recorder before any traffic can record into it
    // (0 disables tracing entirely; see `--no-trace`).
    metrics.recorder.configure(cfg.trace_capacity);
    let batcher = Arc::new(Batcher::new(
        cfg.max_batch,
        Duration::from_millis(cfg.window_ms),
        cfg.queue_depth,
        Arc::clone(&metrics),
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let (store, default_model) = match &provider {
        Provider::Store { store, default, .. } => (Some(Arc::clone(store)), Some(default.clone())),
        Provider::Factory(_) => (None, None),
    };
    // Durable registry: write the starting state before taking traffic,
    // so a crash at any later point recovers to a manifest that exists.
    // A store dir that cannot be written fails startup fast rather than
    // silently serving without crash recovery.
    let manifest = match (&cfg.store_dir, &store, &default_model) {
        (Some(dir), Some(store), Some(default)) => {
            let writer = Arc::new(ManifestWriter::new(dir, Arc::clone(store), default));
            writer.persist()?;
            Some(writer)
        }
        _ => None,
    };

    let workers: Vec<_> = (0..resolve_threads(cfg.workers))
        .map(|wi| {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let manifest = manifest.clone();
            let log_json = cfg.log_json;
            let worker_provider = match &provider {
                Provider::Store { store, default, threads } => Provider::Store {
                    store: Arc::clone(store),
                    default: default.clone(),
                    threads: *threads,
                },
                Provider::Factory(f) => Provider::Factory(Arc::clone(f)),
            };
            thread::Builder::new()
                .name(format!("gs-serve-worker-{wi}"))
                .spawn(move || match worker_provider {
                    Provider::Store { .. } => {
                        while let Some(batch) = batcher.next_batch() {
                            // The whole (model-homogeneous) batch runs on
                            // the slot it was admitted against — pinned
                            // by the request's Arc, so neither a swap nor
                            // an LRU eviction landing mid-flight disturbs
                            // it — and on a single snapshot, so a batch
                            // never mixes versions.
                            let Some(slot) = batch.first().and_then(|r| r.slot.clone()) else {
                                // Per-request accounting (conservation),
                                // as in run_batch's error path.
                                let n = batch.len() as u64;
                                metrics.count_errors(&batch[0].model, n);
                                for req in batch {
                                    let why = Reject::error("request lost its slot");
                                    let _ = req.tx.send((req.id, Err(why)));
                                }
                                continue;
                            };
                            let vm = slot.current();
                            let name = batch[0].model.clone();
                            // Captured before execution: the batch that
                            // carries a half-open probe reports as one.
                            let probe = batch.iter().any(|r| r.probe);
                            let mm = metrics.model(&name);
                            let (ok, err) =
                                run_batch(&vm.model, batch, &metrics, Some(mm.as_ref()));
                            // Outcomes feed the slot's canary watch and
                            // circuit breaker, keyed by the snapshot
                            // version so stragglers from an older
                            // generation cannot judge the new one.
                            let events = slot.observe_execution(vm.version, ok, err, probe);
                            apply_slot_events(
                                &events,
                                &name,
                                &metrics,
                                manifest.as_deref(),
                                log_json,
                            );
                        }
                    }
                    Provider::Factory(factory) => {
                        let model = match factory() {
                            Ok(m) => m,
                            Err(e) => {
                                eprintln!("worker {wi}: model load failed: {e:#}");
                                metrics.errors.fetch_add(1, Ordering::Relaxed);
                                return;
                            }
                        };
                        while let Some(batch) = batcher.next_batch() {
                            run_batch(&model, batch, &metrics, None);
                        }
                    }
                })
                .expect("spawn worker")
        })
        .collect();

    let conns = Arc::new(ConnTracker::new());
    let acceptor = {
        let batcher = Arc::clone(&batcher);
        let metrics = Arc::clone(&metrics);
        let stop2 = Arc::clone(&stop);
        let tracker = Arc::clone(&conns);
        let ctx = Arc::new(ConnCtx {
            store: store.clone(),
            default_model: default_model.clone(),
            threads: match &provider {
                Provider::Store { threads, .. } => *threads,
                Provider::Factory(_) => 0,
            },
            input_width: cfg.input_width,
            deadline_ms: cfg.deadline_ms,
            idle_timeout_ms: cfg.idle_timeout_ms,
            max_frame_bytes: cfg.max_frame_bytes,
            slot_cfg: cfg.slot,
            manifest: manifest.clone(),
            conns: Arc::clone(&conns),
            log_json: cfg.log_json,
            slow_request_ms: cfg.slow_request_ms,
        });
        let max_conns = cfg.max_conns;
        thread::Builder::new()
            .name("gs-serve-acceptor".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(mut conn) = conn else { continue };
                    let _ = conn.set_nodelay(true); // JSON-lines RPC: Nagle hurts
                    if max_conns > 0 && tracker.live.load(Ordering::SeqCst) >= max_conns {
                        // At capacity: one structured reply, no thread.
                        let reply = Json::obj(vec![
                            (
                                "error",
                                Json::Str("server at connection capacity; retry later".into()),
                            ),
                            ("max_conns", Json::Num(max_conns as f64)),
                        ]);
                        let _ = conn.write_all(reply.to_string().as_bytes());
                        let _ = conn.write_all(b"\n");
                        continue; // drop = close
                    }
                    if ctx.idle_timeout_ms > 0 {
                        let t = Duration::from_millis(ctx.idle_timeout_ms);
                        let _ = conn.set_read_timeout(Some(t));
                        let _ = conn.set_write_timeout(Some(t));
                    }
                    let id = tracker.register(&conn);
                    let batcher = Arc::clone(&batcher);
                    let metrics = Arc::clone(&metrics);
                    let ctx = Arc::clone(&ctx);
                    let guard = ConnGuard { tracker: Arc::clone(&tracker), id };
                    let handle = thread::spawn(move || {
                        let _guard = guard;
                        let _ = handle_connection(conn, &batcher, &metrics, &ctx);
                    });
                    tracker.track(handle);
                }
            })
            .expect("spawn acceptor")
    };

    Ok(ServerHandle {
        addr,
        batcher,
        stop,
        metrics,
        store,
        default_model,
        workers,
        acceptor: Some(acceptor),
        conns,
    })
}

/// Everything a connection needs to admit and route requests.
struct ConnCtx {
    /// None in factory mode.
    store: Option<Arc<ModelStore>>,
    default_model: Option<String>,
    /// Kernel threads for `load`-instantiated models.
    threads: usize,
    /// Factory-mode admission width (store mode checks per slot).
    input_width: usize,
    /// Server-default queue-wait budget (0 = none).
    deadline_ms: u64,
    /// Per-connection read/idle timeout (0 = none); used for the
    /// structured goodbye message.
    idle_timeout_ms: u64,
    /// Frame-size bound for the line reader (0 = unbounded).
    max_frame_bytes: usize,
    /// Deployment-safety contract for `load`-registered slots.
    slot_cfg: SlotConfig,
    /// Durable registry writer (`--store-dir`); None when persistence is
    /// off or in factory mode.
    manifest: Option<Arc<ManifestWriter>>,
    /// Live-connection registry (the `connections` stats gauge).
    conns: Arc<ConnTracker>,
    /// Operational log lines as JSON objects instead of prose.
    log_json: bool,
    /// Slow-request trace-logging threshold in ms (0 = off).
    slow_request_ms: u64,
}

/// Re-persist the durable registry after an accepted deploy op. The
/// in-memory registry already changed, so a failed write is logged
/// rather than failing the op — the next successful persist (or a
/// restart from the previous manifest generation) re-converges.
fn persist_manifest(ctx: &ConnCtx, op: &str) {
    if let Some(m) = &ctx.manifest {
        if let Err(e) = m.persist() {
            eprintln!("manifest persist after {op}: {e:#}");
        }
    }
}

fn err_json(msg: String) -> Json {
    Json::obj(vec![("error", Json::Str(msg))])
}

/// Resolve the request's `"model"` field (or the default) to a slot
/// name. Only called in store mode (factory mode rejects routed
/// requests before routing). A present-but-non-string field is an
/// error, never a silent fallthrough to the default model (that would
/// execute the request on the wrong model). Errors come back as plain
/// messages so each caller can shape the reply (infer attaches the
/// request id).
fn requested_model<'a>(msg: &'a Json, ctx: &'a ConnCtx) -> Result<&'a str, String> {
    match msg.get("model") {
        Some(Json::Str(name)) => Ok(name.as_str()),
        Some(_) => Err("\"model\" must be a string".into()),
        None => match &ctx.default_model {
            Some(default) => Ok(default.as_str()),
            None => Err("server has no default model".into()),
        },
    }
}

/// Outcome of reading one protocol frame through the bounded reader.
enum Frame {
    Line(String),
    /// Orderly end of stream.
    Eof,
    /// The frame outgrew `max_frame_bytes` before its newline arrived.
    TooLarge,
    /// The connection's read timeout elapsed mid-frame (slowloris or
    /// idle client).
    TimedOut,
}

/// Read one newline-terminated frame with a hard byte bound. Unlike
/// `BufReader::lines`, the buffer can never outgrow `max_bytes`
/// (0 = unbounded): the cap is checked against the buffered chunk
/// *before* copying, so an attacker streaming an unterminated line
/// costs at most one buffer's worth of memory. EOF with a trailing
/// unterminated frame yields that frame (matching `lines()` semantics).
fn read_frame(reader: &mut BufReader<TcpStream>, max_bytes: usize) -> std::io::Result<Frame> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = match reader.fill_buf() {
            Ok(c) => c,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return Ok(Frame::TimedOut)
            }
            Err(e) => return Err(e),
        };
        if chunk.is_empty() {
            return Ok(if buf.is_empty() {
                Frame::Eof
            } else {
                Frame::Line(String::from_utf8_lossy(&buf).into_owned())
            });
        }
        let (len, sep) = match chunk.iter().position(|&b| b == b'\n') {
            Some(pos) => (pos, 1),
            None => (chunk.len(), 0),
        };
        if max_bytes > 0 && buf.len() + len > max_bytes {
            return Ok(Frame::TooLarge);
        }
        buf.extend_from_slice(&chunk[..len]);
        reader.consume(len + sep);
        if sep == 1 {
            return Ok(Frame::Line(String::from_utf8_lossy(&buf).into_owned()));
        }
    }
}

fn handle_connection(
    conn: TcpStream,
    batcher: &Batcher,
    metrics: &Metrics,
    ctx: &ConnCtx,
) -> Result<()> {
    let mut writer = conn.try_clone()?;
    let mut reader = BufReader::new(conn);
    loop {
        let line = match read_frame(&mut reader, ctx.max_frame_bytes)? {
            Frame::Eof => break,
            Frame::TimedOut => {
                // Best-effort goodbye — the thread is released either
                // way, which is the point of the timeout.
                let bye = err_json(format!(
                    "idle timeout: no complete frame within {} ms; closing connection",
                    ctx.idle_timeout_ms
                ));
                let _ = writer.write_all(bye.to_string().as_bytes());
                let _ = writer.write_all(b"\n");
                break;
            }
            Frame::TooLarge => {
                // Mid-frame there is no way to resync on the stream, so
                // reply structurally and close.
                let bye = Json::obj(vec![
                    (
                        "error",
                        Json::Str("frame too large; closing connection".into()),
                    ),
                    ("max_frame_bytes", Json::Num(ctx.max_frame_bytes as f64)),
                ]);
                let _ = writer.write_all(bye.to_string().as_bytes());
                let _ = writer.write_all(b"\n");
                break;
            }
            Frame::Line(line) => line,
        };
        if line.trim().is_empty() {
            continue;
        }
        let mut infer_meta: Option<ReplyMeta> = None;
        let reply = match Json::parse(&line) {
            Err(e) => err_json(format!("bad json: {e}")),
            Ok(msg) => match msg.get("op").and_then(Json::as_str) {
                Some("ping") => {
                    let mut fields = vec![("ok", Json::Bool(true))];
                    if let Some(slot) = default_slot(ctx) {
                        fields.push(("version", Json::Num(slot.version() as f64)));
                    }
                    Json::obj(fields)
                }
                Some("stats") => stats_json(metrics, batcher, ctx),
                Some("models") => models_json(ctx),
                Some("swap") => handle_swap(&msg, ctx, metrics),
                Some("load") => handle_load(&msg, ctx, metrics),
                Some("unload") => handle_unload(&msg, ctx),
                Some("rollback") => handle_rollback(&msg, ctx, metrics),
                Some("infer") => handle_infer(&msg, batcher, metrics, ctx, &mut infer_meta),
                Some("trace") => handle_trace(&msg, metrics),
                Some("metrics") => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    (
                        "content_type",
                        Json::Str("text/plain; version=0.0.4".into()),
                    ),
                    ("text", Json::Str(prometheus_text(metrics, batcher, ctx))),
                ]),
                Some("profile") => profile_json(&msg),
                _ => err_json("unknown op".into()),
            },
        };
        let write_started = Instant::now();
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        // An admitted infer finishes its stage accounting only once its
        // reply actually hit the socket.
        if let Some(meta) = infer_meta {
            let wsecs = write_started.elapsed().as_secs_f64();
            metrics.stages.record(Stage::ReplyWrite, wsecs);
            if let Some(mm) = &meta.mm {
                mm.stages.record(Stage::ReplyWrite, wsecs);
            }
            let total_ms = meta.started.elapsed().as_secs_f64() * 1e3;
            if ctx.slow_request_ms > 0 && total_ms > ctx.slow_request_ms as f64 {
                log_slow_request(metrics, &meta, total_ms, ctx.log_json);
            }
        }
    }
    Ok(())
}

/// What the reply path needs to finish an admitted infer's accounting
/// after its reply hits the socket: the reply-write stage sample and
/// the slow-request check. Requests rejected before admission never
/// produce one.
struct ReplyMeta {
    id: u64,
    model: String,
    /// The routed model's breakdown (None in factory mode).
    mm: Option<Arc<ModelMetrics>>,
    /// When the connection thread started handling this request.
    started: Instant,
}

/// A request outlived `slow_request_ms`: log one line carrying its full
/// retained lifecycle from the flight recorder — its request-scoped
/// events plus the batch-scoped events of any batch it rode. Request
/// ids are client-chosen correlation hints, so a shared id merges the
/// traces of requests using it (documented in [`super::trace`]).
fn log_slow_request(metrics: &Metrics, meta: &ReplyMeta, total_ms: f64, log_json: bool) {
    let events = metrics.recorder.snapshot();
    let batch_ids: Vec<u64> = events
        .iter()
        .filter(|e| e.request_id == meta.id && e.batch_id != 0)
        .map(|e| e.batch_id)
        .collect();
    let mine: Vec<&TraceEvent> = events
        .iter()
        .filter(|e| {
            e.request_id == meta.id || (e.batch_id != 0 && batch_ids.contains(&e.batch_id))
        })
        .collect();
    if log_json {
        eprintln!(
            "{}",
            Json::obj(vec![
                ("event", Json::Str("slow_request".into())),
                ("id", Json::Num(meta.id as f64)),
                ("model", Json::Str(meta.model.clone())),
                ("total_ms", Json::Num(total_ms)),
                ("trace", Json::Arr(mine.iter().map(|e| e.to_json()).collect())),
            ])
        );
    } else {
        eprintln!(
            "slow request id={} model=\"{}\": {total_ms:.1} ms; {} trace events:",
            meta.id,
            meta.model,
            mine.len()
        );
        for e in &mine {
            eprintln!("  {}", e.to_json());
        }
    }
}

/// `{"op":"trace"}`: the flight recorder's retained events, oldest
/// first, optionally narrowed by `"model"`, `"event"` (wire spelling,
/// e.g. `"batch_formed"`), `"id"` (request id), and `"limit"` (keep
/// only the newest N after filtering).
fn handle_trace(msg: &Json, metrics: &Metrics) -> Json {
    let rec = &metrics.recorder;
    let mut events = rec.snapshot();
    if let Some(model) = msg.get("model").and_then(Json::as_str) {
        events.retain(|e| e.model == model);
    }
    if let Some(kind) = msg.get("event").and_then(Json::as_str) {
        events.retain(|e| e.kind.name() == kind);
    }
    if let Some(id) = msg.get("id").and_then(Json::as_f64) {
        events.retain(|e| e.request_id == id as u64);
    }
    if let Some(limit) = msg.get("limit").and_then(Json::as_f64) {
        let keep = limit.max(0.0) as usize;
        if events.len() > keep {
            events.drain(..events.len() - keep);
        }
    }
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("enabled", Json::Bool(rec.is_enabled())),
        ("capacity", Json::Num(rec.capacity() as f64)),
        ("dropped", Json::Num(rec.dropped() as f64)),
        (
            "events",
            Json::Arr(events.iter().map(TraceEvent::to_json).collect()),
        ),
    ])
}

/// `{"op":"profile"}`: kernel chunk load-imbalance summaries keyed by
/// plan geometry fingerprint (see [`crate::kernels::profile`]). With
/// `"reset":true` the aggregates are cleared after being reported.
fn profile_json(msg: &Json) -> Json {
    let plans = kernel_profile::snapshot_json();
    if msg.get("reset").and_then(Json::as_bool) == Some(true) {
        kernel_profile::reset();
    }
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("profiling", Json::Bool(kernel_profile::enabled())),
        ("plans", plans),
    ])
}

fn default_slot(ctx: &ConnCtx) -> Option<Arc<ModelSlot>> {
    ctx.store.as_ref()?.get(ctx.default_model.as_deref()?)
}

fn handle_infer(
    msg: &Json,
    batcher: &Batcher,
    metrics: &Metrics,
    ctx: &ConnCtx,
    meta: &mut Option<ReplyMeta>,
) -> Json {
    let started = Instant::now();
    let id = msg.get("id").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let with_id = |mut fields: Vec<(&str, Json)>| {
        fields.insert(0, ("id", Json::Num(id as f64)));
        Json::obj(fields)
    };
    // Resolve the route. Factory mode admits only unrouted requests.
    // This lookup is a plain `get` — recency is only bumped further
    // down, once the request has actually been validated and admitted
    // (a stream of rejected requests must not keep a cold model warm).
    let (mut slot, model_name) = match &ctx.store {
        Some(store) => {
            let name = match requested_model(msg, ctx) {
                Ok(n) => n,
                Err(e) => return with_id(vec![("error", Json::Str(e))]),
            };
            match store.get(name) {
                Some(slot) => (Some(slot), name.to_string()),
                None => {
                    return with_id(vec![(
                        "error",
                        Json::Str(format!("unknown model \"{name}\"")),
                    )])
                }
            }
        }
        None => {
            if msg.get("model").is_some() {
                return with_id(vec![(
                    "error",
                    Json::Str(
                        "model routing unavailable: server runs factory-backed workers".into(),
                    ),
                )]);
            }
            (None, String::new())
        }
    };
    let width = slot.as_ref().map_or(ctx.input_width, |s| s.input_width());
    let input = match msg.get("input").and_then(Json::to_f32_vec) {
        Some(input) if input.len() == width => input,
        _ => {
            let suffix = if model_name.is_empty() {
                String::new()
            } else {
                format!(" (model \"{model_name}\")")
            };
            return with_id(vec![(
                "error",
                Json::Str(format!("input must be {width} floats{suffix}")),
            )]);
        }
    };
    let mut route_mm = None;
    if let Some(store) = &ctx.store {
        // Touch-on-admit: the validated request bumps LRU recency (and
        // re-resolves the slot in case a concurrent load replaced it —
        // the freshest generation should serve).
        match store.acquire(&model_name) {
            Some(s) => {
                // The name may have been re-registered with different
                // geometry between validation and admission; re-check
                // against the slot that will actually execute, so a
                // stale-width request can never join (and fail) a batch
                // of valid requests on the new slot.
                if s.input_width() != input.len() {
                    return with_id(vec![(
                        "error",
                        Json::Str(format!(
                            "input must be {} floats (model \"{model_name}\")",
                            s.input_width()
                        )),
                    )]);
                }
                slot = Some(s);
            }
            None => {
                return with_id(vec![(
                    "error",
                    Json::Str(format!("unknown model \"{model_name}\"")),
                )])
            }
        }
        let mm = metrics.model(&model_name);
        mm.requests.fetch_add(1, Ordering::Relaxed);
        mm.touch();
        route_mm = Some(mm);
    }
    // Queue-wait budget: the request's own "deadline_ms" wins over the
    // server default; an explicit 0 opts out. A present-but-invalid
    // value is an error, never a silent fallthrough (the client clearly
    // wanted a deadline; running without one would violate it).
    let deadline_ms = match msg.get("deadline_ms") {
        None => ctx.deadline_ms,
        Some(j) => match j.as_f64() {
            Some(v) if v >= 0.0 && v.fract() == 0.0 => v as u64,
            _ => {
                return with_id(vec![(
                    "error",
                    Json::Str("\"deadline_ms\" must be a non-negative integer".into()),
                )])
            }
        },
    };
    let (tx, rx) = channel();
    let cap = slot.as_ref().map_or(usize::MAX, |s| s.batch_capacity());
    if metrics.recorder.is_enabled() {
        metrics
            .recorder
            .record(EventKind::Admit, &model_name, id, 0, "");
    }
    *meta = Some(ReplyMeta {
        id,
        model: model_name.clone(),
        mm: route_mm,
        started,
    });
    // A refused submit (overload shed, shutdown) has already failed the
    // request's tx with a structured Reject, so the reply path below is
    // uniform — the Result here is deliberately not consulted.
    let _ = batcher.submit(InferRequest {
        id,
        input,
        enqueued: Instant::now(),
        tx,
        model: model_name,
        slot,
        cap,
        batch_id: 0,
        deadline_ms: if deadline_ms == 0 { None } else { Some(deadline_ms) },
        probe: false,
    });
    match rx.recv() {
        Ok((id, Ok(out))) => Json::obj(vec![
            ("id", Json::Num(id as f64)),
            ("output", Json::nums_f32(&out)),
        ]),
        Ok((id, Err(why))) => {
            let mut fields = vec![
                ("id", Json::Num(id as f64)),
                ("error", Json::Str(why.error)),
            ];
            if let Some(ms) = why.retry_after_ms {
                fields.push(("retry_after_ms", Json::Num(ms as f64)));
            }
            if let Some(ms) = why.waited_ms {
                fields.push(("waited_ms", Json::Num(ms as f64)));
            }
            if let Some(ms) = why.quarantined_for_ms {
                fields.push(("quarantined_for_ms", Json::Num(ms as f64)));
            }
            Json::obj(fields)
        }
        Err(_) => err_json("worker dropped".into()),
    }
}

/// Parse the optional `"canary":{"requests":N,"max_error_rate":F}`
/// block of a swap. `Ok(None)` = no canary requested; a present but
/// malformed block is an error, never a silent plain swap (the operator
/// clearly wanted a watched deploy).
fn canary_spec(msg: &Json) -> Result<Option<(u64, f64)>, String> {
    let Some(canary) = msg.get("canary") else {
        return Ok(None);
    };
    let requests = canary.get("requests").and_then(Json::as_f64);
    let rate = canary.get("max_error_rate").and_then(Json::as_f64);
    match (requests, rate) {
        (Some(n), Some(f)) if n >= 1.0 && n.fract() == 0.0 && (0.0..=1.0).contains(&f) => {
            Ok(Some((n as u64, f)))
        }
        _ => Err("\"canary\" requires an integer \"requests\" >= 1 and a \"max_error_rate\" \
                  between 0 and 1"
            .into()),
    }
}

/// `{"op":"swap","model":...,"path":...}`: load + validate the artifact,
/// instantiate it, and swap it into the named (or default) slot. Traffic
/// keeps flowing on the old version until the new one is installed;
/// nothing is interrupted on failure (the error comes back on this
/// connection, the slot keeps its current generation, and the failure is
/// counted in `swap_failures` globally and per model). With a
/// `"canary"` block the new generation installs under a canary watch
/// (auto-rollback past the error budget) and the reply carries
/// `"state":"canary"`.
fn handle_swap(msg: &Json, ctx: &ConnCtx, metrics: &Metrics) -> Json {
    let Some(store) = &ctx.store else {
        return err_json("hot swap unavailable: server runs factory-backed workers".into());
    };
    let name = match requested_model(msg, ctx) {
        Ok(n) => n,
        Err(e) => return err_json(e),
    };
    let Some(path) = msg.get("path").and_then(Json::as_str) else {
        return err_json("swap requires a \"path\" to a .gsm artifact".into());
    };
    let canary = match canary_spec(msg) {
        Ok(c) => c,
        Err(e) => return err_json(e),
    };
    let Some(slot) = store.get(name) else {
        // A typo'd deploy is still a failed deploy: surface it on the
        // global counter (no per-model entry — never-registered names
        // must not grow the metrics map).
        metrics.swap_failures.fetch_add(1, Ordering::Relaxed);
        return err_json(format!("unknown model \"{name}\""));
    };
    let mm = metrics.model(name);
    let swapped = match canary {
        None => slot.swap_path(path),
        Some((requests, max_error_rate)) => slot.swap_path_canary(path, requests, max_error_rate),
    };
    match swapped {
        Ok(vm) => {
            metrics.swaps.fetch_add(1, Ordering::Relaxed);
            mm.swaps.fetch_add(1, Ordering::Relaxed);
            persist_manifest(ctx, "swap");
            metrics.recorder.record(
                EventKind::Swap,
                name,
                0,
                0,
                &format!(
                    "v{}{}",
                    vm.version,
                    if canary.is_some() { " canary" } else { "" }
                ),
            );
            // Report the generation *this* request installed, not
            // whatever a concurrent later swap made current.
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(name.into())),
                ("version", Json::Num(vm.version as f64)),
                (
                    "state",
                    Json::Str(if canary.is_some() { "canary" } else { "serving" }.into()),
                ),
            ];
            if let Some(p) = vm.precision() {
                fields.push(("precision", Json::Str(p.name().into())));
            }
            Json::obj(fields)
        }
        Err(e) => {
            metrics.swap_failures.fetch_add(1, Ordering::Relaxed);
            mm.swap_failures.fetch_add(1, Ordering::Relaxed);
            err_json(format!("{e:#}"))
        }
    }
}

/// `{"op":"load","model":...,"path":...}`: make a named model resident.
/// An existing name is hot-swapped in place (same zero-downtime path as
/// `swap`; the slot's serving contract still applies). A new name
/// registers a fresh slot at version 1, LRU-evicting the coldest
/// non-pinned model(s) if the store is at capacity — gracefully:
/// admitted requests hold their slot `Arc` and finish undisturbed.
fn handle_load(msg: &Json, ctx: &ConnCtx, metrics: &Metrics) -> Json {
    let Some(store) = &ctx.store else {
        return err_json("load unavailable: server runs factory-backed workers".into());
    };
    let Some(name) = msg.get("model").and_then(Json::as_str) else {
        return err_json("load requires a \"model\" name".into());
    };
    let Some(path) = msg.get("path").and_then(Json::as_str) else {
        return err_json("load requires a \"path\" to a .gsm artifact".into());
    };
    if msg.get("canary").is_some() {
        return err_json(
            "canary deploys are only supported on \"swap\": a freshly loaded model has no \
             previous generation to roll back to"
                .into(),
        );
    }
    // Load + instantiate exactly once, before any registry decision.
    let model = match ModelArtifact::load(path).and_then(|a| {
        a.instantiate(ctx.threads)
            .with_context(|| format!("instantiate artifact {path}"))
    }) {
        Ok(m) => m,
        Err(e) => {
            // Global counter only: a failed load of a never-registered
            // name must not mint a permanent per-model metrics entry
            // (typo'd names would grow `stats` without bound).
            metrics.swap_failures.fetch_add(1, Ordering::Relaxed);
            return err_json(format!("{e:#}"));
        }
    };
    let precision = model.precision();
    if let Some(existing) = store.get(name) {
        // Resident name: swap the instantiated model into the captured
        // slot handle (contract-checked, zero-downtime, no second
        // artifact read). Operating on the handle rather than looking
        // the name up again means a concurrent unload cannot turn this
        // legitimate load into an "unknown model" failure — concurrent
        // admin ops are last-writer-wins at the registry.
        let mm = metrics.model(name);
        return match existing.swap(model, path) {
            Ok(vm) => {
                metrics.swaps.fetch_add(1, Ordering::Relaxed);
                mm.swaps.fetch_add(1, Ordering::Relaxed);
                persist_manifest(ctx, "load");
                let mut fields = vec![
                    ("ok", Json::Bool(true)),
                    ("model", Json::Str(name.into())),
                    ("version", Json::Num(vm.version as f64)),
                ];
                if let Some(p) = vm.precision() {
                    fields.push(("precision", Json::Str(p.name().into())));
                }
                Json::obj(fields)
            }
            Err(e) => {
                metrics.swap_failures.fetch_add(1, Ordering::Relaxed);
                mm.swap_failures.fetch_add(1, Ordering::Relaxed);
                err_json(format!("{e:#}"))
            }
        };
    }
    let slot = Arc::new(ModelSlot::with_config(model, path, ctx.threads, ctx.slot_cfg));
    match store.register_new(name, slot) {
        Ok(Some(evicted)) => {
            metrics
                .evictions
                .fetch_add(evicted.len() as u64, Ordering::Relaxed);
            persist_manifest(ctx, "load");
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(name.into())),
                ("version", Json::Num(1.0)),
                (
                    "evicted",
                    Json::Arr(evicted.into_iter().map(Json::Str).collect()),
                ),
            ];
            if let Some(p) = precision {
                fields.push(("precision", Json::Str(p.name().into())));
            }
            Json::obj(fields)
        }
        // A concurrent load registered this name first: swap into that
        // slot so the contract check applies and neither deploy is
        // silently dropped.
        Ok(None) => handle_swap(msg, ctx, metrics),
        Err(e) => {
            metrics.swap_failures.fetch_add(1, Ordering::Relaxed);
            err_json(format!("{e:#}"))
        }
    }
}

/// `{"op":"unload","model":...}`: drop a model from the registry. The
/// pinned default cannot be unloaded; in-flight batches on the dropped
/// slot finish undisturbed (they hold the `Arc`).
fn handle_unload(msg: &Json, ctx: &ConnCtx) -> Json {
    let Some(store) = &ctx.store else {
        return err_json("unload unavailable: server runs factory-backed workers".into());
    };
    let Some(name) = msg.get("model").and_then(Json::as_str) else {
        return err_json("unload requires a \"model\" name".into());
    };
    match store.unload(name) {
        Ok(()) => {
            persist_manifest(ctx, "unload");
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(name.into())),
            ])
        }
        Err(e) => err_json(format!("{e:#}")),
    }
}

/// `{"op":"rollback","model":...}`: restore the named (or default)
/// slot's previous retained generation under live traffic — the same
/// zero-downtime path as swap, in reverse. In-flight batches finish on
/// the generation they snapshotted; queued requests ride the restored
/// one. Fails (without touching the slot) when nothing is retained.
fn handle_rollback(msg: &Json, ctx: &ConnCtx, metrics: &Metrics) -> Json {
    let Some(store) = &ctx.store else {
        return err_json("rollback unavailable: server runs factory-backed workers".into());
    };
    let name = match requested_model(msg, ctx) {
        Ok(n) => n,
        Err(e) => return err_json(e),
    };
    let Some(slot) = store.get(name) else {
        return err_json(format!("unknown model \"{name}\""));
    };
    match slot.rollback("operator rollback") {
        Ok(vm) => {
            metrics.count_rollback(name);
            persist_manifest(ctx, "rollback");
            metrics
                .recorder
                .record(EventKind::Rollback, name, 0, 0, &format!("v{}", vm.version));
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(name.into())),
                ("version", Json::Num(vm.version as f64)),
            ];
            if let Some(p) = vm.precision() {
                fields.push(("precision", Json::Str(p.name().into())));
            }
            Json::obj(fields)
        }
        Err(e) => err_json(format!("{e:#}")),
    }
}

/// `{"op":"models"}`: every resident slot with version/precision/geometry.
fn models_json(ctx: &ConnCtx) -> Json {
    let Some(store) = &ctx.store else {
        return err_json("model registry unavailable: server runs factory-backed workers".into());
    };
    let default = ctx.default_model.clone().unwrap_or_default();
    let mut models = Vec::new();
    for name in store.names() {
        let Some(slot) = store.get(&name) else { continue };
        let vm = slot.current();
        let mut fields = vec![
            ("version", Json::Num(vm.version as f64)),
            ("source", Json::Str(vm.source.clone())),
            ("inputs", Json::Num(vm.model.inputs as f64)),
            ("hidden", Json::Num(vm.model.hidden as f64)),
            ("outputs", Json::Num(vm.model.outputs as f64)),
            ("max_batch", Json::Num(vm.model.max_batch as f64)),
            ("default", Json::Bool(name == default)),
            ("state", Json::Str(slot.state_name().into())),
            ("retained_versions", Json::Num(slot.retained() as f64)),
        ];
        if let Some(p) = vm.precision() {
            fields.push(("precision", Json::Str(p.name().into())));
        }
        if let Some(r) = slot.last_rollback() {
            fields.push(("last_rollback", Json::Str(r)));
        }
        models.push((name, Json::obj(fields)));
    }
    Json::obj(vec![
        ("default", Json::Str(default)),
        ("max_models", Json::Num(store.max_models() as f64)),
        ("models", Json::Obj(models.into_iter().collect())),
    ])
}

/// The per-stage latency breakdown (`stats.stages`): sample count and
/// p50/p95/p99/mean (ms) per pipeline stage; stages with no samples
/// yet are omitted.
fn stages_json(stages: &StageSet) -> Json {
    let mut fields = Vec::new();
    for stage in Stage::ALL {
        if let Some(s) = stages.summary(stage) {
            fields.push((
                stage.name(),
                Json::obj(vec![
                    ("n", Json::Num(s.n as f64)),
                    ("p50_ms", Json::Num(s.p50 * 1e3)),
                    ("p95_ms", Json::Num(s.p95 * 1e3)),
                    ("p99_ms", Json::Num(s.p99 * 1e3)),
                    ("mean_ms", Json::Num(s.mean * 1e3)),
                ]),
            ));
        }
    }
    Json::obj(fields)
}

/// `{"op":"metrics"}`: the whole metrics surface in Prometheus text
/// exposition format 0.0.4 — counters (global series plus one
/// `{model="..."}` series per touched model), gauges, and
/// quantile-labelled summaries for request latency, per-stage latency,
/// and batch occupancy. Emitted by hand: the format is line-oriented
/// text and the crate takes no dependencies.
fn prometheus_text(metrics: &Metrics, batcher: &Batcher, ctx: &ConnCtx) -> String {
    use std::fmt::Write as _;

    fn esc(v: &str) -> String {
        v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
    }

    fn labels(pairs: &[(&str, &str)]) -> String {
        if pairs.is_empty() {
            return String::new();
        }
        let body: Vec<String> = pairs
            .iter()
            .map(|(k, v)| format!("{k}=\"{}\"", esc(v)))
            .collect();
        format!("{{{}}}", body.join(","))
    }

    /// One summary-typed series: quantile samples + `_sum`/`_count`.
    /// The sum is reconstructed as `mean * n` (the histogram keeps the
    /// exact sum, but only the summary crosses this interface).
    fn summary_lines(out: &mut String, name: &str, base: &[(&str, &str)], s: &Summary) {
        for (q, v) in [("0.5", s.p50), ("0.95", s.p95), ("0.99", s.p99)] {
            let mut pairs = base.to_vec();
            pairs.push(("quantile", q));
            let _ = writeln!(out, "{name}{} {v}", labels(&pairs));
        }
        let _ = writeln!(out, "{name}_sum{} {}", labels(base), s.mean * s.n as f64);
        let _ = writeln!(out, "{name}_count{} {}", labels(base), s.n);
    }

    let (queue_depth, queue_depths) = batcher.queue_depths();
    let models = metrics.model_snapshot();
    let mut out = String::new();

    type PerModel = fn(&ModelMetrics) -> &AtomicU64;
    let counters: [(&str, &str, u64, Option<PerModel>); 13] = [
        (
            "gs_requests_total",
            "Inference requests admitted.",
            metrics.requests.load(Ordering::Relaxed),
            Some(|m| &m.requests),
        ),
        (
            "gs_responses_total",
            "Successful inference replies.",
            metrics.responses.load(Ordering::Relaxed),
            Some(|m| &m.responses),
        ),
        (
            "gs_errors_total",
            "Requests failed with an error reply.",
            metrics.errors.load(Ordering::Relaxed),
            Some(|m| &m.errors),
        ),
        (
            "gs_shed_total",
            "Requests shed by bounded admission.",
            metrics.shed.load(Ordering::Relaxed),
            Some(|m| &m.shed),
        ),
        (
            "gs_expired_total",
            "Requests failed on their queue-wait deadline.",
            metrics.expired.load(Ordering::Relaxed),
            Some(|m| &m.expired),
        ),
        (
            "gs_panics_total",
            "Batch executions that panicked (caught).",
            metrics.panics.load(Ordering::Relaxed),
            None,
        ),
        (
            "gs_swaps_total",
            "Successful model hot swaps.",
            metrics.swaps.load(Ordering::Relaxed),
            Some(|m| &m.swaps),
        ),
        (
            "gs_swap_failures_total",
            "Rejected or failed swap attempts.",
            metrics.swap_failures.load(Ordering::Relaxed),
            Some(|m| &m.swap_failures),
        ),
        (
            "gs_evictions_total",
            "Models LRU-evicted from the store.",
            metrics.evictions.load(Ordering::Relaxed),
            None,
        ),
        (
            "gs_rollbacks_total",
            "Slot rollbacks (manual and canary).",
            metrics.rollbacks.load(Ordering::Relaxed),
            Some(|m| &m.rollbacks),
        ),
        (
            "gs_quarantined_total",
            "Requests fast-failed under quarantine.",
            metrics.quarantined.load(Ordering::Relaxed),
            Some(|m| &m.quarantined),
        ),
        (
            "gs_batches_total",
            "Batches formed.",
            metrics.batches.load(Ordering::Relaxed),
            None,
        ),
        (
            "gs_batched_rows_total",
            "Requests carried by formed batches.",
            metrics.batched_rows.load(Ordering::Relaxed),
            None,
        ),
    ];
    for (name, help, global, per) in counters {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {global}");
        if let Some(f) = per {
            for (model, m) in &models {
                let _ = writeln!(
                    out,
                    "{name}{} {}",
                    labels(&[("model", model)]),
                    f(m).load(Ordering::Relaxed)
                );
            }
        }
    }

    let _ = writeln!(out, "# HELP gs_queue_depth Requests waiting in the batcher.");
    let _ = writeln!(out, "# TYPE gs_queue_depth gauge");
    let _ = writeln!(out, "gs_queue_depth {queue_depth}");
    for (model, depth) in &queue_depths {
        let _ = writeln!(out, "gs_queue_depth{} {depth}", labels(&[("model", model)]));
    }
    let _ = writeln!(out, "# HELP gs_connections Open client connections.");
    let _ = writeln!(out, "# TYPE gs_connections gauge");
    let _ = writeln!(
        out,
        "gs_connections {}",
        ctx.conns.live.load(Ordering::SeqCst)
    );
    let _ = writeln!(out, "# HELP gs_uptime_seconds Seconds since server start.");
    let _ = writeln!(out, "# TYPE gs_uptime_seconds gauge");
    let _ = writeln!(out, "gs_uptime_seconds {}", metrics.uptime_ms() as f64 / 1e3);

    let _ = writeln!(
        out,
        "# HELP gs_request_latency_seconds End-to-end request latency (enqueue to result)."
    );
    let _ = writeln!(out, "# TYPE gs_request_latency_seconds summary");
    if let Some(s) = metrics.latency_summary() {
        summary_lines(&mut out, "gs_request_latency_seconds", &[], &s);
    }
    for (model, m) in &models {
        if let Some(s) = m.latency_summary() {
            summary_lines(
                &mut out,
                "gs_request_latency_seconds",
                &[("model", model)],
                &s,
            );
        }
    }

    let _ = writeln!(
        out,
        "# HELP gs_stage_seconds Request latency attributed to one pipeline stage."
    );
    let _ = writeln!(out, "# TYPE gs_stage_seconds summary");
    for stage in Stage::ALL {
        if let Some(s) = metrics.stages.summary(stage) {
            summary_lines(&mut out, "gs_stage_seconds", &[("stage", stage.name())], &s);
        }
    }
    for (model, m) in &models {
        for stage in Stage::ALL {
            if let Some(s) = m.stages.summary(stage) {
                summary_lines(
                    &mut out,
                    "gs_stage_seconds",
                    &[("model", model), ("stage", stage.name())],
                    &s,
                );
            }
        }
    }

    let _ = writeln!(out, "# HELP gs_batch_occupancy Rows per formed batch.");
    let _ = writeln!(out, "# TYPE gs_batch_occupancy summary");
    if let Some(s) = metrics.batch_occupancy.summary() {
        summary_lines(&mut out, "gs_batch_occupancy", &[], &s);
    }
    out
}

fn stats_json(metrics: &Metrics, batcher: &Batcher, ctx: &ConnCtx) -> Json {
    // One lock hold: the global and per-model queue depths in a single
    // stats reply are mutually consistent.
    let (queue_depth, queue_depths) = batcher.queue_depths();
    let mut fields = vec![
        (
            "requests",
            Json::Num(metrics.requests.load(Ordering::Relaxed) as f64),
        ),
        (
            "responses",
            Json::Num(metrics.responses.load(Ordering::Relaxed) as f64),
        ),
        (
            "batches",
            Json::Num(metrics.batches.load(Ordering::Relaxed) as f64),
        ),
        ("mean_batch", Json::Num(metrics.mean_batch_size())),
        (
            "errors",
            Json::Num(metrics.errors.load(Ordering::Relaxed) as f64),
        ),
        (
            "shed",
            Json::Num(metrics.shed.load(Ordering::Relaxed) as f64),
        ),
        (
            "expired",
            Json::Num(metrics.expired.load(Ordering::Relaxed) as f64),
        ),
        (
            "panics",
            Json::Num(metrics.panics.load(Ordering::Relaxed) as f64),
        ),
        ("queue_depth", Json::Num(queue_depth as f64)),
        (
            "connections",
            Json::Num(ctx.conns.live.load(Ordering::SeqCst) as f64),
        ),
        (
            "swaps",
            Json::Num(metrics.swaps.load(Ordering::Relaxed) as f64),
        ),
        (
            "swap_failures",
            Json::Num(metrics.swap_failures.load(Ordering::Relaxed) as f64),
        ),
        (
            "evictions",
            Json::Num(metrics.evictions.load(Ordering::Relaxed) as f64),
        ),
        (
            "rollbacks",
            Json::Num(metrics.rollbacks.load(Ordering::Relaxed) as f64),
        ),
        (
            "quarantined",
            Json::Num(metrics.quarantined.load(Ordering::Relaxed) as f64),
        ),
        ("uptime_ms", Json::Num(metrics.uptime_ms() as f64)),
    ];
    if let Some(slot) = default_slot(ctx) {
        let vm = slot.current();
        fields.push(("model_version", Json::Num(vm.version as f64)));
        if let Some(p) = vm.precision() {
            fields.push(("precision", Json::Str(p.name().into())));
        }
    }
    if let Some(s) = metrics.latency_summary() {
        fields.push(("p50_ms", Json::Num(s.p50 * 1e3)));
        fields.push(("p95_ms", Json::Num(s.p95 * 1e3)));
        fields.push(("mean_ms", Json::Num(s.mean * 1e3)));
    }
    fields.push(("stages", stages_json(&metrics.stages)));
    if let Some(s) = metrics.batch_occupancy.summary() {
        fields.push((
            "batch_occupancy",
            Json::obj(vec![
                ("n", Json::Num(s.n as f64)),
                ("p50", Json::Num(s.p50)),
                ("p95", Json::Num(s.p95)),
                ("min", Json::Num(s.min)),
                ("max", Json::Num(s.max)),
                ("mean", Json::Num(s.mean)),
            ]),
        ));
    }
    // Per-slot breakdown: every resident model plus every model that
    // ever took traffic (counters are history — an eviction or unload
    // must not erase a model's request/latency record from `stats`).
    // Reads go through the snapshot, never `metrics.model()` — a stats
    // poll must not mint permanent entries for untouched models. The
    // top-level keys above keep their historical global meaning.
    if let Some(store) = &ctx.store {
        let history: std::collections::BTreeMap<String, Arc<ModelMetrics>> =
            metrics.model_snapshot().into_iter().collect();
        let mut names = store.names();
        for name in history.keys() {
            if !names.contains(name) {
                names.push(name.clone());
            }
        }
        names.sort();
        let mut models = Vec::new();
        for name in names {
            let mm = history.get(&name);
            let counter = |f: fn(&ModelMetrics) -> &std::sync::atomic::AtomicU64| {
                mm.map_or(0.0, |m| f(m).load(Ordering::Relaxed) as f64)
            };
            let mut mf = vec![
                ("requests", Json::Num(counter(|m| &m.requests))),
                ("responses", Json::Num(counter(|m| &m.responses))),
                ("errors", Json::Num(counter(|m| &m.errors))),
                ("shed", Json::Num(counter(|m| &m.shed))),
                ("expired", Json::Num(counter(|m| &m.expired))),
                (
                    "queue_depth",
                    Json::Num(queue_depths.get(&name).copied().unwrap_or(0) as f64),
                ),
                ("swaps", Json::Num(counter(|m| &m.swaps))),
                ("swap_failures", Json::Num(counter(|m| &m.swap_failures))),
                ("rollbacks", Json::Num(counter(|m| &m.rollbacks))),
                ("quarantined", Json::Num(counter(|m| &m.quarantined))),
            ];
            match store.get(&name) {
                Some(slot) => {
                    let vm = slot.current();
                    mf.push(("resident", Json::Bool(true)));
                    mf.push(("version", Json::Num(vm.version as f64)));
                    mf.push(("state", Json::Str(slot.state_name().into())));
                    mf.push(("retained_versions", Json::Num(slot.retained() as f64)));
                    if let Some(p) = vm.precision() {
                        mf.push(("precision", Json::Str(p.name().into())));
                    }
                }
                None => mf.push(("resident", Json::Bool(false))),
            }
            if let Some(m) = mm {
                if let Some(idle) = m.idle_secs() {
                    mf.push(("last_used_s", Json::Num(idle)));
                }
                if let Some(s) = m.latency_summary() {
                    mf.push(("p50_ms", Json::Num(s.p50 * 1e3)));
                    mf.push(("p95_ms", Json::Num(s.p95 * 1e3)));
                    mf.push(("mean_ms", Json::Num(s.mean * 1e3)));
                }
                mf.push(("stages", stages_json(&m.stages)));
            }
            models.push((name, Json::obj(mf)));
        }
        fields.push(("models", Json::Obj(models.into_iter().collect())));
    }
    Json::obj(fields)
}

/// Outcome of a single infer attempt where an overload shed is an
/// expected, retryable state rather than a hard failure (see
/// [`Client::try_infer`]).
#[derive(Clone, Debug, PartialEq)]
pub enum InferOutcome {
    Output(Vec<f32>),
    /// The server shed this request under overload; back off for the
    /// hinted milliseconds and retry.
    Overloaded { retry_after_ms: u64 },
    /// The request outwaited its deadline in the server queue and was
    /// failed at batch formation — it never executed.
    Expired { waited_ms: u64 },
}

/// Blocking JSON-lines client (tests, examples, bench harness).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        Self::from_stream(TcpStream::connect(addr)?)
    }

    /// Connect with a bound on how long to wait for the server to
    /// accept — an unreachable or wedged server fails fast instead of
    /// hanging the caller on the OS connect timeout.
    pub fn connect_timeout(addr: std::net::SocketAddr, timeout: Duration) -> Result<Client> {
        Self::from_stream(TcpStream::connect_timeout(&addr, timeout)?)
    }

    fn from_stream(stream: TcpStream) -> Result<Client> {
        let _ = stream.set_nodelay(true);
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    /// Bound every subsequent read and write on this connection
    /// (`None` clears the bound). With a timeout set, a wedged server
    /// surfaces as a clear "server timed out" error instead of hanging
    /// the calling thread forever.
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(timeout)?;
        self.writer.set_write_timeout(timeout)?;
        Ok(())
    }

    /// Map a timed-out read/write to a clear error (the raw io error
    /// kind differs by platform: `WouldBlock` on unix, `TimedOut` on
    /// windows).
    fn io_ctx<T>(r: std::io::Result<T>) -> Result<T> {
        r.map_err(|e| match e.kind() {
            ErrorKind::WouldBlock | ErrorKind::TimedOut => anyhow::anyhow!(
                "server timed out: no reply within the configured timeout \
                 (server wedged or overloaded)"
            ),
            _ => e.into(),
        })
    }

    fn roundtrip(&mut self, msg: Json) -> Result<Json> {
        Self::io_ctx(self.writer.write_all(msg.to_string().as_bytes()))?;
        Self::io_ctx(self.writer.write_all(b"\n"))?;
        let mut line = String::new();
        // 0 bytes = orderly EOF: surface it as what it is instead of
        // feeding the empty string to the JSON parser (which used to
        // produce a baffling "bad json" error).
        if Self::io_ctx(self.reader.read_line(&mut line))? == 0 {
            anyhow::bail!("connection closed by server");
        }
        Ok(Json::parse(&line)?)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.roundtrip(Json::obj(vec![("op", "ping".into())]))?;
        Ok(r.get("ok").and_then(Json::as_bool).unwrap_or(false))
    }

    /// One infer attempt with overload and deadline expiry surfaced
    /// structurally: a shed reply (`retry_after_ms` present) returns
    /// [`InferOutcome::Overloaded`] and an expired reply (`waited_ms`
    /// present) returns [`InferOutcome::Expired`] instead of an error,
    /// so callers implementing back-pressure need not parse error
    /// strings. Hard failures (bad input, unknown model, transport)
    /// still `Err`.
    pub fn try_infer(&mut self, model: Option<&str>, input: &[f32]) -> Result<InferOutcome> {
        self.try_infer_deadline(model, input, None)
    }

    /// [`Client::try_infer`] with a queue-wait budget: the server fails
    /// the request with a structured expiry instead of executing it
    /// once it has queued longer than `deadline_ms`. `Some(0)`
    /// explicitly opts out of the server's default deadline.
    pub fn try_infer_deadline(
        &mut self,
        model: Option<&str>,
        input: &[f32],
        deadline_ms: Option<u64>,
    ) -> Result<InferOutcome> {
        let id = self.next_id;
        self.next_id += 1;
        let mut fields = vec![
            ("op", "infer".into()),
            ("id", Json::Num(id as f64)),
            ("input", Json::nums_f32(input)),
        ];
        if let Some(model) = model {
            fields.push(("model", Json::Str(model.into())));
        }
        if let Some(ms) = deadline_ms {
            fields.push(("deadline_ms", Json::Num(ms as f64)));
        }
        let r = self.roundtrip(Json::obj(fields))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            if let Some(ms) = r.get("retry_after_ms").and_then(Json::as_f64) {
                return Ok(InferOutcome::Overloaded { retry_after_ms: ms as u64 });
            }
            if let Some(ms) = r.get("waited_ms").and_then(Json::as_f64) {
                return Ok(InferOutcome::Expired { waited_ms: ms as u64 });
            }
            anyhow::bail!("server error: {err}");
        }
        r.get("output")
            .and_then(Json::to_f32_vec)
            .map(InferOutcome::Output)
            .ok_or_else(|| anyhow::anyhow!("malformed response"))
    }

    fn infer_inner(&mut self, model: Option<&str>, input: &[f32]) -> Result<Vec<f32>> {
        match self.try_infer(model, input)? {
            InferOutcome::Output(out) => Ok(out),
            // For the plain-infer API an overload shed is still an
            // error, with the hint in the message.
            InferOutcome::Overloaded { retry_after_ms } => anyhow::bail!(
                "server overloaded (retry after {retry_after_ms} ms): request shed, \
                 back off and retry"
            ),
            InferOutcome::Expired { waited_ms } => anyhow::bail!(
                "deadline exceeded: request expired after {waited_ms} ms in the server \
                 queue without executing"
            ),
        }
    }

    /// Infer on the server's default model.
    pub fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        self.infer_inner(None, input)
    }

    /// Infer on a named model.
    pub fn infer_model(&mut self, model: &str, input: &[f32]) -> Result<Vec<f32>> {
        self.infer_inner(Some(model), input)
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.roundtrip(Json::obj(vec![("op", "stats".into())]))
    }

    /// The flight recorder's retained lifecycle events
    /// (`{"op":"trace"}`). `filter` entries are passed through as
    /// protocol fields, e.g. `&[("model", Json::Str("m".into())),
    /// ("limit", Json::Num(50.0))]`; empty = everything retained.
    pub fn trace(&mut self, filter: &[(&str, Json)]) -> Result<Json> {
        let mut fields = vec![("op", Json::Str("trace".into()))];
        fields.extend(filter.iter().map(|(k, v)| (*k, v.clone())));
        let r = self.roundtrip(Json::obj(fields))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("trace failed: {err}");
        }
        Ok(r)
    }

    /// The Prometheus text exposition (`{"op":"metrics"}`), unwrapped
    /// from its JSON envelope.
    pub fn metrics_text(&mut self) -> Result<String> {
        let r = self.roundtrip(Json::obj(vec![("op", "metrics".into())]))?;
        r.get("text")
            .and_then(Json::as_str)
            .map(String::from)
            .ok_or_else(|| anyhow::anyhow!("malformed metrics response"))
    }

    /// Kernel chunk load-imbalance profiles (`{"op":"profile"}`).
    pub fn profile(&mut self) -> Result<Json> {
        let r = self.roundtrip(Json::obj(vec![("op", "profile".into())]))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("profile failed: {err}");
        }
        Ok(r)
    }

    /// The model registry listing (`{"op":"models"}`).
    pub fn models(&mut self) -> Result<Json> {
        let r = self.roundtrip(Json::obj(vec![("op", "models".into())]))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("models failed: {err}");
        }
        Ok(r)
    }

    fn deploy(&mut self, op: &str, model: Option<&str>, path: &str) -> Result<Json> {
        let mut fields = vec![("op", Json::Str(op.into())), ("path", Json::Str(path.into()))];
        if let Some(model) = model {
            fields.push(("model", Json::Str(model.into())));
        }
        let r = self.roundtrip(Json::obj(fields))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("{op} failed: {err}");
        }
        Ok(r)
    }

    fn version_of(r: &Json, op: &str) -> Result<u64> {
        r.get("version")
            .and_then(Json::as_f64)
            .map(|v| v as u64)
            .ok_or_else(|| anyhow::anyhow!("malformed {op} response"))
    }

    /// Hot-swap the default model to the artifact at `path`; returns the
    /// new deployment version.
    pub fn swap(&mut self, path: &str) -> Result<u64> {
        let r = self.deploy("swap", None, path)?;
        Self::version_of(&r, "swap")
    }

    /// Hot-swap a named model's slot; returns the new version.
    pub fn swap_model(&mut self, model: &str, path: &str) -> Result<u64> {
        let r = self.deploy("swap", Some(model), path)?;
        Self::version_of(&r, "swap")
    }

    /// Canary-swap a named model: install the artifact at `path` under a
    /// watch over its first `requests` requests, auto-rolling back if
    /// more than `max_error_rate` of them fail. Returns the canary's
    /// version (the server reply also carries `"state":"canary"`).
    pub fn swap_canary(
        &mut self,
        model: &str,
        path: &str,
        requests: u64,
        max_error_rate: f64,
    ) -> Result<u64> {
        let r = self.roundtrip(Json::obj(vec![
            ("op", "swap".into()),
            ("model", Json::Str(model.into())),
            ("path", Json::Str(path.into())),
            (
                "canary",
                Json::obj(vec![
                    ("requests", Json::Num(requests as f64)),
                    ("max_error_rate", Json::Num(max_error_rate)),
                ]),
            ),
        ]))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("swap failed: {err}");
        }
        Self::version_of(&r, "swap")
    }

    /// Roll the named (or default) model back to its retained previous
    /// generation; returns the restored version.
    pub fn rollback(&mut self, model: Option<&str>) -> Result<u64> {
        let mut fields = vec![("op", Json::Str("rollback".into()))];
        if let Some(model) = model {
            fields.push(("model", Json::Str(model.into())));
        }
        let r = self.roundtrip(Json::obj(fields))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("rollback failed: {err}");
        }
        Self::version_of(&r, "rollback")
    }

    /// Make `model` resident from the artifact at `path`; returns the
    /// deployed version (1 for a fresh slot) and any evicted model names.
    pub fn load(&mut self, model: &str, path: &str) -> Result<(u64, Vec<String>)> {
        let r = self.deploy("load", Some(model), path)?;
        let evicted = r
            .get("evicted")
            .and_then(Json::as_arr)
            .map(|xs| {
                xs.iter()
                    .filter_map(|j| j.as_str().map(String::from))
                    .collect()
            })
            .unwrap_or_default();
        Ok((Self::version_of(&r, "load")?, evicted))
    }

    /// Drop `model` from the registry (the pinned default is refused).
    pub fn unload(&mut self, model: &str) -> Result<()> {
        let r = self.roundtrip(Json::obj(vec![
            ("op", "unload".into()),
            ("model", Json::Str(model.into())),
        ]))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("unload failed: {err}");
        }
        Ok(())
    }
}
