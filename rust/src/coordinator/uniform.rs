//! Uniform (padded) GS layout — the JAX-side representation.
//!
//! The Pallas kernel takes `value`/`index` as dense `[nbands, g, B]`
//! tensors with the same group count `g` in every band; a ragged
//! [`GsFormat`] is padded with zero-valued groups whose indices are the
//! identity residues `0..B` (inert: they gather arbitrary activations and
//! multiply them by zero — proven inert in `python/tests/test_kernel.py`).

use crate::pruning::prune;
use crate::runtime::Tensor;
use crate::sparse::dense::Dense;
use crate::sparse::format::GsFormat;
use crate::sparse::pattern::Pattern;
use anyhow::{ensure, Result};

/// Padded GS arrays ready to ship to the artifact.
#[derive(Clone, Debug)]
pub struct UniformGs {
    pub nbands: usize,
    pub groups: usize,
    pub b: usize,
    pub k: usize,
    /// `[nbands * groups * b]` values, band-major.
    pub value: Vec<f32>,
    /// Matching column indices (i32 for the artifact).
    pub index: Vec<i32>,
}

impl UniformGs {
    /// Pad `gs` to exactly `groups` groups per band. Fails if any band has
    /// more (the caller pruned at a sparsity that does not fit the
    /// artifact's static shape).
    pub fn from_format(gs: &GsFormat, groups: usize) -> Result<UniformGs> {
        ensure!(gs.rowmap.is_none(), "scatter patterns need a rowmap-aware artifact");
        let nbands = gs.nbands();
        let b = gs.b;
        let mut value = vec![0.0f32; nbands * groups * b];
        let mut index = vec![0i32; nbands * groups * b];
        // Inert padding: identity residues.
        for slot in index.chunks_mut(b) {
            for (j, v) in slot.iter_mut().enumerate() {
                *v = j as i32;
            }
        }
        for band in 0..nbands {
            let lo = gs.indptr[band] as usize;
            let hi = gs.indptr[band + 1] as usize;
            ensure!(
                hi - lo <= groups,
                "band {band} has {} groups, artifact holds {groups}",
                hi - lo
            );
            for (gi, g) in (lo..hi).enumerate() {
                let dst = (band * groups + gi) * b;
                value[dst..dst + b].copy_from_slice(&gs.value[g * b..(g + 1) * b]);
                for j in 0..b {
                    index[dst + j] = gs.index[g * b + j] as i32;
                }
            }
        }
        Ok(UniformGs { nbands, groups, b, k: gs.k, value, index })
    }

    /// Like [`from_format`], but when a band exceeds `groups` its
    /// smallest-|value| groups are dropped (the serving-side capacity
    /// clamp: the artifact's static shape wins over the pruner's
    /// round-up). Returns the layout and the number of dropped groups.
    pub fn from_format_truncating(gs: &GsFormat, groups: usize) -> Result<(UniformGs, usize)> {
        ensure!(gs.rowmap.is_none(), "scatter patterns need a rowmap-aware artifact");
        let b = gs.b;
        let mut clamped = gs.clone();
        let mut dropped = 0;
        let mut value = Vec::new();
        let mut index = Vec::new();
        let mut indptr = vec![0u32];
        for band in 0..gs.nbands() {
            let lo = gs.indptr[band] as usize;
            let hi = gs.indptr[band + 1] as usize;
            let mut order: Vec<usize> = (lo..hi).collect();
            // Keep the largest-L1 groups.
            order.sort_by(|&ga, &gb| {
                let la: f32 = gs.value[ga * b..(ga + 1) * b].iter().map(|v| v.abs()).sum();
                let lb: f32 = gs.value[gb * b..(gb + 1) * b].iter().map(|v| v.abs()).sum();
                lb.partial_cmp(&la).unwrap()
            });
            dropped += order.len().saturating_sub(groups);
            order.truncate(groups);
            order.sort_unstable(); // keep original order among survivors
            for g in order {
                value.extend_from_slice(&gs.value[g * b..(g + 1) * b]);
                index.extend_from_slice(&gs.index[g * b..(g + 1) * b]);
            }
            indptr.push((value.len() / b) as u32);
        }
        clamped.value = value;
        clamped.index = index;
        clamped.indptr = indptr;
        let uniform = UniformGs::from_format(&clamped, groups)?;
        Ok((uniform, dropped))
    }

    /// One-call deployment path: prune `weights` under `GS(B,B)` to the
    /// sparsity the artifact's static capacity implies, compress, and
    /// clamp to `groups` groups per band.
    pub fn compress_for(weights: &Dense, b: usize, groups: usize) -> Result<UniformGs> {
        let pattern = Pattern::Gs { b, k: b };
        let sparsity = (1.0 - (groups * b) as f64 / weights.cols as f64).max(0.0);
        let mask = prune(weights, pattern, sparsity)?;
        let mut pruned = weights.clone();
        pruned.apply_mask(&mask);
        let gs = GsFormat::from_dense(&pruned, pattern)?;
        let (uniform, _dropped) = UniformGs::from_format_truncating(&gs, groups)?;
        Ok(uniform)
    }

    pub fn value_tensor(&self) -> Tensor {
        Tensor::f32(&[self.nbands, self.groups, self.b], self.value.clone())
    }

    pub fn index_tensor(&self) -> Tensor {
        Tensor::i32(
            &[self.nbands, self.groups, self.b],
            self.index.clone(),
        )
    }

    /// Dense reconstruction (rows = nbands·B/k), for oracle checks.
    pub fn to_dense(&self, cols: usize) -> Vec<Vec<f32>> {
        let slots = self.b / self.k;
        let rows = self.nbands * slots;
        let mut out = vec![vec![0.0f32; cols]; rows];
        for band in 0..self.nbands {
            for g in 0..self.groups {
                for j in 0..self.b {
                    let at = (band * self.groups + g) * self.b + j;
                    let row = band * slots + j / self.k;
                    out[row][self.index[at] as usize] += self.value[at];
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::prune;
    use crate::sparse::dense::Dense;
    use crate::sparse::pattern::Pattern;
    use crate::util::prng::Prng;

    #[test]
    fn padding_is_inert_and_roundtrips() {
        let mut rng = Prng::new(1);
        let mut w = Dense::random(8, 32, 1.0, &mut rng);
        let p = Pattern::Gs { b: 8, k: 8 };
        let mask = prune(&w, p, 0.5).unwrap();
        w.apply_mask(&mask);
        let gs = GsFormat::from_dense(&w, p).unwrap();
        let max_groups = (0..gs.nbands())
            .map(|b| (gs.indptr[b + 1] - gs.indptr[b]) as usize)
            .max()
            .unwrap();
        let u = UniformGs::from_format(&gs, max_groups + 2).unwrap();
        let dense = u.to_dense(32);
        for r in 0..8 {
            for c in 0..32 {
                assert_eq!(dense[r][c], w.at(r, c), "({r},{c})");
            }
        }
    }

    #[test]
    fn rejects_insufficient_groups() {
        let mut rng = Prng::new(2);
        let mut w = Dense::random(8, 32, 1.0, &mut rng);
        let p = Pattern::Gs { b: 8, k: 8 };
        let mask = prune(&w, p, 0.25).unwrap();
        w.apply_mask(&mask);
        let gs = GsFormat::from_dense(&w, p).unwrap();
        assert!(UniformGs::from_format(&gs, 1).is_err());
    }
}
