//! Serving metrics: global counters + latency reservoir, plus a
//! per-model breakdown for multi-model serving.
//!
//! The global [`Metrics`] fields keep their historical meaning (every
//! request/response/swap on the server, whichever model it routed to),
//! so existing dashboards and tests reading the top-level `stats` keys
//! are unaffected. [`Metrics::model`] lazily creates a [`ModelMetrics`]
//! per slot name; the server records each routed request into both the
//! global aggregates and its model's breakdown, and `stats` reports the
//! per-model view under a `"models"` object.

use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Bounded latency sample store shared by the global and per-model
/// views: keeps the most recent 100k samples (one policy, two users —
/// the cap/drain behavior cannot drift between them).
#[derive(Default)]
struct Reservoir(Mutex<Vec<f64>>);

impl Reservoir {
    fn push(&self, secs: f64) {
        let mut l = self.0.lock().unwrap();
        if l.len() >= 100_000 {
            l.drain(..50_000);
        }
        l.push(secs);
    }

    fn summary(&self) -> Option<Summary> {
        let l = self.0.lock().unwrap();
        if l.is_empty() {
            None
        } else {
            Some(Summary::of(&l))
        }
    }
}

/// Counters + latency reservoir for one model slot.
#[derive(Default)]
pub struct ModelMetrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    /// Requests rejected by bounded admission (overload shedding) —
    /// kept separate from `errors` so overload never masquerades as
    /// inference failure. Together: `requests == responses + errors +
    /// shed + expired` once the model's traffic has quiesced.
    pub shed: AtomicU64,
    /// Requests that outwaited their `deadline_ms` budget in queue and
    /// were failed at batch-formation time instead of executing — kept
    /// separate from `errors` (the request was fine; the queue was
    /// slow) and from `shed` (admission accepted it).
    pub expired: AtomicU64,
    /// Successful hot-swaps of this slot.
    pub swaps: AtomicU64,
    pub swap_failures: AtomicU64,
    /// Rollbacks of this slot (manual `rollback` ops + canary
    /// auto-rollbacks).
    pub rollbacks: AtomicU64,
    /// Requests fast-failed at admission because the slot was
    /// quarantined. A supplementary view: each is also counted in
    /// `errors`, so the conservation identity is unchanged.
    pub quarantined: AtomicU64,
    latencies: Reservoir,
    /// When this model last admitted an infer request (None = never).
    last_used: Mutex<Option<Instant>>,
}

impl ModelMetrics {
    pub fn record_latency(&self, secs: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.latencies.push(secs);
    }

    /// Stamp "an infer request routed here just now".
    pub fn touch(&self) {
        *self.last_used.lock().unwrap() = Some(Instant::now());
    }

    /// Seconds since the last routed infer request (None = never used).
    pub fn idle_secs(&self) -> Option<f64> {
        self.last_used
            .lock()
            .unwrap()
            .map(|t| t.elapsed().as_secs_f64())
    }

    /// Latency summary (None until the first response).
    pub fn latency_summary(&self) -> Option<Summary> {
        self.latencies.summary()
    }
}

/// Thread-safe serving metrics.
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub batched_rows: AtomicU64,
    pub errors: AtomicU64,
    /// Requests rejected by bounded admission (overload shedding).
    /// Every submitted request ends as exactly one of
    /// response/error/shed/expired, so `requests == responses + errors
    /// + shed + expired` holds exactly once traffic has quiesced.
    pub shed: AtomicU64,
    /// Requests failed at batch-formation time because they outwaited
    /// their deadline in queue (never executed).
    pub expired: AtomicU64,
    /// Worker batch executions that panicked. The panic is caught, the
    /// batch's requests are failed per-request (counted in `errors`),
    /// and the worker survives — this counter is the crash audit trail.
    pub panics: AtomicU64,
    /// Successful model hot-swaps (deploys) since startup, across every
    /// slot. Together with `model_version`/`precision` in the `stats`
    /// response, this lets an operator confirm a deploy actually landed.
    pub swaps: AtomicU64,
    /// Rejected/failed swap attempts — kept separate from `errors` so
    /// deploy mistakes never masquerade as inference failures.
    pub swap_failures: AtomicU64,
    /// Cold models LRU-evicted from the store under capacity pressure.
    pub evictions: AtomicU64,
    /// Slot rollbacks (manual `rollback` ops + canary auto-rollbacks)
    /// across every slot.
    pub rollbacks: AtomicU64,
    /// Requests fast-failed at admission because their slot was
    /// quarantined. Supplementary: each is also counted in `errors`, so
    /// `requests == responses + errors + shed + expired` still holds
    /// exactly (same pattern as `panics`).
    pub quarantined: AtomicU64,
    latencies: Reservoir,
    /// Per-model breakdowns, keyed by slot name. Entries are created on
    /// first touch and survive unload/eviction (counters are history,
    /// not registry state).
    models: RwLock<BTreeMap<String, Arc<ModelMetrics>>>,
    /// Server start time, backing the `uptime_ms` stats key.
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_rows: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            swap_failures: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            rollbacks: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            latencies: Reservoir::default(),
            models: RwLock::new(BTreeMap::new()),
            started: Instant::now(),
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Milliseconds since this metrics object (the server) was created.
    pub fn uptime_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// The per-model breakdown for `name`, created on first use.
    pub fn model(&self, name: &str) -> Arc<ModelMetrics> {
        if let Some(m) = self.models.read().unwrap().get(name) {
            return Arc::clone(m);
        }
        let mut map = self.models.write().unwrap();
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Snapshot of every per-model breakdown (sorted by name).
    pub fn model_snapshot(&self) -> Vec<(String, Arc<ModelMetrics>)> {
        self.models
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Arc::clone(v)))
            .collect()
    }

    pub fn record_latency(&self, secs: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.latencies.push(secs);
    }

    /// Count `n` request errors globally and, for routed requests
    /// (non-empty model name), in the model's breakdown. Every
    /// conservation-relevant error bump goes through this one shape so
    /// a per-model count cannot be missed at any call site.
    pub fn count_errors(&self, model: &str, n: u64) {
        self.errors.fetch_add(n, Ordering::Relaxed);
        if !model.is_empty() {
            self.model(model).errors.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Count one shed request globally and per model (same shape as
    /// [`Metrics::count_errors`]).
    pub fn count_shed(&self, model: &str) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        if !model.is_empty() {
            self.model(model).shed.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one deadline-expired request globally and per model (same
    /// shape as [`Metrics::count_errors`]).
    pub fn count_expired(&self, model: &str) {
        self.expired.fetch_add(1, Ordering::Relaxed);
        if !model.is_empty() {
            self.model(model).expired.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one rollback globally and per model (same shape as
    /// [`Metrics::count_errors`]).
    pub fn count_rollback(&self, model: &str) {
        self.rollbacks.fetch_add(1, Ordering::Relaxed);
        if !model.is_empty() {
            self.model(model).rollbacks.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one quarantine fast-fail globally and per model. The
    /// request is terminal with an error reply, so it bumps `errors`
    /// (keeping the conservation identity exact) *and* the supplementary
    /// `quarantined` counter that tells operators why.
    pub fn count_quarantined(&self, model: &str) {
        self.quarantined.fetch_add(1, Ordering::Relaxed);
        self.errors.fetch_add(1, Ordering::Relaxed);
        if !model.is_empty() {
            let mm = self.model(model);
            mm.quarantined.fetch_add(1, Ordering::Relaxed);
            mm.errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record_batch(&self, rows: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);
    }

    /// Latency summary (None until the first response).
    pub fn latency_summary(&self) -> Option<Summary> {
        self.latencies.summary()
    }

    /// Mean rows per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_rows.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary_and_batch_means() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        m.record_latency(0.001);
        m.record_latency(0.003);
        m.record_batch(4);
        m.record_batch(8);
        let s = m.latency_summary().unwrap();
        assert_eq!(s.n, 2);
        assert!((s.mean - 0.002).abs() < 1e-9);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-9);
        assert_eq!(m.responses.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn swap_counter_starts_at_zero() {
        let m = Metrics::new();
        assert_eq!(m.swaps.load(Ordering::Relaxed), 0);
        m.swaps.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.swaps.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn per_model_breakdowns_are_independent() {
        let m = Metrics::new();
        let a = m.model("a");
        let b = m.model("b");
        a.requests.fetch_add(3, Ordering::Relaxed);
        a.record_latency(0.002);
        b.requests.fetch_add(1, Ordering::Relaxed);
        // The same name returns the same breakdown.
        assert_eq!(m.model("a").requests.load(Ordering::Relaxed), 3);
        assert_eq!(m.model("b").requests.load(Ordering::Relaxed), 1);
        assert_eq!(a.latency_summary().unwrap().n, 1);
        assert!(b.latency_summary().is_none());
        let names: Vec<String> = m.model_snapshot().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn quarantine_counts_keep_conservation_exact() {
        let m = Metrics::new();
        m.count_quarantined("a");
        m.count_quarantined("a");
        m.count_rollback("a");
        assert_eq!(m.quarantined.load(Ordering::Relaxed), 2);
        assert_eq!(m.errors.load(Ordering::Relaxed), 2, "each fast-fail is also an error");
        assert_eq!(m.rollbacks.load(Ordering::Relaxed), 1);
        let a = m.model("a");
        assert_eq!(a.quarantined.load(Ordering::Relaxed), 2);
        assert_eq!(a.errors.load(Ordering::Relaxed), 2);
        assert_eq!(a.rollbacks.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn uptime_advances() {
        let m = Metrics::new();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(m.uptime_ms() >= 1);
    }

    #[test]
    fn idle_secs_tracks_touch() {
        let mm = ModelMetrics::default();
        assert!(mm.idle_secs().is_none());
        mm.touch();
        let idle = mm.idle_secs().unwrap();
        assert!(idle >= 0.0 && idle < 1.0, "{idle}");
    }
}
