//! Serving metrics: counters + latency reservoir.

use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Thread-safe serving metrics.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub batched_rows: AtomicU64,
    pub errors: AtomicU64,
    /// Successful model hot-swaps (deploys) since startup. Together with
    /// `model_version`/`precision` in the `stats` response, this lets an
    /// operator confirm a deploy actually landed.
    pub swaps: AtomicU64,
    /// Rejected/failed swap attempts — kept separate from `errors` so
    /// deploy mistakes never masquerade as inference failures.
    pub swap_failures: AtomicU64,
    latencies: Mutex<Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_latency(&self, secs: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies.lock().unwrap();
        // Bounded reservoir: keep the most recent 100k samples.
        if l.len() >= 100_000 {
            l.drain(..50_000);
        }
        l.push(secs);
    }

    pub fn record_batch(&self, rows: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);
    }

    /// Latency summary (None until the first response).
    pub fn latency_summary(&self) -> Option<Summary> {
        let l = self.latencies.lock().unwrap();
        if l.is_empty() {
            None
        } else {
            Some(Summary::of(&l))
        }
    }

    /// Mean rows per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_rows.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary_and_batch_means() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        m.record_latency(0.001);
        m.record_latency(0.003);
        m.record_batch(4);
        m.record_batch(8);
        let s = m.latency_summary().unwrap();
        assert_eq!(s.n, 2);
        assert!((s.mean - 0.002).abs() < 1e-9);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-9);
        assert_eq!(m.responses.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn swap_counter_starts_at_zero() {
        let m = Metrics::new();
        assert_eq!(m.swaps.load(Ordering::Relaxed), 0);
        m.swaps.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.swaps.load(Ordering::Relaxed), 1);
    }
}
