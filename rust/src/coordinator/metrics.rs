//! Serving metrics: global counters + stage latency histograms, plus a
//! per-model breakdown for multi-model serving and the embedded flight
//! recorder.
//!
//! The global [`Metrics`] fields keep their historical meaning (every
//! request/response/swap on the server, whichever model it routed to),
//! so existing dashboards and tests reading the top-level `stats` keys
//! are unaffected. [`Metrics::model`] lazily creates a [`ModelMetrics`]
//! per slot name; the server records each routed request into both the
//! global aggregates and its model's breakdown, and `stats` reports the
//! per-model view under a `"models"` object.
//!
//! Latency storage is a log-scale [`Histogram`] (see
//! `util::histogram`), **cumulative over the process lifetime**: `n`
//! counts every sample since startup and memory is fixed, unlike the
//! old reservoir whose bulk drain silently discarded the oldest half.
//! Per-request time is additionally attributed to pipeline [`Stage`]s
//! (queue-wait, batch-formation, execute, reply-write) so `stats` and
//! the Prometheus exposition can say *where* time went, not just how
//! much.

use crate::coordinator::trace::FlightRecorder;
use crate::util::histogram::Histogram;
use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

/// One stage of a request's pipeline. `name()` is the wire spelling
/// used by `stats.stages`, the Prometheus `stage` label, and JSON logs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Request enqueue → its batch sealing (per request).
    QueueWait,
    /// Batch head enqueue → batch sealed (per batch).
    BatchForm,
    /// Worker executing `infer_batch` (per batch).
    Execute,
    /// Serialized reply hitting the socket write (per request).
    ReplyWrite,
}

impl Stage {
    pub const ALL: [Stage; 4] = [
        Stage::QueueWait,
        Stage::BatchForm,
        Stage::Execute,
        Stage::ReplyWrite,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::BatchForm => "batch_form",
            Stage::Execute => "execute",
            Stage::ReplyWrite => "reply_write",
        }
    }
}

/// One latency histogram per pipeline stage.
pub struct StageSet {
    hists: [Histogram; 4],
}

impl Default for StageSet {
    fn default() -> StageSet {
        StageSet {
            hists: [
                Histogram::latency(),
                Histogram::latency(),
                Histogram::latency(),
                Histogram::latency(),
            ],
        }
    }
}

impl StageSet {
    pub fn record(&self, stage: Stage, secs: f64) {
        self.hists[stage as usize].record(secs);
    }

    /// Summary for one stage (None until its first sample).
    pub fn summary(&self, stage: Stage) -> Option<Summary> {
        self.hists[stage as usize].summary()
    }
}

/// Counters + latency histograms for one model slot.
pub struct ModelMetrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    /// Requests rejected by bounded admission (overload shedding) —
    /// kept separate from `errors` so overload never masquerades as
    /// inference failure. Together: `requests == responses + errors +
    /// shed + expired` once the model's traffic has quiesced.
    pub shed: AtomicU64,
    /// Requests that outwaited their `deadline_ms` budget in queue and
    /// were failed at batch-formation time instead of executing — kept
    /// separate from `errors` (the request was fine; the queue was
    /// slow) and from `shed` (admission accepted it).
    pub expired: AtomicU64,
    /// Successful hot-swaps of this slot.
    pub swaps: AtomicU64,
    pub swap_failures: AtomicU64,
    /// Rollbacks of this slot (manual `rollback` ops + canary
    /// auto-rollbacks).
    pub rollbacks: AtomicU64,
    /// Requests fast-failed at admission because the slot was
    /// quarantined. A supplementary view: each is also counted in
    /// `errors`, so the conservation identity is unchanged.
    pub quarantined: AtomicU64,
    /// Per-stage latency breakdown for requests routed to this model.
    pub stages: StageSet,
    latencies: Histogram,
    /// Construction time anchoring the `last_used` stamp.
    epoch: Instant,
    /// Milliseconds since `epoch` of the last routed infer request,
    /// stored +1 so 0 means "never" — an atomic store on the admit
    /// path where the old `Mutex<Option<Instant>>` took a lock.
    last_used: AtomicU64,
}

impl Default for ModelMetrics {
    fn default() -> ModelMetrics {
        ModelMetrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            swap_failures: AtomicU64::new(0),
            rollbacks: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            stages: StageSet::default(),
            latencies: Histogram::latency(),
            epoch: Instant::now(),
            last_used: AtomicU64::new(0),
        }
    }
}

impl ModelMetrics {
    pub fn record_latency(&self, secs: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.latencies.record(secs);
    }

    /// Stamp "an infer request routed here just now" (lock-free).
    pub fn touch(&self) {
        let now = self.epoch.elapsed().as_millis() as u64;
        self.last_used.store(now + 1, Ordering::Relaxed);
    }

    /// Seconds since the last routed infer request (None = never used).
    pub fn idle_secs(&self) -> Option<f64> {
        match self.last_used.load(Ordering::Relaxed) {
            0 => None,
            stamp => {
                let now = self.epoch.elapsed().as_millis() as u64;
                Some(now.saturating_sub(stamp - 1) as f64 / 1e3)
            }
        }
    }

    /// Latency summary (None until the first response). Cumulative over
    /// every response this model has ever served.
    pub fn latency_summary(&self) -> Option<Summary> {
        self.latencies.summary()
    }
}

/// Thread-safe serving metrics.
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub batched_rows: AtomicU64,
    pub errors: AtomicU64,
    /// Requests rejected by bounded admission (overload shedding).
    /// Every submitted request ends as exactly one of
    /// response/error/shed/expired, so `requests == responses + errors
    /// + shed + expired` holds exactly once traffic has quiesced.
    pub shed: AtomicU64,
    /// Requests failed at batch-formation time because they outwaited
    /// their deadline in queue (never executed).
    pub expired: AtomicU64,
    /// Worker batch executions that panicked. The panic is caught, the
    /// batch's requests are failed per-request (counted in `errors`),
    /// and the worker survives — this counter is the crash audit trail.
    pub panics: AtomicU64,
    /// Successful model hot-swaps (deploys) since startup, across every
    /// slot. Together with `model_version`/`precision` in the `stats`
    /// response, this lets an operator confirm a deploy actually landed.
    pub swaps: AtomicU64,
    /// Rejected/failed swap attempts — kept separate from `errors` so
    /// deploy mistakes never masquerade as inference failures.
    pub swap_failures: AtomicU64,
    /// Cold models LRU-evicted from the store under capacity pressure.
    pub evictions: AtomicU64,
    /// Slot rollbacks (manual `rollback` ops + canary auto-rollbacks)
    /// across every slot.
    pub rollbacks: AtomicU64,
    /// Requests fast-failed at admission because their slot was
    /// quarantined. Supplementary: each is also counted in `errors`, so
    /// `requests == responses + errors + shed + expired` still holds
    /// exactly (same pattern as `panics`).
    pub quarantined: AtomicU64,
    /// JSON-framed frames parsed off client connections (requests and
    /// control ops; empty keep-alive lines are not counted).
    pub frames_json: AtomicU64,
    /// Binary frames parsed off client connections (HELLO + INFER).
    pub frames_binary: AtomicU64,
    /// Successful HELLO → HELLO_ACK binary-framing negotiations.
    pub binary_negotiations: AtomicU64,
    /// Connections currently speaking binary framing (gauge).
    pub binary_connections: AtomicU64,
    /// Admitted infer requests whose reply has not yet been written to
    /// a socket (gauge) — pipelining depth across all connections.
    pub inflight: AtomicU64,
    /// Per-stage latency breakdown across every model.
    pub stages: StageSet,
    /// Rows-per-batch distribution (how full formed batches run).
    pub batch_occupancy: Histogram,
    /// The flight recorder (ring of lifecycle events). Embedded here so
    /// every layer already holding the metrics handle can record
    /// without new plumbing; capacity is reconfigured at serve startup.
    pub recorder: FlightRecorder,
    latencies: Histogram,
    /// Per-model breakdowns, keyed by slot name. Entries are created on
    /// first touch and survive unload/eviction (counters are history,
    /// not registry state).
    models: RwLock<BTreeMap<String, Arc<ModelMetrics>>>,
    /// Server start time, backing the `uptime_ms` stats key.
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_rows: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            swap_failures: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            rollbacks: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            frames_json: AtomicU64::new(0),
            frames_binary: AtomicU64::new(0),
            binary_negotiations: AtomicU64::new(0),
            binary_connections: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            stages: StageSet::default(),
            batch_occupancy: Histogram::occupancy(),
            recorder: FlightRecorder::new(4096),
            latencies: Histogram::latency(),
            models: RwLock::new(BTreeMap::new()),
            started: Instant::now(),
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Milliseconds since this metrics object (the server) was created.
    pub fn uptime_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// The per-model breakdown for `name`, created on first use.
    pub fn model(&self, name: &str) -> Arc<ModelMetrics> {
        if let Some(m) = self.models.read().unwrap().get(name) {
            return Arc::clone(m);
        }
        let mut map = self.models.write().unwrap();
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Snapshot of every per-model breakdown (sorted by name).
    pub fn model_snapshot(&self) -> Vec<(String, Arc<ModelMetrics>)> {
        self.models
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Arc::clone(v)))
            .collect()
    }

    pub fn record_latency(&self, secs: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.latencies.record(secs);
    }

    /// Count `n` request errors globally and, for routed requests
    /// (non-empty model name), in the model's breakdown. Every
    /// conservation-relevant error bump goes through this one shape so
    /// a per-model count cannot be missed at any call site.
    pub fn count_errors(&self, model: &str, n: u64) {
        self.errors.fetch_add(n, Ordering::Relaxed);
        if !model.is_empty() {
            self.model(model).errors.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Count one shed request globally and per model (same shape as
    /// [`Metrics::count_errors`]).
    pub fn count_shed(&self, model: &str) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        if !model.is_empty() {
            self.model(model).shed.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one deadline-expired request globally and per model (same
    /// shape as [`Metrics::count_errors`]).
    pub fn count_expired(&self, model: &str) {
        self.expired.fetch_add(1, Ordering::Relaxed);
        if !model.is_empty() {
            self.model(model).expired.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one rollback globally and per model (same shape as
    /// [`Metrics::count_errors`]).
    pub fn count_rollback(&self, model: &str) {
        self.rollbacks.fetch_add(1, Ordering::Relaxed);
        if !model.is_empty() {
            self.model(model).rollbacks.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one quarantine fast-fail globally and per model. The
    /// request is terminal with an error reply, so it bumps `errors`
    /// (keeping the conservation identity exact) *and* the supplementary
    /// `quarantined` counter that tells operators why.
    pub fn count_quarantined(&self, model: &str) {
        self.quarantined.fetch_add(1, Ordering::Relaxed);
        self.errors.fetch_add(1, Ordering::Relaxed);
        if !model.is_empty() {
            let mm = self.model(model);
            mm.quarantined.fetch_add(1, Ordering::Relaxed);
            mm.errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one formed batch of `rows` requests; returns the minted
    /// batch id (1-based, unique for the server's lifetime) used to
    /// link `batch_formed`/`exec_*`/`reply` trace events.
    pub fn record_batch(&self, rows: usize) -> u64 {
        let id = self.batches.fetch_add(1, Ordering::Relaxed) + 1;
        self.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.batch_occupancy.record(rows as f64);
        id
    }

    /// Latency summary (None until the first response). Cumulative over
    /// every response since startup.
    pub fn latency_summary(&self) -> Option<Summary> {
        self.latencies.summary()
    }

    /// Mean rows per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_rows.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary_and_batch_means() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        m.record_latency(0.001);
        m.record_latency(0.003);
        m.record_batch(4);
        m.record_batch(8);
        let s = m.latency_summary().unwrap();
        assert_eq!(s.n, 2);
        assert!((s.mean - 0.002).abs() < 1e-9);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-9);
        assert_eq!(m.responses.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn swap_counter_starts_at_zero() {
        let m = Metrics::new();
        assert_eq!(m.swaps.load(Ordering::Relaxed), 0);
        m.swaps.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.swaps.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn per_model_breakdowns_are_independent() {
        let m = Metrics::new();
        let a = m.model("a");
        let b = m.model("b");
        a.requests.fetch_add(3, Ordering::Relaxed);
        a.record_latency(0.002);
        b.requests.fetch_add(1, Ordering::Relaxed);
        // The same name returns the same breakdown.
        assert_eq!(m.model("a").requests.load(Ordering::Relaxed), 3);
        assert_eq!(m.model("b").requests.load(Ordering::Relaxed), 1);
        assert_eq!(a.latency_summary().unwrap().n, 1);
        assert!(b.latency_summary().is_none());
        let names: Vec<String> = m.model_snapshot().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn quarantine_counts_keep_conservation_exact() {
        let m = Metrics::new();
        m.count_quarantined("a");
        m.count_quarantined("a");
        m.count_rollback("a");
        assert_eq!(m.quarantined.load(Ordering::Relaxed), 2);
        assert_eq!(m.errors.load(Ordering::Relaxed), 2, "each fast-fail is also an error");
        assert_eq!(m.rollbacks.load(Ordering::Relaxed), 1);
        let a = m.model("a");
        assert_eq!(a.quarantined.load(Ordering::Relaxed), 2);
        assert_eq!(a.errors.load(Ordering::Relaxed), 2);
        assert_eq!(a.rollbacks.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn uptime_advances() {
        let m = Metrics::new();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(m.uptime_ms() >= 1);
    }

    #[test]
    fn idle_secs_tracks_touch() {
        let mm = ModelMetrics::default();
        assert!(mm.idle_secs().is_none());
        mm.touch();
        let idle = mm.idle_secs().unwrap();
        assert!(idle >= 0.0 && idle < 1.0, "{idle}");
    }

    #[test]
    fn batch_ids_are_unique_and_occupancy_recorded() {
        let m = Metrics::new();
        assert_eq!(m.record_batch(4), 1);
        assert_eq!(m.record_batch(8), 2);
        assert_eq!(m.record_batch(1), 3);
        let occ = m.batch_occupancy.summary().unwrap();
        assert_eq!(occ.n, 3);
        assert_eq!(occ.min, 1.0);
        assert_eq!(occ.max, 8.0);
    }

    #[test]
    fn stages_record_independently() {
        let m = Metrics::new();
        m.stages.record(Stage::QueueWait, 0.001);
        m.stages.record(Stage::QueueWait, 0.002);
        m.stages.record(Stage::Execute, 0.010);
        assert_eq!(m.stages.summary(Stage::QueueWait).unwrap().n, 2);
        assert_eq!(m.stages.summary(Stage::Execute).unwrap().n, 1);
        assert!(m.stages.summary(Stage::BatchForm).is_none());
        assert!(m.stages.summary(Stage::ReplyWrite).is_none());
    }
}
