//! Flight recorder: a bounded ring buffer of structured request
//! lifecycle events, drained over the protocol via `{"op":"trace"}`.
//!
//! Every stage of a request's life — admission, enqueue, batch
//! formation, execution, reply — plus shedding, expiry, and deployment
//! transitions (quarantine, canary, swap, rollback) drops one
//! [`TraceEvent`] stamped with a monotonic microsecond clock and the
//! request/batch ids involved. The buffer has **overwrite-oldest**
//! semantics: memory is fixed at `capacity` slots and a writer *never*
//! blocks on a full buffer — it claims the next sequence number with
//! one atomic increment and overwrites that slot. The per-slot mutex
//! only serializes two writers that collide on the same slot (capacity
//! apart in sequence) or a writer with a concurrent snapshot, both
//! bounded critical sections of a few copies.
//!
//! Request ids are client-chosen (the protocol's `"id"` field), so they
//! are correlation hints, not unique keys — two in-flight requests that
//! share an id trace interleaved. Batch ids are server-minted and
//! unique.

use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};
use std::time::Instant;

/// What happened. `name()` is the wire spelling used by the
/// `{"op":"trace"}` event filter and the JSON log stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Request validated and admitted toward the batcher.
    Admit,
    /// Request joined its model's sub-queue.
    Enqueue,
    /// Request shed by bounded admission (overload).
    Shed,
    /// Request outwaited its deadline and was failed at formation.
    Expired,
    /// A model-homogeneous batch was sealed (batch id minted here).
    BatchFormed,
    /// Worker began executing a batch.
    ExecStart,
    /// Worker finished executing a batch.
    ExecEnd,
    /// A request's result (or structured failure) was delivered.
    Reply,
    /// Circuit breaker tripped: the slot fast-fails at admission.
    Quarantined,
    /// A half-open probe succeeded; the slot serves again.
    Recovered,
    /// A canary generation survived its watch and was promoted.
    CanaryPromoted,
    /// A canary generation breached its error budget and rolled back.
    CanaryRolledBack,
    /// A generation was hot-swapped in (`swap`/`load` on a live name).
    Swap,
    /// An operator rollback restored a retained generation.
    Rollback,
    /// A connection negotiated binary wire framing (HELLO → HELLO_ACK).
    Negotiate,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Admit => "admit",
            EventKind::Enqueue => "enqueue",
            EventKind::Shed => "shed",
            EventKind::Expired => "expired",
            EventKind::BatchFormed => "batch_formed",
            EventKind::ExecStart => "exec_start",
            EventKind::ExecEnd => "exec_end",
            EventKind::Reply => "reply",
            EventKind::Quarantined => "quarantined",
            EventKind::Recovered => "recovered",
            EventKind::CanaryPromoted => "canary_promoted",
            EventKind::CanaryRolledBack => "canary_rolled_back",
            EventKind::Swap => "swap",
            EventKind::Rollback => "rollback",
            EventKind::Negotiate => "negotiate",
        }
    }
}

/// One recorded lifecycle event.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Monotonic sequence number (1-based; gaps mean overwritten).
    pub seq: u64,
    /// Microseconds since the recorder was created.
    pub t_us: u64,
    pub kind: EventKind,
    /// Slot name ("" for unrouted factory-mode traffic).
    pub model: String,
    /// Client-chosen request id (0 = not request-scoped).
    pub request_id: u64,
    /// Server-minted batch id (0 = not batch-scoped).
    pub batch_id: u64,
    /// Free-form context (row counts, reasons, versions).
    pub detail: String,
}

impl TraceEvent {
    /// Wire shape for `{"op":"trace"}` replies and `--log-json` lines.
    /// Zero ids and empty details are omitted.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("seq", Json::Num(self.seq as f64)),
            ("t_us", Json::Num(self.t_us as f64)),
            ("event", Json::Str(self.kind.name().into())),
        ];
        if !self.model.is_empty() {
            fields.push(("model", Json::Str(self.model.clone())));
        }
        if self.request_id != 0 {
            fields.push(("request_id", Json::Num(self.request_id as f64)));
        }
        if self.batch_id != 0 {
            fields.push(("batch_id", Json::Num(self.batch_id as f64)));
        }
        if !self.detail.is_empty() {
            fields.push(("detail", Json::Str(self.detail.clone())));
        }
        Json::obj(fields)
    }
}

/// The bounded ring buffer. Embedded in [`super::metrics::Metrics`] so
/// every serving layer that already carries the metrics handle can
/// record without new plumbing.
pub struct FlightRecorder {
    epoch: Instant,
    /// Total events ever recorded; slot = (seq - 1) % capacity.
    seq: AtomicU64,
    enabled: AtomicBool,
    /// The slot vector is only swapped by [`FlightRecorder::configure`]
    /// (server startup); the record path takes the read lock, which is
    /// uncontended everywhere else.
    slots: RwLock<Vec<Mutex<Option<TraceEvent>>>>,
}

impl FlightRecorder {
    /// A recorder with `capacity` slots (0 = disabled: recording is a
    /// cheap no-op until `configure` grows it).
    pub fn new(capacity: usize) -> FlightRecorder {
        FlightRecorder {
            epoch: Instant::now(),
            seq: AtomicU64::new(0),
            enabled: AtomicBool::new(capacity > 0),
            slots: RwLock::new((0..capacity).map(|_| Mutex::new(None)).collect()),
        }
    }

    /// Replace the ring with `capacity` fresh slots (0 disables).
    /// Previously recorded events are discarded; the sequence counter
    /// keeps running so `dropped` accounting stays monotonic.
    pub fn configure(&self, capacity: usize) {
        *self.slots.write().unwrap() = (0..capacity).map(|_| Mutex::new(None)).collect();
        self.enabled.store(capacity > 0, Ordering::Relaxed);
    }

    /// Runtime kill switch (capacity stays allocated).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed) && self.capacity() > 0
    }

    pub fn capacity(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    /// Total events recorded since startup (including overwritten).
    pub fn recorded(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Drop one event into the ring (overwrites the oldest at
    /// capacity; never blocks on a full buffer).
    pub fn record(
        &self,
        kind: EventKind,
        model: &str,
        request_id: u64,
        batch_id: u64,
        detail: &str,
    ) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        let slots = self.slots.read().unwrap();
        if slots.is_empty() {
            return;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let event = TraceEvent {
            seq,
            t_us: self.epoch.elapsed().as_micros() as u64,
            kind,
            model: model.to_string(),
            request_id,
            batch_id,
            detail: detail.to_string(),
        };
        *slots[(seq as usize - 1) % slots.len()].lock().unwrap() = Some(event);
    }

    /// Non-destructive snapshot of everything currently retained, in
    /// sequence order (oldest first).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let slots = self.slots.read().unwrap();
        let mut events: Vec<TraceEvent> = slots
            .iter()
            .filter_map(|s| s.lock().unwrap().clone())
            .collect();
        events.sort_by_key(|e| e.seq);
        events
    }

    /// How many recorded events are no longer retained (overwritten or
    /// discarded by a reconfigure).
    pub fn dropped(&self) -> u64 {
        let retained = self.snapshot().len() as u64;
        self.recorded().saturating_sub(retained)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn records_in_order_with_ids() {
        let r = FlightRecorder::new(16);
        r.record(EventKind::Admit, "m", 7, 0, "");
        r.record(EventKind::Enqueue, "m", 7, 0, "");
        r.record(EventKind::BatchFormed, "m", 0, 1, "n=1");
        let events = r.snapshot();
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].kind, EventKind::Admit);
        assert_eq!(events[0].request_id, 7);
        assert_eq!(events[2].batch_id, 1);
        assert!(events.windows(2).all(|w| w[0].seq < w[1].seq));
        assert!(events.windows(2).all(|w| w[0].t_us <= w[1].t_us));
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn overflow_keeps_the_newest_events() {
        let r = FlightRecorder::new(8);
        for i in 1..=20u64 {
            r.record(EventKind::Enqueue, "m", i, 0, "");
        }
        let events = r.snapshot();
        assert_eq!(events.len(), 8);
        let ids: Vec<u64> = events.iter().map(|e| e.request_id).collect();
        assert_eq!(ids, (13..=20).collect::<Vec<_>>(), "oldest overwritten");
        assert_eq!(r.recorded(), 20);
        assert_eq!(r.dropped(), 12);
    }

    #[test]
    fn zero_capacity_and_disable_are_cheap_no_ops() {
        let r = FlightRecorder::new(0);
        assert!(!r.is_enabled());
        r.record(EventKind::Admit, "m", 1, 0, "");
        assert!(r.snapshot().is_empty());
        let r = FlightRecorder::new(4);
        r.set_enabled(false);
        r.record(EventKind::Admit, "m", 1, 0, "");
        assert!(r.snapshot().is_empty());
        r.set_enabled(true);
        r.record(EventKind::Admit, "m", 2, 0, "");
        assert_eq!(r.snapshot().len(), 1);
    }

    #[test]
    fn configure_resizes_and_disables() {
        let r = FlightRecorder::new(4);
        r.record(EventKind::Admit, "m", 1, 0, "");
        r.configure(2);
        assert!(r.snapshot().is_empty(), "reconfigure discards history");
        r.record(EventKind::Admit, "m", 2, 0, "");
        r.record(EventKind::Admit, "m", 3, 0, "");
        r.record(EventKind::Admit, "m", 4, 0, "");
        assert_eq!(r.snapshot().len(), 2);
        r.configure(0);
        assert!(!r.is_enabled());
        r.record(EventKind::Admit, "m", 5, 0, "");
        assert!(r.snapshot().is_empty());
    }

    /// The satellite contract: many concurrent writers hammer a tiny
    /// ring and every write completes promptly (no writer ever blocks
    /// on a "full" buffer — there is no full state, only overwrite),
    /// while the newest events survive.
    #[test]
    fn concurrent_hammer_never_blocks_writers() {
        let r = Arc::new(FlightRecorder::new(64));
        let start = Instant::now();
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    for i in 0..5_000u64 {
                        r.record(EventKind::Enqueue, "hammer", t * 10_000 + i, 0, "");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(r.recorded(), 40_000);
        assert!(
            start.elapsed() < std::time::Duration::from_secs(10),
            "writers must not serialize on a full buffer"
        );
        let events = r.snapshot();
        assert_eq!(events.len(), 64, "ring stays at capacity");
        // Every retained event is from the newest window of sequence
        // numbers (overwrite-oldest, not overwrite-random).
        assert!(events.iter().all(|e| e.seq > 40_000 - 64));
    }

    #[test]
    fn event_json_omits_zero_ids() {
        let e = TraceEvent {
            seq: 3,
            t_us: 12,
            kind: EventKind::Shed,
            model: "m".into(),
            request_id: 0,
            batch_id: 0,
            detail: String::new(),
        };
        let j = e.to_json().to_string();
        assert!(j.contains("\"event\":\"shed\""), "{j}");
        assert!(!j.contains("request_id"), "{j}");
        assert!(!j.contains("batch_id"), "{j}");
        assert!(!j.contains("detail"), "{j}");
    }
}
