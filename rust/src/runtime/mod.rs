//! Artifact runtime: manifest parsing, host tensors, and (behind the
//! `pjrt` cargo feature) a PJRT client that loads AOT artifacts and
//! executes them with no Python at request time.
//!
//! * [`Tensor`] is the crate's host-side array: shape + f32/i32 data. It
//!   is always available — the training orchestrator and the uniform GS
//!   layout use it regardless of backend.
//! * [`manifest`] parses `artifacts/manifest.json` so the rest of the
//!   crate knows every artifact's signature without importing Python.
//! * `pjrt` feature only: [`Runtime`] wraps a `PjRtClient` (CPU);
//!   [`Executable`] wraps one compiled HLO module loaded from
//!   `artifacts/*.hlo.txt` (text is the interchange format — see
//!   `python/compile/aot.py`). The default build carries none of this —
//!   serving runs on the native execution engine
//!   ([`crate::kernels::exec`]) instead.

pub mod manifest;

use anyhow::{anyhow, Result};

pub use manifest::{Manifest, ModelManifest};

#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(feature = "pjrt")]
use std::path::Path;

/// Host-side tensor (f32 or i32), row-major.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::f32(shape, vec![0.0; shape.iter().product()])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// Convert to an XLA literal.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            Tensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Convert back from an XLA literal.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            ty => Err(anyhow!("unsupported artifact element type {ty:?}")),
        }
    }
}

/// A PJRT client that loads and compiles HLO-text artifacts.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// CPU client (the only backend in this environment).
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {path:?}"))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// One compiled artifact; `run` executes it on host tensors.
#[cfg(feature = "pjrt")]
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with host tensors; artifacts are lowered with
    /// `return_tuple=True`, so the single output decomposes into the
    /// function's flat result list.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let out = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute {}", self.name))?;
        let result = out[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = result.to_tuple().context("decompose result tuple")?;
        parts.iter().map(Tensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Literal round-trips need a real XLA runtime; they only compile with
    // the `pjrt` feature and only pass against the real `xla` crate (the
    // offline stub errors by design).
    #[cfg(feature = "pjrt")]
    mod literal_roundtrips {
        use super::*;

        #[test]
        #[ignore = "requires the real xla crate (vendor/xla is a stub)"]
        fn tensor_literal_roundtrip_f32() {
            let t = Tensor::f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
            let lit = t.to_literal().unwrap();
            let back = Tensor::from_literal(&lit).unwrap();
            assert_eq!(back, t);
        }

        #[test]
        #[ignore = "requires the real xla crate (vendor/xla is a stub)"]
        fn tensor_literal_roundtrip_i32() {
            let t = Tensor::i32(&[4], vec![1, -2, 3, -4]);
            let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    fn tensor_scalar_shape() {
        let t = Tensor::scalar_f32(7.5);
        assert!(t.shape().is_empty());
        assert_eq!(t.as_f32().unwrap(), &[7.5]);
    }

    #[test]
    fn tensor_accessors() {
        let t = Tensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.as_f32().unwrap().len(), 4);
        let i = Tensor::i32(&[2], vec![5, 6]);
        assert!(i.as_f32().is_err());
    }

    #[test]
    fn shape_mismatch_panics() {
        let r = std::panic::catch_unwind(|| Tensor::f32(&[2, 2], vec![1.0]));
        assert!(r.is_err());
    }
}
