//! `artifacts/manifest.json` parsing (written by `python/compile/aot.py`).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One parameter's metadata.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub prunable: bool,
}

/// Batch input metadata.
#[derive(Clone, Debug)]
pub struct BatchSpec {
    pub x_shape: Vec<usize>,
    pub x_is_int: bool,
    pub y_shape: Vec<usize>,
    pub y_is_int: bool,
}

/// One micro model's artifact set.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub params: Vec<ParamSpec>,
    pub batch: BatchSpec,
    pub train_path: PathBuf,
    pub eval_path: PathBuf,
    pub lr: f64,
    /// Free-form config (vocab sizes etc.) from the model module.
    pub config: BTreeMap<String, f64>,
}

impl ModelManifest {
    pub fn n_prunable(&self) -> usize {
        self.params.iter().filter(|p| p.prunable).count()
    }

    pub fn cfg(&self, key: &str) -> Result<usize> {
        self.config
            .get(key)
            .map(|&v| v as usize)
            .ok_or_else(|| anyhow!("missing config key {key} in {}", self.name))
    }
}

/// The serving MLP artifact.
#[derive(Clone, Debug)]
pub struct MlpManifest {
    pub forward_path: PathBuf,
    pub config: BTreeMap<String, f64>,
}

impl MlpManifest {
    pub fn cfg(&self, key: &str) -> Result<usize> {
        self.config
            .get(key)
            .map(|&v| v as usize)
            .ok_or_else(|| anyhow!("missing mlp config key {key}"))
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
    pub mlp: MlpManifest,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("shape not an array"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect()
}

fn config_of(j: &Json) -> BTreeMap<String, f64> {
    match j {
        Json::Obj(m) => m
            .iter()
            .filter_map(|(k, v)| v.as_f64().map(|f| (k.clone(), f)))
            .collect(),
        _ => BTreeMap::new(),
    }
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read {dir:?}/manifest.json — run `make artifacts`"))?;
        let root = Json::parse(&text).context("parse manifest.json")?;

        let mut models = BTreeMap::new();
        let models_json = root
            .get("models")
            .and_then(|m| match m {
                Json::Obj(o) => Some(o),
                _ => None,
            })
            .ok_or_else(|| anyhow!("manifest missing models object"))?;
        for (name, mj) in models_json {
            let params = mj
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing params"))?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("param name"))?
                            .to_string(),
                        shape: shape_of(p.get("shape").ok_or_else(|| anyhow!("param shape"))?)?,
                        prunable: p
                            .get("prunable")
                            .and_then(Json::as_bool)
                            .unwrap_or(false),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let batch = mj.get("batch").ok_or_else(|| anyhow!("{name}: batch"))?;
            let xd = batch.get("x").ok_or_else(|| anyhow!("batch.x"))?;
            let yd = batch.get("y").ok_or_else(|| anyhow!("batch.y"))?;
            let is_int = |d: &Json| {
                d.get("dtype")
                    .and_then(Json::as_str)
                    .map(|s| s.contains("int"))
                    .unwrap_or(false)
            };
            models.insert(
                name.clone(),
                ModelManifest {
                    name: name.clone(),
                    params,
                    batch: BatchSpec {
                        x_shape: shape_of(xd.get("shape").unwrap_or(&Json::Null))?,
                        x_is_int: is_int(xd),
                        y_shape: shape_of(yd.get("shape").unwrap_or(&Json::Null))?,
                        y_is_int: is_int(yd),
                    },
                    train_path: dir.join(
                        mj.get("train")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("{name}: train path"))?,
                    ),
                    eval_path: dir.join(
                        mj.get("eval")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("{name}: eval path"))?,
                    ),
                    lr: mj.get("lr").and_then(Json::as_f64).unwrap_or(0.01),
                    config: config_of(mj.get("config").unwrap_or(&Json::Null)),
                },
            );
        }

        let mlp_json = root
            .get("mlp_forward")
            .ok_or_else(|| anyhow!("manifest missing mlp_forward"))?;
        let mlp = MlpManifest {
            forward_path: dir.join(
                mlp_json
                    .get("forward")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("mlp forward path"))?,
            ),
            config: config_of(mlp_json.get("config").unwrap_or(&Json::Null)),
        };

        Ok(Manifest { dir, models, mlp })
    }

    /// Default artifacts directory (repo-root relative with env override).
    pub fn default_dir() -> PathBuf {
        std::env::var("GS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The real manifest, when artifacts are built (skips otherwise so
    /// `cargo test` stays green pre-`make artifacts`).
    #[test]
    fn loads_real_manifest_when_present() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        for name in ["gnmt", "resnet", "jasper"] {
            let mm = m.models.get(name).unwrap_or_else(|| panic!("{name} missing"));
            assert!(!mm.params.is_empty());
            assert!(mm.n_prunable() > 0);
            assert!(mm.train_path.exists());
            assert!(mm.eval_path.exists());
        }
        assert!(m.mlp.forward_path.exists());
        assert!(m.mlp.cfg("gs_b").unwrap() > 0);
    }

    #[test]
    fn parses_synthetic_manifest() {
        let tmp = std::env::temp_dir().join(format!("gs-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(
            tmp.join("manifest.json"),
            r#"{"models":{"m":{"params":[{"name":"w","shape":[2,3],"prunable":true}],
                "batch":{"x":{"shape":[4,2],"dtype":"float32"},"y":{"shape":[4],"dtype":"int32"}},
                "train":"t.hlo.txt","eval":"e.hlo.txt","lr":0.5,
                "config":{"vocab":7}}},
                "mlp_forward":{"forward":"f.hlo.txt","config":{"gs_b":8}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&tmp).unwrap();
        let mm = &m.models["m"];
        assert_eq!(mm.params[0].shape, vec![2, 3]);
        assert!(mm.params[0].prunable);
        assert!(!mm.batch.x_is_int);
        assert!(mm.batch.y_is_int);
        assert_eq!(mm.cfg("vocab").unwrap(), 7);
        assert_eq!(mm.lr, 0.5);
        std::fs::remove_dir_all(&tmp).ok();
    }
}
