#![cfg_attr(feature = "simd", feature(portable_simd))]
//! # gs-sparse — Load-balanced Gather-Scatter Patterns for Sparse DNNs
//!
//! A full-stack reproduction of *"Load-balanced Gather-scatter Patterns for
//! Sparse Deep Neural Networks"* (Sun et al., 2021).
//!
//! The paper observes that fine-grained ("irregular") weight sparsity keeps
//! model accuracy but is slow on real hardware because the indirect
//! activation accesses it induces collide in banked scratchpad memories
//! (TCMs), while coarse block sparsity is fast but loses accuracy. The fix
//! is a family of *gather-scatter (GS) patterns*: fine-grained sparsity
//! constrained so that every group of `B` non-zero weights touches `B`
//! distinct TCM sub-banks (column indices mod `B` are a permutation), so a
//! gather/scatter engine fetches all matching activations in one
//! conflict-free access.
//!
//! This crate provides, in layers (see `DESIGN.md`):
//!
//! * [`sparse`] — the GS pattern family `GS(B,k)` (Definitions 4.1/4.2),
//!   the compact value/index/indptr(/rowmap) format (Fig. 3), baseline
//!   formats (CSR, block-sparse/BSR), and conversions.
//! * [`pruning`] — load-balanced magnitude pruning (Algorithm 3 and its
//!   vertical/hybrid/scatter generalizations) plus irregular and block
//!   baselines.
//! * [`sim`] — a cycle-level simulator of the paper's evaluation platform:
//!   banked TCM + gather/scatter engine + L1/L2/DRAM hierarchy + a SIMD
//!   issue model (substitute for the paper's Gem5 setup, §X).
//! * [`kernels`] — the paper's sparse kernels (Algorithms 1–2 and the
//!   kernel-shape-aware sparse convolution) in three guises: native f32
//!   (numerics oracle), the prepacked [`kernels::exec`] engine (the
//!   production CPU fast path: joined layout, batched, multi-threaded),
//!   and instrumented programs on [`sim`] (cycle counts).
//! * [`runtime`] — manifest parsing and host tensors; with the `pjrt`
//!   cargo feature, a PJRT CPU client that loads the AOT-compiled
//!   JAX/Pallas artifacts (`artifacts/*.hlo.txt`) and executes them;
//!   Python never runs at request time.
//! * [`train`] — the prune→retrain orchestrator reproducing the accuracy
//!   experiments (Figs. 1/5, Table I) on micro models.
//! * [`coordinator`] — a serving layer (router, dynamic batcher, worker
//!   pool, per-model metrics) exposing multi-model routed sparse-model
//!   inference over TCP.
//! * [`model_store`] — the `.gsm` versioned model artifact format
//!   (checksummed writer + validating reader), the `Arc`-swappable
//!   [`model_store::ModelSlot`] behind zero-downtime weight hot-swap, and
//!   the capacity-bounded LRU [`model_store::ModelStore`] registry behind
//!   multi-model serving.
//! * [`util`] / [`testing`] / [`bench`] — in-tree substrates (PRNG, JSON,
//!   CLI, thread pool, stats, property testing, bench harness). The build
//!   environment is offline, so these are implemented from scratch rather
//!   than pulled from crates.io.

pub mod bench;
pub mod coordinator;
pub mod kernels;
pub mod model_store;
pub mod pruning;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod testing;
pub mod train;
pub mod util;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
