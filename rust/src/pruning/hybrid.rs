//! Vertical / hybrid GS pattern selection (paper §VI).
//!
//! Per band of `B/k` consecutive rows the selection must keep exactly
//! `g·k` entries in every row and `g` entries in every column-residue
//! class, where `g` is the band's group budget. That is a transportation
//! polytope over the (row-slot × residue) cell grid; we maximize kept
//! magnitude with a greedy descent over globally sorted magnitudes (the
//! paper's "pick the first bucket entry with the maximum absolute weight
//! value in the available bucket pool"), then repair any residual quota
//! deficit with augmenting paths — within a cell it is always optimal to
//! keep a cell's largest entries, so cell state is just a count.

use super::baseline::irregular_threshold;
use crate::sparse::dense::{Dense, Mask};

/// Prune to `GS(B,k)` for `k < B` (vertical when `k = 1`).
pub fn prune_hybrid(w: &Dense, b: usize, k: usize, sparsity: f64) -> Mask {
    assert!(
        w.rows % (b / k) == 0,
        "rows {} not divisible by B/k = {}",
        w.rows,
        b / k
    );
    let threshold = irregular_threshold(w, sparsity);
    let band_rows = b / k;
    let mut mask = Mask::all_false(w.rows, w.cols);
    for band in 0..w.rows / band_rows {
        let rows: Vec<usize> = (band * band_rows..(band + 1) * band_rows).collect();
        let groups = band_budget(w, &rows, threshold, b, k);
        select_band(w, &rows, b, k, groups, &mut mask);
    }
    mask
}

/// Group budget for a band: entries above the irregular threshold, rounded
/// up to whole groups (mirroring Algorithm 3's `num_items -= B` loop),
/// capped at `cols/k` groups — the tightest quota that stays feasible:
/// per-row quota `g·k ≤ cols` and per-residue quota
/// `g ≤ (B/k)·(cols/B) = cols/k` (each of the `B/k` rows supplies `cols/B`
/// candidates per residue). Integrality of the transportation polytope
/// then guarantees an exact selection exists.
pub(crate) fn band_budget(w: &Dense, rows: &[usize], threshold: f32, b: usize, k: usize) -> usize {
    let num_items: usize = rows
        .iter()
        .map(|&r| w.row(r).iter().filter(|v| v.abs() > threshold).count())
        .sum();
    num_items.div_ceil(b).min(w.cols / k)
}

/// Select `groups` conflict-free groups in one band, writing into `mask`.
/// `rows` are the band's member rows (arbitrary for scatter).
pub(crate) fn select_band(
    w: &Dense,
    rows: &[usize],
    b: usize,
    k: usize,
    groups: usize,
    mask: &mut Mask,
) {
    if groups == 0 {
        return;
    }
    let band_rows = rows.len();
    debug_assert_eq!(band_rows, b / k);

    // Cell grid: cells[slot][res] = candidate columns sorted by |w| desc.
    // Within a cell the optimal selection of t entries is its top t, so the
    // selection state per cell is just `taken[slot][res]`.
    let mut cells: Vec<Vec<Vec<(f32, u32)>>> = vec![vec![Vec::new(); b]; band_rows];
    for (slot, &r) in rows.iter().enumerate() {
        for c in 0..w.cols {
            let v = w.at(r, c);
            cells[slot][c % b].push((v.abs(), c as u32));
        }
        for res in 0..b {
            cells[slot][res].sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        }
    }
    let mut taken = vec![vec![0usize; b]; band_rows];
    let mut need_row = vec![groups * k; band_rows];
    let mut need_res = vec![groups; b];

    // Greedy pass over globally sorted magnitudes. An entry is eligible
    // exactly when it is the next untaken entry of its cell.
    let mut order: Vec<(f32, usize, usize, usize)> = Vec::new(); // (abs, slot, res, rank)
    for slot in 0..band_rows {
        for res in 0..b {
            for (rank, &(a, _)) in cells[slot][res].iter().enumerate() {
                order.push((a, slot, res, rank));
            }
        }
    }
    order.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
    for &(_, slot, res, rank) in &order {
        if need_row[slot] > 0 && need_res[res] > 0 && taken[slot][res] == rank {
            taken[slot][res] += 1;
            need_row[slot] -= 1;
            need_res[res] -= 1;
        }
    }

    // Repair pass: augmenting paths until every quota is met. The quotas
    // are feasible by construction (groups ≤ cols/B), so augmentation
    // always succeeds; the assert guards the invariant.
    for slot in 0..band_rows {
        while need_row[slot] > 0 {
            let mut visited = vec![false; b];
            let ok = augment(slot, &cells, &mut taken, &mut need_res, &mut visited);
            assert!(ok, "quota repair failed — infeasible band (bug)");
            need_row[slot] -= 1;
        }
    }

    // Materialize the mask: each cell keeps its top `taken` columns.
    for (slot, &r) in rows.iter().enumerate() {
        for res in 0..b {
            for &(_, c) in cells[slot][res].iter().take(taken[slot][res]) {
                mask.set(r, c as usize, true);
            }
        }
    }
}

/// Find an augmenting path that adds one selection to row-slot `slot`:
/// either a residue with spare quota, or displace another slot's weakest
/// selection in a full residue and recursively re-home that slot.
fn augment(
    slot: usize,
    cells: &[Vec<Vec<(f32, u32)>>],
    taken: &mut Vec<Vec<usize>>,
    need_res: &mut Vec<usize>,
    visited: &mut Vec<bool>,
) -> bool {
    let b = need_res.len();
    for res in 0..b {
        if visited[res] || taken[slot][res] >= cells[slot][res].len() {
            continue; // no candidate left in this cell
        }
        visited[res] = true;
        if need_res[res] > 0 {
            taken[slot][res] += 1;
            need_res[res] -= 1;
            return true;
        }
        // Residue full: try to displace another slot's selection there.
        for other in 0..cells.len() {
            if other != slot && taken[other][res] > 0 {
                if augment(other, cells, taken, need_res, visited) {
                    // `other` gained a selection elsewhere; hand its slot
                    // in `res` to us. Quotas stay balanced, but `augment`
                    // consumed one `need_res` for other's new home, which
                    // is correct: net one extra selection overall.
                    taken[other][res] -= 1;
                    taken[slot][res] += 1;
                    return true;
                }
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::pattern::Pattern;
    use crate::util::prng::Prng;

    #[test]
    fn vertical_validates() {
        let mut rng = Prng::new(1);
        let w = Dense::random(32, 64, 1.0, &mut rng);
        let m = prune_hybrid(&w, 8, 1, 0.8);
        Pattern::Gs { b: 8, k: 1 }.validate(&m).unwrap();
    }

    #[test]
    fn hybrid_k2_and_k4_validate() {
        let mut rng = Prng::new(2);
        let w = Dense::random(32, 64, 1.0, &mut rng);
        for k in [2, 4] {
            let m = prune_hybrid(&w, 8, k, 0.75);
            Pattern::Gs { b: 8, k }.validate(&m).unwrap();
        }
    }

    #[test]
    fn sparsity_close_to_target() {
        let mut rng = Prng::new(3);
        let w = Dense::random(64, 128, 1.0, &mut rng);
        for &s in &[0.5, 0.8, 0.9] {
            let m = prune_hybrid(&w, 8, 2, s);
            assert!(
                (m.sparsity() - s).abs() < 0.06,
                "target {s} got {}",
                m.sparsity()
            );
        }
    }

    #[test]
    fn keeps_dominant_entries_when_feasible() {
        // Large values placed in a conflict-free arrangement must be kept.
        let mut w = Dense::zeros(4, 16);
        for c in 0..16 {
            for r in 0..4 {
                w.set(r, c, 0.01);
            }
        }
        // One group: rows 0..4 (B=4,k=1), residues 0..4 distinct.
        w.set(0, 0, 50.0);
        w.set(1, 5, 50.0);
        w.set(2, 10, 50.0);
        w.set(3, 15, 50.0);
        let m = prune_hybrid(&w, 4, 1, 0.9);
        assert!(m.at(0, 0) && m.at(1, 5) && m.at(2, 10) && m.at(3, 15));
        Pattern::Gs { b: 4, k: 1 }.validate(&m).unwrap();
    }

    #[test]
    fn augmentation_handles_adversarial_concentration() {
        // All the large weights of every row share residue 0 — the greedy
        // pass alone would blow the residue quota; the repair pass must
        // spread selections while still validating.
        let mut w = Dense::zeros(8, 64);
        let mut rng = Prng::new(4);
        for r in 0..8 {
            for c in 0..64 {
                let boost = if c % 8 == 0 { 100.0 } else { 1.0 };
                w.set(r, c, rng.gaussian_f32().abs() * boost + 0.001);
            }
        }
        for k in [1usize, 2, 4] {
            let m = prune_hybrid(&w, 8, k, 0.8);
            Pattern::Gs { b: 8, k }.validate(&m).unwrap();
        }
    }

    #[test]
    fn full_quota_tight_columns() {
        // cols == B with k=1: every (row, residue) cell holds exactly one
        // candidate — the tightest feasible instance. At zero sparsity the
        // whole matrix is keepable (8 groups of 8); at 0.5 every quota is
        // half-filled and the selection is forced through augmentation.
        let mut rng = Prng::new(5);
        let w = Dense::random(8, 8, 1.0, &mut rng);
        let dense_mask = prune_hybrid(&w, 8, 1, 0.0);
        Pattern::Gs { b: 8, k: 1 }.validate(&dense_mask).unwrap();
        assert_eq!(dense_mask.kept(), 64);

        let half = prune_hybrid(&w, 8, 1, 0.5);
        Pattern::Gs { b: 8, k: 1 }.validate(&half).unwrap();
        assert_eq!(half.kept(), 32);
    }
}
