//! Baseline pruners: irregular magnitude and Block(B,k).

use crate::sparse::dense::{Dense, Mask};
use crate::util::stats::percentile_f32;

/// Irregular magnitude pruning: keep the largest `1-sparsity` fraction of
/// |w| across the whole matrix (the paper's accuracy upper bound).
pub fn prune_irregular(w: &Dense, sparsity: f64) -> Mask {
    let keep = ((w.data.len() as f64) * (1.0 - sparsity)).round() as usize;
    let mut mask = Mask::all_false(w.rows, w.cols);
    if keep == 0 {
        return mask;
    }
    // O(n) selection instead of a full sort (EXPERIMENTS.md §Perf): find
    // the keep-th largest (|w|, index-desc) and mark its left partition.
    let mut order: Vec<usize> = (0..w.data.len()).collect();
    if keep < order.len() {
        order.select_nth_unstable_by(keep - 1, |&a, &b| {
            w.data[b]
                .abs()
                .partial_cmp(&w.data[a].abs())
                .unwrap()
                .then(a.cmp(&b))
        });
    }
    for &i in order.iter().take(keep) {
        mask.data[i] = true;
    }
    mask
}

/// The magnitude threshold "as if the pattern is irregular" (Algorithm 3
/// line 2): the `sparsity`-percentile of |w|.
pub fn irregular_threshold(w: &Dense, sparsity: f64) -> f32 {
    let abs: Vec<f32> = w.data.iter().map(|v| v.abs()).collect();
    percentile_f32(&abs, sparsity)
}

/// Block(B,k) pruning: score each aligned `B/k × k` block by its L1 norm
/// and keep the top `1-sparsity` fraction of blocks.
pub fn prune_block(w: &Dense, b: usize, k: usize, sparsity: f64) -> Mask {
    let br = b / k;
    assert!(
        w.rows % br == 0 && w.cols % k == 0,
        "shape {}x{} not divisible by block {br}x{k}",
        w.rows,
        w.cols
    );
    let bands = w.rows / br;
    let bcols = w.cols / k;
    let mut scores: Vec<(f32, usize)> = Vec::with_capacity(bands * bcols);
    for band in 0..bands {
        for bc in 0..bcols {
            let mut s = 0.0f32;
            for r in band * br..(band + 1) * br {
                for c in bc * k..(bc + 1) * k {
                    s += w.at(r, c).abs();
                }
            }
            scores.push((s, band * bcols + bc));
        }
    }
    let keep = ((scores.len() as f64) * (1.0 - sparsity)).round() as usize;
    scores.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    let mut mask = Mask::all_false(w.rows, w.cols);
    for &(_, id) in scores.iter().take(keep) {
        let band = id / bcols;
        let bc = id % bcols;
        for r in band * br..(band + 1) * br {
            for c in bc * k..(bc + 1) * k {
                mask.set(r, c, true);
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::pattern::Pattern;
    use crate::util::prng::Prng;

    #[test]
    fn irregular_keeps_largest() {
        let w = Dense::from_vec(1, 4, vec![0.1, -5.0, 0.2, 3.0]);
        let m = prune_irregular(&w, 0.5);
        assert!(!m.at(0, 0) && m.at(0, 1) && !m.at(0, 2) && m.at(0, 3));
    }

    #[test]
    fn irregular_exact_count() {
        let mut rng = Prng::new(1);
        let w = Dense::random(10, 10, 1.0, &mut rng);
        let m = prune_irregular(&w, 0.9);
        assert_eq!(m.kept(), 10);
    }

    #[test]
    fn threshold_is_percentile() {
        let w = Dense::from_vec(1, 10, (1..=10).map(|i| i as f32).collect());
        let t = irregular_threshold(&w, 0.5);
        assert!((t - 5.5).abs() < 1e-5);
    }

    #[test]
    fn block_mask_validates_and_prefers_heavy_blocks() {
        let mut w = Dense::zeros(4, 8);
        // Heavy block at rows 0..4 cols 0..1 for Block(4,1) (4x1 blocks).
        for r in 0..4 {
            w.set(r, 0, 10.0);
            w.set(r, 3, 0.1);
            w.set(r, 5, 0.2);
        }
        let m = prune_block(&w, 4, 1, 0.875); // keep 1 of 8 blocks
        Pattern::Block { b: 4, k: 1 }.validate(&m).unwrap();
        for r in 0..4 {
            assert!(m.at(r, 0));
        }
        assert_eq!(m.kept(), 4);
    }

    #[test]
    fn block_horizontal_shape() {
        let mut rng = Prng::new(3);
        let w = Dense::random(8, 32, 1.0, &mut rng);
        let m = prune_block(&w, 8, 8, 0.75);
        Pattern::Block { b: 8, k: 8 }.validate(&m).unwrap();
        assert_eq!(m.kept(), 8 * 8); // 32 blocks, keep 8, each 8 wide
    }
}
