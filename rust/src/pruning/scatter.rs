//! GS scatter pattern selection (paper §VI).
//!
//! "Instead of forming a group from the data in consecutive rows, we first
//! sort all rows based on the number of entries above the threshold … then
//! group entries from the neighboring sorted rows": rows with similar
//! above-threshold counts are banded together, so the per-band budget
//! wastes little (the rounding/imbalance cost of banding dissimilar rows
//! is what the scatter pattern exists to avoid).
//!
//! Banding is done twice: a provisional pass by above-threshold count
//! fixes each band's budget, then rows are re-sorted by their *final* kept
//! count (ties by row index) and bands re-formed in that order. The second
//! pass makes the banding canonical — reconstructible from the mask alone
//! — so [`Pattern::validate`] and [`GsFormat::from_dense`] (which sort by
//! kept-nnz) recover exactly the bands the pruner used.

use super::baseline::irregular_threshold;
use super::hybrid::{band_budget, select_band};
use crate::sparse::dense::{Dense, Mask};

/// Prune to `GS_scatter(B,k)`.
pub fn prune_scatter(w: &Dense, b: usize, k: usize, sparsity: f64) -> Mask {
    let band_rows = b / k;
    assert!(
        w.rows % band_rows == 0,
        "rows {} not divisible by B/k = {band_rows}",
        w.rows
    );
    let threshold = irregular_threshold(w, sparsity);
    let nbands = w.rows / band_rows;

    // Pass 1: provisional banding by above-threshold count → budgets.
    let counts: Vec<usize> = (0..w.rows)
        .map(|r| w.row(r).iter().filter(|v| v.abs() > threshold).count())
        .collect();
    let mut order: Vec<usize> = (0..w.rows).collect();
    order.sort_by_key(|&r| (counts[r], r));
    let mut kept = vec![0usize; w.rows]; // final kept count per row
    for band in 0..nbands {
        let rows = &order[band * band_rows..(band + 1) * band_rows];
        let groups = band_budget(w, rows, threshold, b, k);
        for &r in rows {
            kept[r] = groups * k;
        }
    }

    // Pass 2: canonical banding by (kept, index); budgets are uniform
    // within a band by construction, so re-banding within equal-kept runs
    // is harmless and makes the banding a pure function of the mask.
    order.sort_by_key(|&r| (kept[r], r));
    let mut mask = Mask::all_false(w.rows, w.cols);
    for band in 0..nbands {
        let rows = order[band * band_rows..(band + 1) * band_rows].to_vec();
        let groups = kept[rows[0]] / k;
        debug_assert!(rows.iter().all(|&r| kept[r] == groups * k));
        select_band(w, &rows, b, k, groups, &mut mask);
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::format::GsFormat;
    use crate::sparse::pattern::Pattern;
    use crate::util::prng::Prng;

    #[test]
    fn scatter_validates() {
        let mut rng = Prng::new(1);
        let w = Dense::random(32, 64, 1.0, &mut rng);
        for k in [1usize, 2] {
            let m = prune_scatter(&w, 8, k, 0.8);
            Pattern::GsScatter { b: 8, k }.validate(&m).unwrap();
        }
    }

    #[test]
    fn scatter_format_roundtrip() {
        let mut rng = Prng::new(2);
        let mut w = Dense::random(16, 64, 1.0, &mut rng);
        let m = prune_scatter(&w, 8, 1, 0.75);
        w.apply_mask(&m);
        let gs = GsFormat::from_dense(&w, Pattern::GsScatter { b: 8, k: 1 }).unwrap();
        gs.validate().unwrap();
        assert_eq!(gs.to_dense(), w);
    }

    #[test]
    fn handles_skewed_row_densities() {
        // Half the rows carry 10× heavier weights: consecutive banding
        // (plain vertical) would force the light rows to match the heavy
        // rows' budget; scatter bands like with like. Interleave them so
        // consecutive bands are maximally mismatched.
        let mut rng = Prng::new(3);
        let mut w = Dense::zeros(16, 64);
        for r in 0..16 {
            let scale = if r % 2 == 0 { 10.0 } else { 0.1 };
            for c in 0..64 {
                w.set(r, c, rng.gaussian_f32() * scale);
            }
        }
        let m = prune_scatter(&w, 8, 1, 0.75);
        Pattern::GsScatter { b: 8, k: 1 }.validate(&m).unwrap();
        // Heavy rows must keep more than light rows.
        let kept_heavy: usize = (0..16).step_by(2).map(|r| m.row_indices(r).len()).sum();
        let kept_light: usize = (1..16).step_by(2).map(|r| m.row_indices(r).len()).sum();
        assert!(
            kept_heavy > kept_light,
            "scatter failed to adapt budgets: heavy {kept_heavy} vs light {kept_light}"
        );
    }

    #[test]
    fn scatter_keeps_more_magnitude_than_vertical_on_skewed_rows() {
        // The motivating property: on rows with very different densities,
        // scatter's like-with-like banding preserves more magnitude than
        // consecutive banding at the same target sparsity.
        let mut rng = Prng::new(4);
        let mut w = Dense::zeros(16, 64);
        for r in 0..16 {
            let scale = if r % 2 == 0 { 5.0 } else { 0.05 };
            for c in 0..64 {
                w.set(r, c, rng.gaussian_f32() * scale);
            }
        }
        let mag = |m: &Mask| -> f64 {
            w.data
                .iter()
                .zip(&m.data)
                .filter(|(_, &keep)| keep)
                .map(|(&v, _)| v.abs() as f64)
                .sum()
        };
        let m_scatter = prune_scatter(&w, 8, 1, 0.8);
        let m_vertical = super::super::hybrid::prune_hybrid(&w, 8, 1, 0.8);
        assert!(
            mag(&m_scatter) >= mag(&m_vertical) * 0.999,
            "scatter {} < vertical {}",
            mag(&m_scatter),
            mag(&m_vertical)
        );
    }
}
