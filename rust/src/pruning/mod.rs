//! Pruning methodology (paper §VI).
//!
//! Given dense weights and a target sparsity, produce a keep-mask that
//! (a) satisfies the requested [`Pattern`] and (b) keeps the
//! largest-magnitude weights the pattern allows:
//!
//! * [`baseline`] — irregular magnitude pruning (the accuracy upper bound)
//!   and `Block(B,k)` pruning (the structured baseline).
//! * [`horizontal`] — Algorithm 3: per-row residue buckets, round-robin
//!   top-magnitude picks.
//! * [`hybrid`] — vertical (`k=1`) and hybrid (`1<k<B`) selection: greedy
//!   max-magnitude under per-row and per-residue quotas, with an
//!   augmenting-path fix-up so the quota polytope is always met exactly.
//! * [`scatter`] — rows sorted by above-threshold counts, banded as
//!   neighbors, then hybrid selection per band.

pub mod baseline;
pub mod horizontal;
pub mod hybrid;
pub mod scatter;

use crate::sparse::dense::{Dense, Mask};
use crate::sparse::pattern::Pattern;
use anyhow::{Context, Result};

/// Prune `weights` to `sparsity` (fraction of zeros, in `[0,1)`) under
/// `pattern`. The returned mask always validates against `pattern`; the
/// achieved sparsity matches the target up to the pattern's rounding
/// granularity (`B` per band for GS, one block for Block).
pub fn prune(weights: &Dense, pattern: Pattern, sparsity: f64) -> Result<Mask> {
    assert!((0.0..1.0).contains(&sparsity), "sparsity in [0,1)");
    pattern.check_params()?;
    let mask = match pattern {
        Pattern::Irregular => baseline::prune_irregular(weights, sparsity),
        Pattern::Block { b, k } => baseline::prune_block(weights, b, k, sparsity),
        Pattern::Gs { b, k } if k == b => horizontal::prune_horizontal(weights, b, sparsity),
        Pattern::Gs { b, k } => hybrid::prune_hybrid(weights, b, k, sparsity),
        Pattern::GsScatter { b, k } => scatter::prune_scatter(weights, b, k, sparsity),
    };
    pattern
        .validate(&mask)
        .with_context(|| format!("pruner produced an invalid {} mask (bug)", pattern.name()))?;
    Ok(mask)
}

/// Keep-count for a row/band of `len` weights at `sparsity`, rounded to a
/// multiple of `b` (a gather group is all-or-nothing). Uses
/// round-to-nearest so the achieved sparsity is unbiased across bands.
pub fn keep_count(len: usize, b: usize, sparsity: f64) -> usize {
    let want = (len as f64 * (1.0 - sparsity)).round() as usize;
    let rounded = (want as f64 / b as f64).round() as usize * b;
    rounded.min(len / b * b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn keep_count_rounds_to_group() {
        assert_eq!(keep_count(1024, 8, 0.9), 104); // 102.4 → 104 (13 groups)
        assert_eq!(keep_count(16, 4, 0.5), 8);
        assert_eq!(keep_count(16, 4, 0.95), 0); // 0.8 → round 1 → group 0
        assert_eq!(keep_count(10, 4, 0.0), 8); // capped at full groups
    }

    /// End-to-end: every pattern produces a valid mask at target sparsity.
    #[test]
    fn all_patterns_validate_and_hit_sparsity() {
        let mut rng = Prng::new(42);
        let w = Dense::random(32, 64, 1.0, &mut rng);
        let patterns = [
            Pattern::Irregular,
            Pattern::Block { b: 8, k: 8 },
            Pattern::Block { b: 8, k: 1 },
            Pattern::Gs { b: 8, k: 8 },
            Pattern::Gs { b: 8, k: 1 },
            Pattern::Gs { b: 8, k: 2 },
            Pattern::Gs { b: 8, k: 4 },
            Pattern::GsScatter { b: 8, k: 1 },
            Pattern::GsScatter { b: 8, k: 2 },
        ];
        for p in patterns {
            let mask = prune(&w, p, 0.75).unwrap();
            let got = mask.sparsity();
            assert!(
                (got - 0.75).abs() < 0.08,
                "{}: sparsity {got} too far from 0.75",
                p.name()
            );
        }
    }

    /// Higher sparsity never keeps more weights.
    #[test]
    fn sparsity_monotone() {
        let mut rng = Prng::new(7);
        let w = Dense::random(16, 64, 1.0, &mut rng);
        for p in [
            Pattern::Irregular,
            Pattern::Gs { b: 8, k: 8 },
            Pattern::Gs { b: 8, k: 1 },
            Pattern::Block { b: 8, k: 8 },
        ] {
            let k50 = prune(&w, p, 0.5).unwrap().kept();
            let k80 = prune(&w, p, 0.8).unwrap().kept();
            let k95 = prune(&w, p, 0.95).unwrap().kept();
            assert!(k50 >= k80 && k80 >= k95, "{} not monotone", p.name());
        }
    }

    /// GS patterns keep at least as much magnitude *per kept entry* as
    /// block at the same sparsity, and at most as much as irregular (the
    /// paper's motivating ordering, §II). Per-entry averages are compared
    /// because GS rounds keep-counts up to whole groups.
    #[test]
    fn kept_magnitude_ordering() {
        let mut rng = Prng::new(9);
        let w = Dense::random(32, 128, 1.0, &mut rng);
        let avg_mag = |mask: &Mask| -> f64 {
            let total: f64 = w
                .data
                .iter()
                .zip(&mask.data)
                .filter(|(_, &m)| m)
                .map(|(&v, _)| v.abs() as f64)
                .sum();
            total / mask.kept() as f64
        };
        let irr = avg_mag(&prune(&w, Pattern::Irregular, 0.8).unwrap());
        let gs = avg_mag(&prune(&w, Pattern::Gs { b: 8, k: 8 }, 0.8).unwrap());
        let blk = avg_mag(&prune(&w, Pattern::Block { b: 8, k: 8 }, 0.8).unwrap());
        assert!(gs <= irr * 1.001, "GS avg magnitude above irregular?");
        assert!(
            gs >= blk,
            "GS kept lighter entries than block ({gs:.3} < {blk:.3})"
        );
    }
}
