//! Algorithm 3: horizontal GS pattern selection.
//!
//! Per row: bucket entries by column residue mod B, sort each bucket by
//! descending |w|, then repeatedly pop the top of every bucket to form one
//! conflict-free group, until the row's keep budget (derived from the
//! irregular threshold, rounded to whole groups) is met.

use super::baseline::irregular_threshold;
use crate::sparse::dense::{Dense, Mask};

/// Prune to the GS horizontal pattern `GS(B,B)`.
pub fn prune_horizontal(w: &Dense, b: usize, sparsity: f64) -> Mask {
    let threshold = irregular_threshold(w, sparsity); // Alg. 3 line 2
    let mut mask = Mask::all_false(w.rows, w.cols);
    for row in 0..w.rows {
        // Lines 5-8: bucket (value, col) by col mod B.
        let mut buckets: Vec<Vec<(f32, usize)>> = vec![Vec::new(); b];
        for col in 0..w.cols {
            let v = w.at(row, col);
            buckets[col % b].push((v, col));
        }
        // Lines 9-11: sort each bucket by descending magnitude.
        for bucket in &mut buckets {
            bucket.sort_by(|a, b| b.0.abs().partial_cmp(&a.0.abs()).unwrap());
        }
        // Line 12: per-row budget from the global threshold…
        let num_items = w.row(row).iter().filter(|v| v.abs() > threshold).count();
        // …rounded *up* to whole groups as in the Alg. 3 loop structure
        // (`num_items -= B` per pass), capped by bucket capacity.
        let groups = num_items.div_ceil(b).min(w.cols / b);
        // Lines 13-18: pop the top entry of each bucket per group.
        for g in 0..groups {
            for bucket in buckets.iter() {
                let (_, col) = bucket[g];
                mask.set(row, col, true);
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::pattern::Pattern;
    use crate::util::prng::Prng;

    #[test]
    fn produces_valid_gs_horizontal() {
        let mut rng = Prng::new(1);
        let w = Dense::random(16, 64, 1.0, &mut rng);
        let m = prune_horizontal(&w, 8, 0.8);
        Pattern::Gs { b: 8, k: 8 }.validate(&m).unwrap();
    }

    #[test]
    fn keeps_top_entry_per_bucket() {
        // One dominant weight per residue class must survive.
        let mut w = Dense::zeros(1, 16);
        for res in 0..4 {
            w.set(0, 4 + res, 100.0); // columns 4..8 cover residues 0..4
        }
        for c in 0..16 {
            if w.at(0, c) == 0.0 {
                w.set(0, c, 0.01);
            }
        }
        let m = prune_horizontal(&w, 4, 0.75);
        for res in 0..4 {
            assert!(m.at(0, 4 + res), "dominant residue-{res} entry pruned");
        }
    }

    #[test]
    fn sparsity_close_to_target() {
        let mut rng = Prng::new(2);
        let w = Dense::random(32, 128, 1.0, &mut rng);
        for &s in &[0.5, 0.8, 0.9] {
            let m = prune_horizontal(&w, 8, s);
            assert!(
                (m.sparsity() - s).abs() < 0.06,
                "target {s}, got {}",
                m.sparsity()
            );
        }
    }

    #[test]
    fn rows_are_independent() {
        // A row of tiny weights next to a row of huge weights: the huge row
        // keeps more (its per-row count from the global threshold is higher).
        let mut w = Dense::zeros(2, 16);
        for c in 0..16 {
            w.set(0, c, 0.001 * (c + 1) as f32);
            w.set(1, c, 10.0 + c as f32);
        }
        let m = prune_horizontal(&w, 4, 0.5);
        let kept0 = (0..16).filter(|&c| m.at(0, c)).count();
        let kept1 = (0..16).filter(|&c| m.at(1, c)).count();
        assert!(kept1 > kept0);
        Pattern::Gs { b: 4, k: 4 }.validate(&m).unwrap();
    }

    #[test]
    fn full_density_cap() {
        let mut rng = Prng::new(3);
        let w = Dense::random(4, 16, 1.0, &mut rng);
        let m = prune_horizontal(&w, 4, 0.0);
        // Every group slot used: whole matrix kept.
        assert_eq!(m.kept(), 64);
    }
}
