//! One model's training state driven through the AOT artifacts.

#[cfg(feature = "pjrt")]
use super::data::TaskGen;
#[cfg(feature = "pjrt")]
use crate::pruning::prune as prune_mask;
#[cfg(feature = "pjrt")]
use crate::runtime::{Executable, ModelManifest, Runtime, Tensor};
use crate::sparse::dense::{Dense, Mask};
use crate::sparse::pattern::Pattern;
#[cfg(feature = "pjrt")]
use crate::util::prng::Prng;
#[cfg(feature = "pjrt")]
use anyhow::{Context, Result};

/// Training session: parameters + Adam state + masks + task generator,
/// with the train/eval artifacts compiled once.
#[cfg(feature = "pjrt")]
pub struct TrainSession {
    pub manifest: ModelManifest,
    train_exe: Executable,
    eval_exe: Executable,
    pub params: Vec<Tensor>,
    mstate: Vec<Tensor>,
    vstate: Vec<Tensor>,
    t: Tensor,
    /// Masks for prunable params, in spec order.
    pub masks: Vec<Tensor>,
    gen: TaskGen,
    rng: Prng,
}

#[cfg(feature = "pjrt")]
impl TrainSession {
    /// Initialize with Glorot-normal weights (zero biases), all-ones masks.
    pub fn new(rt: &Runtime, manifest: &ModelManifest, seed: u64) -> Result<TrainSession> {
        let train_exe = rt.load_hlo(&manifest.train_path)?;
        let eval_exe = rt.load_hlo(&manifest.eval_path)?;
        let mut rng = Prng::new(seed);
        let params: Vec<Tensor> = manifest
            .params
            .iter()
            .map(|p| {
                let n: usize = p.shape.iter().product();
                if p.shape.len() >= 2 {
                    let fan_in: usize = p.shape[..p.shape.len() - 1].iter().product();
                    let fan_out = p.shape[p.shape.len() - 1];
                    let scale = (2.0 / (fan_in + fan_out) as f32).sqrt();
                    Tensor::f32(&p.shape, rng.normal_vec(n, scale))
                } else {
                    Tensor::zeros(&p.shape)
                }
            })
            .collect();
        let zeros_like: Vec<Tensor> = manifest
            .params
            .iter()
            .map(|p| Tensor::zeros(&p.shape))
            .collect();
        let masks = manifest
            .params
            .iter()
            .filter(|p| p.prunable)
            .map(|p| Tensor::f32(&p.shape, vec![1.0; p.shape.iter().product()]))
            .collect();
        let gen = TaskGen::for_model(manifest, seed ^ 0xDA7A)?;
        Ok(TrainSession {
            manifest: manifest.clone(),
            train_exe,
            eval_exe,
            params,
            mstate: zeros_like.clone(),
            vstate: zeros_like,
            t: Tensor::scalar_f32(0.0),
            masks,
            gen,
            rng,
        })
    }

    fn train_inputs(&self, batch_x: Tensor, batch_y: Tensor) -> Vec<Tensor> {
        let mut inputs = Vec::new();
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.mstate.iter().cloned());
        inputs.extend(self.vstate.iter().cloned());
        inputs.push(self.t.clone());
        inputs.extend(self.masks.iter().cloned());
        inputs.push(batch_x);
        inputs.push(batch_y);
        inputs
    }

    /// Run `steps` train steps on fresh synthetic batches; returns losses.
    pub fn train_steps(&mut self, steps: usize) -> Result<Vec<f32>> {
        let n = self.params.len();
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let batch = self.gen.batch(&mut self.rng);
            let inputs = self.train_inputs(batch.x, batch.y);
            let mut out = self
                .train_exe
                .run(&inputs)
                .context("train step execution")?;
            anyhow::ensure!(out.len() == 3 * n + 2, "train output arity");
            let loss = out.pop().unwrap().as_f32()?[0];
            self.t = out.pop().unwrap();
            self.vstate = out.split_off(2 * n);
            self.mstate = out.split_off(n);
            self.params = out;
            losses.push(loss);
        }
        Ok(losses)
    }

    /// Evaluate on `batches` fresh batches; returns (mean loss, mean metric).
    pub fn eval(&mut self, batches: usize) -> Result<(f32, f32)> {
        let mut tot_loss = 0.0;
        let mut tot_metric = 0.0;
        for _ in 0..batches {
            let batch = self.gen.batch(&mut self.rng);
            let mut inputs = Vec::new();
            inputs.extend(self.params.iter().cloned());
            inputs.extend(self.masks.iter().cloned());
            inputs.push(batch.x);
            inputs.push(batch.y);
            let out = self.eval_exe.run(&inputs).context("eval execution")?;
            anyhow::ensure!(out.len() == 2, "eval output arity");
            tot_loss += out[0].as_f32()?[0];
            tot_metric += out[1].as_f32()?[0];
        }
        Ok((tot_loss / batches as f32, tot_metric / batches as f32))
    }

    /// Prune every prunable parameter to `sparsity` under `pattern`
    /// (adapted per tensor, see [`fit_pattern`]), zeroing the pruned
    /// weights and their Adam state.
    pub fn prune(&mut self, pattern: Pattern, sparsity: f64) -> Result<()> {
        let mut mask_idx = 0;
        for (pi, spec) in self.manifest.params.clone().iter().enumerate() {
            if !spec.prunable {
                continue;
            }
            let view = MatrixView::of(spec.name.as_str(), &spec.shape);
            let dense = view.extract(self.params[pi].as_f32()?);
            let fitted = fit_pattern(pattern, dense.rows, dense.cols);
            let mask = prune_mask(&dense, fitted, sparsity)
                .with_context(|| format!("pruning {}", spec.name))?;
            let flat_mask = view.restore_mask(&mask);
            // Write the mask tensor and zero pruned weights + Adam state.
            let mt = self.masks[mask_idx].as_f32_mut()?;
            for (m, &keep) in mt.iter_mut().zip(&flat_mask) {
                *m = if keep { 1.0 } else { 0.0 };
            }
            for tensor in [&mut self.params[pi], &mut self.mstate[pi], &mut self.vstate[pi]] {
                let data = tensor.as_f32_mut()?;
                for (v, &keep) in data.iter_mut().zip(&flat_mask) {
                    if !keep {
                        *v = 0.0;
                    }
                }
            }
            mask_idx += 1;
        }
        Ok(())
    }

    /// Capture the full mutable state (params, Adam state, masks, RNG), so
    /// sweeps can train dense once and fork per pattern/sparsity.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            params: self.params.clone(),
            mstate: self.mstate.clone(),
            vstate: self.vstate.clone(),
            t: self.t.clone(),
            masks: self.masks.clone(),
            rng: self.rng.clone(),
        }
    }

    /// Restore a [`Snapshot`] taken from this session.
    pub fn restore(&mut self, s: &Snapshot) {
        self.params = s.params.clone();
        self.mstate = s.mstate.clone();
        self.vstate = s.vstate.clone();
        self.t = s.t.clone();
        self.masks = s.masks.clone();
        self.rng = s.rng.clone();
    }

    /// Achieved weight sparsity over prunable parameters.
    pub fn sparsity(&self) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for m in &self.masks {
            let d = m.as_f32().unwrap();
            zeros += d.iter().filter(|&&v| v == 0.0).count();
            total += d.len();
        }
        if total == 0 {
            0.0
        } else {
            zeros as f64 / total as f64
        }
    }
}

/// A point-in-time copy of a session's mutable state.
#[cfg(feature = "pjrt")]
#[derive(Clone)]
pub struct Snapshot {
    params: Vec<Tensor>,
    mstate: Vec<Tensor>,
    vstate: Vec<Tensor>,
    t: Tensor,
    masks: Vec<Tensor>,
    rng: Prng,
}

/// How a parameter tensor maps to the Definition 4.1/4.2 matrix the
/// pattern constrains. `x @ W` layers are pruned on `Wᵀ` (the reduction
/// dimension — the activation index — must be the *column* so residues map
/// to TCM banks; Fig. 3 shows "transposed weight matrices"). OhwI/OLI conv
/// filters are already `O × (flat)` in row-major.
pub enum MatrixView {
    /// rows/cols of the tensor as stored (conv: O × hwI).
    Direct { rows: usize, cols: usize },
    /// Transposed 2-D matmul weight ([in, out] stored, pruned as [out, in]).
    Transposed { stored_rows: usize, stored_cols: usize },
}

impl MatrixView {
    pub fn of(name: &str, shape: &[usize]) -> MatrixView {
        if shape.len() > 2 || name.starts_with("conv") {
            MatrixView::Direct {
                rows: shape[0],
                cols: shape[1..].iter().product(),
            }
        } else {
            MatrixView::Transposed {
                stored_rows: shape[0],
                stored_cols: shape[1],
            }
        }
    }

    /// Extract the pattern-facing Dense matrix from flat tensor data.
    pub fn extract(&self, data: &[f32]) -> Dense {
        match *self {
            MatrixView::Direct { rows, cols } => Dense::from_vec(rows, cols, data.to_vec()),
            MatrixView::Transposed { stored_rows, stored_cols } => {
                let mut out = Dense::zeros(stored_cols, stored_rows);
                for r in 0..stored_rows {
                    for c in 0..stored_cols {
                        out.set(c, r, data[r * stored_cols + c]);
                    }
                }
                out
            }
        }
    }

    /// Map a pattern-space mask back to the stored tensor's flat layout.
    pub fn restore_mask(&self, mask: &Mask) -> Vec<bool> {
        match *self {
            MatrixView::Direct { .. } => mask.data.clone(),
            MatrixView::Transposed { stored_rows, stored_cols } => {
                let mut out = vec![false; stored_rows * stored_cols];
                for r in 0..stored_rows {
                    for c in 0..stored_cols {
                        out[r * stored_cols + c] = mask.at(c, r);
                    }
                }
                out
            }
        }
    }
}

/// Adapt a pattern to a tensor whose shape cannot host it: vertical/hybrid
/// GS (and vertical blocks) need `rows % (B/k) == 0`; when that fails we
/// fall back to the horizontal variant with the same `B` (documented in
/// DESIGN.md — affects only the tiny classifier heads of the micro
/// models). Block patterns additionally need `cols % k == 0`.
pub fn fit_pattern(pattern: Pattern, rows: usize, cols: usize) -> Pattern {
    match pattern {
        Pattern::Gs { b, k } if rows % (b / k) != 0 => Pattern::Gs { b, k: b },
        Pattern::GsScatter { b, k } if rows % (b / k) != 0 => Pattern::Gs { b, k: b },
        Pattern::Block { b, k } if rows % (b / k) != 0 || cols % k != 0 => {
            Pattern::Block { b, k: b }
        }
        p => p,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_view_transposed_roundtrip() {
        // Stored [2,3] (in=2, out=3) → pattern space [3,2].
        let data = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let view = MatrixView::of("out_w", &[2, 3]);
        let d = view.extract(&data);
        assert_eq!((d.rows, d.cols), (3, 2));
        assert_eq!(d.at(0, 0), 1.0); // stored (0,0)
        assert_eq!(d.at(2, 1), 6.0); // stored (1,2)
        let mut mask = Mask::all_false(3, 2);
        mask.set(2, 1, true);
        let flat = view.restore_mask(&mask);
        assert_eq!(flat, vec![false, false, false, false, false, true]);
    }

    #[test]
    fn matrix_view_conv_is_direct() {
        let view = MatrixView::of("conv1", &[4, 3, 3, 8]);
        match view {
            MatrixView::Direct { rows, cols } => {
                assert_eq!((rows, cols), (4, 72));
            }
            _ => panic!("conv must be direct"),
        }
    }

    #[test]
    fn fit_pattern_fallbacks() {
        // [10,16] head cannot host GS(8,1) bands of 8 rows.
        assert_eq!(
            fit_pattern(Pattern::Gs { b: 8, k: 1 }, 10, 16),
            Pattern::Gs { b: 8, k: 8 }
        );
        // Fits fine at 16 rows.
        assert_eq!(
            fit_pattern(Pattern::Gs { b: 8, k: 1 }, 16, 16),
            Pattern::Gs { b: 8, k: 1 }
        );
        assert_eq!(
            fit_pattern(Pattern::Block { b: 8, k: 1 }, 10, 16),
            Pattern::Block { b: 8, k: 8 }
        );
        assert_eq!(fit_pattern(Pattern::Irregular, 10, 16), Pattern::Irregular);
    }
}
