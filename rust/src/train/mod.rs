//! Prune→retrain orchestrator (paper §VI/§X, driving Figs. 1/5, Table I).
//!
//! Rust owns the whole experiment loop: it initializes parameters,
//! generates synthetic batches, executes the AOT train/eval artifacts via
//! PJRT, computes pattern masks with [`crate::pruning`], and applies the
//! paper's prune-from-dense / iterative-pruning schedules. Python never
//! runs here.

pub mod data;
pub mod experiments;
pub mod session;

pub use experiments::QualityResult;
#[cfg(feature = "pjrt")]
pub use experiments::run_quality;
#[cfg(feature = "pjrt")]
pub use session::TrainSession;
