//! Quality-experiment driver: dense train → (iterative) prune → retrain →
//! eval, the schedule behind Figs. 1/5 and Table I.

#[cfg(feature = "pjrt")]
use super::session::TrainSession;
#[cfg(feature = "pjrt")]
use crate::runtime::{ModelManifest, Runtime};
#[cfg(feature = "pjrt")]
use crate::sparse::pattern::Pattern;
#[cfg(feature = "pjrt")]
use anyhow::Result;

/// Steps for each phase; env-tunable so benches can trade time for fidelity.
#[derive(Clone, Copy, Debug)]
pub struct Schedule {
    pub dense_steps: usize,
    pub retrain_steps: usize,
    pub eval_batches: usize,
}

impl Default for Schedule {
    fn default() -> Self {
        let env = |k: &str, d: usize| {
            std::env::var(k).ok().and_then(|s| s.parse().ok()).unwrap_or(d)
        };
        Schedule {
            dense_steps: env("GS_DENSE_STEPS", 400),
            retrain_steps: env("GS_RETRAIN_STEPS", 250),
            eval_batches: env("GS_EVAL_BATCHES", 8),
        }
    }
}

/// Outcome of one quality run.
#[derive(Clone, Debug)]
pub struct QualityResult {
    pub model: String,
    pub pattern: String,
    pub target_sparsity: f64,
    pub achieved_sparsity: f64,
    pub loss: f32,
    /// Accuracy-like metric (higher better); benches convert to the
    /// paper's orientation (e.g. WER) when printing.
    pub metric: f32,
    pub dense_metric: f32,
}

/// The paper's pruning schedule: one-shot to moderate sparsity, iterative
/// through 80% for higher targets (§X: "the 90% sparsity model is
/// iteratively pruned from the 80%").
pub fn milestones(target: f64) -> Vec<f64> {
    if target > 0.85 {
        vec![0.8, target]
    } else {
        vec![target]
    }
}

/// Train dense, prune to `sparsity` under `pattern` (iteratively for high
/// targets), retrain after each prune, and evaluate.
///
/// `pattern = None` evaluates the dense baseline (no pruning phases).
#[cfg(feature = "pjrt")]
pub fn run_quality(
    rt: &Runtime,
    manifest: &ModelManifest,
    pattern: Option<Pattern>,
    sparsity: f64,
    schedule: Schedule,
    seed: u64,
) -> Result<QualityResult> {
    let mut session = TrainSession::new(rt, manifest, seed)?;
    session.train_steps(schedule.dense_steps)?;
    let (_, dense_metric) = session.eval(schedule.eval_batches)?;

    if let Some(pattern) = pattern {
        for s in milestones(sparsity) {
            session.prune(pattern, s)?;
            session.train_steps(schedule.retrain_steps)?;
        }
    }
    let (loss, metric) = session.eval(schedule.eval_batches)?;
    Ok(QualityResult {
        model: manifest.name.clone(),
        pattern: pattern.map(|p| p.name()).unwrap_or_else(|| "Dense".into()),
        target_sparsity: if pattern.is_some() { sparsity } else { 0.0 },
        achieved_sparsity: session.sparsity(),
        loss,
        metric,
        dense_metric,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn milestones_match_paper_schedule() {
        assert_eq!(milestones(0.8), vec![0.8]);
        assert_eq!(milestones(0.9), vec![0.8, 0.9]);
        assert_eq!(milestones(0.95), vec![0.8, 0.95]);
        assert_eq!(milestones(0.6), vec![0.6]);
    }
}
