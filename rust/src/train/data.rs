//! Synthetic task generators (the WMT/ImageNet/LibriSpeech substitutes).
//!
//! Each generator is a pure function of (manifest config, seed), so every
//! experiment row in EXPERIMENTS.md is reproducible. Train and eval draw
//! from the same distribution with disjoint seeds.

use crate::runtime::{ModelManifest, Tensor};
use crate::util::prng::Prng;
use anyhow::Result;

/// A generated batch.
pub struct Batch {
    pub x: Tensor,
    pub y: Tensor,
}

/// Task generator for one model family.
pub enum TaskGen {
    /// Sequence reversal over tokens `1..vocab` (micro-GNMT).
    Reversal { vocab: usize, seq: usize, batch: usize },
    /// Prototype classification: `x = proto[y] + σ·noise` (micro-ResNet /
    /// micro-Jasper). Prototypes are fixed by `proto_seed`.
    Prototype {
        classes: usize,
        feature_shape: Vec<usize>,
        batch: usize,
        protos: Vec<f32>,
        noise: f32,
    },
}

impl TaskGen {
    /// Build the generator matching a model manifest.
    pub fn for_model(m: &ModelManifest, proto_seed: u64) -> Result<TaskGen> {
        Ok(match m.name.as_str() {
            "gnmt" => TaskGen::Reversal {
                vocab: m.cfg("vocab")?,
                seq: m.cfg("seq")?,
                batch: m.cfg("batch")?,
            },
            "resnet" => {
                let classes = m.cfg("classes")?;
                let size = m.cfg("size")?;
                let in_ch = m.cfg("in_ch")?;
                let feature_shape = vec![size, size, in_ch];
                let n: usize = feature_shape.iter().product();
                let mut rng = Prng::new(proto_seed);
                TaskGen::Prototype {
                    classes,
                    feature_shape,
                    batch: m.cfg("batch")?,
                    protos: rng.normal_vec(classes * n, 1.0),
                    noise: 0.4,
                }
            }
            "jasper" => {
                let classes = m.cfg("classes")?;
                let seq = m.cfg("seq")?;
                let in_ch = m.cfg("in_ch")?;
                let feature_shape = vec![seq, in_ch];
                let n: usize = feature_shape.iter().product();
                let mut rng = Prng::new(proto_seed ^ 0x9E37);
                TaskGen::Prototype {
                    classes,
                    feature_shape,
                    batch: m.cfg("batch")?,
                    protos: rng.normal_vec(classes * n, 1.0),
                    noise: 0.5,
                }
            }
            other => anyhow::bail!("no task generator for model {other}"),
        })
    }

    /// Generate one batch from `rng`.
    pub fn batch(&self, rng: &mut Prng) -> Batch {
        match self {
            TaskGen::Reversal { vocab, seq, batch } => {
                let mut x = Vec::with_capacity(batch * seq);
                let mut y = Vec::with_capacity(batch * seq);
                for _ in 0..*batch {
                    let tokens: Vec<i32> =
                        (0..*seq).map(|_| rng.range(1, *vocab) as i32).collect();
                    x.extend(&tokens);
                    y.extend(tokens.iter().rev());
                }
                Batch {
                    x: Tensor::i32(&[*batch, *seq], x),
                    y: Tensor::i32(&[*batch, *seq], y),
                }
            }
            TaskGen::Prototype {
                classes,
                feature_shape,
                batch,
                protos,
                noise,
            } => {
                let n: usize = feature_shape.iter().product();
                let mut x = Vec::with_capacity(batch * n);
                let mut y = Vec::with_capacity(*batch);
                for _ in 0..*batch {
                    let class = rng.below(*classes);
                    y.push(class as i32);
                    let proto = &protos[class * n..(class + 1) * n];
                    x.extend(proto.iter().map(|&p| p + noise * rng.gaussian_f32()));
                }
                let mut shape = vec![*batch];
                shape.extend(feature_shape);
                Batch {
                    x: Tensor::f32(&shape, x),
                    y: Tensor::i32(&[*batch], y),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reversal_batches_reverse() {
        let gen = TaskGen::Reversal { vocab: 8, seq: 5, batch: 3 };
        let mut rng = Prng::new(1);
        let b = gen.batch(&mut rng);
        assert_eq!(b.x.shape(), &[3, 5]);
        let (x, y) = match (&b.x, &b.y) {
            (Tensor::I32 { data: x, .. }, Tensor::I32 { data: y, .. }) => (x, y),
            _ => panic!("wrong dtypes"),
        };
        for row in 0..3 {
            let xr = &x[row * 5..(row + 1) * 5];
            let yr = &y[row * 5..(row + 1) * 5];
            let rev: Vec<i32> = xr.iter().rev().copied().collect();
            assert_eq!(yr, rev.as_slice());
            assert!(xr.iter().all(|&t| (1..8).contains(&t)));
        }
    }

    #[test]
    fn prototype_batches_cluster_around_protos() {
        let mut rng = Prng::new(2);
        let protos = rng.normal_vec(4 * 6, 1.0);
        let gen = TaskGen::Prototype {
            classes: 4,
            feature_shape: vec![6],
            batch: 16,
            protos: protos.clone(),
            noise: 0.01,
        };
        let b = gen.batch(&mut rng);
        let (x, y) = match (&b.x, &b.y) {
            (Tensor::F32 { data: x, .. }, Tensor::I32 { data: y, .. }) => (x, y),
            _ => panic!("wrong dtypes"),
        };
        for i in 0..16 {
            let cls = y[i] as usize;
            let xi = &x[i * 6..(i + 1) * 6];
            let pi = &protos[cls * 6..(cls + 1) * 6];
            let dist: f32 = xi.iter().zip(pi).map(|(a, b)| (a - b).abs()).sum();
            assert!(dist < 0.5, "sample {i} far from its prototype: {dist}");
        }
    }

    #[test]
    fn generators_are_deterministic() {
        let gen = TaskGen::Reversal { vocab: 8, seq: 4, batch: 2 };
        let b1 = gen.batch(&mut Prng::new(5));
        let b2 = gen.batch(&mut Prng::new(5));
        assert_eq!(b1.x, b2.x);
        assert_eq!(b1.y, b2.y);
    }
}
