//! Sparse patterns and formats (paper §IV–§V).
//!
//! * [`dense`] — row-major dense matrices and masks (the substrate every
//!   format converts to/from and every kernel is checked against).
//! * [`pattern`] — the pattern family: irregular, `Block(B,k)`, `GS(B,k)`,
//!   `GS_scatter(B,k)` with the Definition 4.1 validators.
//! * [`format`] — the compact gather-scatter format of Fig. 3(b)(d):
//!   `value` / `index` / `indptr` (+ `rowmap` for scatter), plus the joined
//!   value+index layout the paper suggests for cache locality.
//! * [`csr`] — CSR/COO baselines (used for the §IV bank-conflict claim).
//! * [`block`] — block-sparse (BSR-like) baseline for `Block(B,k)`.
//! * [`conv`] — Definition 4.2: OhwI/OLI filter flattening and the
//!   kernel-shape-aware engine offsets ((W−w)·C row adjustment, §V).

pub mod block;
pub mod conv;
pub mod csr;
pub mod dense;
pub mod format;
pub mod pattern;

pub use block::BlockSparse;
pub use csr::{Coo, Csr};
pub use dense::{Dense, Mask};
pub use format::GsFormat;
pub use pattern::{Pattern, PatternError};
