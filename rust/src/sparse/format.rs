//! The compact gather-scatter sparse format (paper §V, Fig. 3(b)(d)).
//!
//! Three arrays as in BSR, except `index` is two-dimensional like `value`:
//!
//! * `value[g*B + j]` — the j-th non-zero weight of group `g` (a *group* is
//!   the unit one gather serves: exactly `B` weights whose column indices
//!   are distinct modulo `B`, i.e. they touch `B` distinct TCM sub-banks).
//! * `index[g*B + j]` — the column index of that weight.
//! * `indptr[band]` — group counts per *band* (`B/k` consecutive rows):
//!   groups of band `i` are `indptr[i]..indptr[i+1]`. For the horizontal
//!   pattern (`k = B`) a band is one row, matching Algorithm 1; for the
//!   vertical pattern (`k = 1`) a band is `B` rows, matching Algorithm 2.
//! * `rowmap` — only for the scatter pattern: the actual matrix row behind
//!   each band row-slot (the paper's "fourth array to indicate the entries
//!   of the outputs").
//!
//! Within a group, entry `j` belongs to band row-slot `j / k`, so the SIMD
//! lane ↔ output row mapping of Algorithm 2 holds by construction.
//!
//! Group construction is a theorem, not a heuristic: a band satisfying
//! Definition 4.1 induces a bipartite multigraph (row-slots × residues)
//! that is `N/B`-regular after splitting each row into `k` virtual slots,
//! and König's theorem guarantees it decomposes into `N/B` perfect
//! matchings — each matching is one conflict-free gather group. We
//! implement the decomposition with Kuhn augmenting paths.

use super::dense::Dense;
use super::pattern::{Pattern, PatternError};
use anyhow::{bail, Context, Result};

/// Compact gather-scatter matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct GsFormat {
    /// Number of TCM sub-banks = group size.
    pub b: usize,
    /// Elements gathered per row within a group (`GS(B,k)`).
    pub k: usize,
    pub rows: usize,
    pub cols: usize,
    /// `ngroups * b` weight values, grouped.
    pub value: Vec<f32>,
    /// `ngroups * b` column indices; within a group, `index % b` is a
    /// permutation of `0..b`.
    pub index: Vec<u32>,
    /// `nbands + 1` cumulative group counts.
    pub indptr: Vec<u32>,
    /// Scatter only: actual row per band row-slot, `nbands * (b/k)` long.
    pub rowmap: Option<Vec<u32>>,
}

impl GsFormat {
    pub fn band_rows(&self) -> usize {
        self.b / self.k
    }

    pub fn nbands(&self) -> usize {
        self.indptr.len() - 1
    }

    pub fn ngroups(&self) -> usize {
        self.value.len() / self.b
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.value.len()
    }

    /// The matrix row that entry `j` of a group in `band` writes to.
    #[inline]
    pub fn entry_row(&self, band: usize, j: usize) -> usize {
        let slot = j / self.k;
        match &self.rowmap {
            Some(map) => map[band * self.band_rows() + slot] as usize,
            None => band * self.band_rows() + slot,
        }
    }

    /// Convert a masked dense matrix into the compact format.
    ///
    /// `pattern` must be `Gs{b,k}` or `GsScatter{b,k}` and `dense`'s
    /// non-zero mask must satisfy it (checked; returns the
    /// [`PatternError`] otherwise).
    pub fn from_dense(dense: &Dense, pattern: Pattern) -> Result<GsFormat> {
        let mask = dense.nonzero_mask();
        let (b, k, scatter) = match pattern {
            Pattern::Gs { b, k } => (b, k, false),
            Pattern::GsScatter { b, k } => (b, k, true),
            p => bail!("GsFormat requires a GS pattern, got {}", p.name()),
        };
        pattern
            .validate(&mask)
            .with_context(|| format!("mask does not satisfy {}", pattern.name()))?;

        let band_rows = b / k;
        let nbands = dense.rows / band_rows;

        // Band membership: identity for GS, nnz-sorted for scatter (mirrors
        // the scatter pruner and `validate_gs_scatter`).
        let band_members: Vec<Vec<usize>> = if scatter {
            let mut order: Vec<usize> = (0..dense.rows).collect();
            let nnz: Vec<usize> = (0..dense.rows)
                .map(|r| mask.row_indices(r).len())
                .collect();
            order.sort_by_key(|&r| (nnz[r], r));
            (0..nbands)
                .map(|i| order[i * band_rows..(i + 1) * band_rows].to_vec())
                .collect()
        } else {
            (0..nbands)
                .map(|i| (i * band_rows..(i + 1) * band_rows).collect())
                .collect()
        };

        let mut value = Vec::new();
        let mut index = Vec::new();
        let mut indptr = vec![0u32];
        let mut rowmap = Vec::new();

        for members in &band_members {
            let per_row: Vec<Vec<u32>> = members
                .iter()
                .map(|&r| mask.row_indices(r).iter().map(|&c| c as u32).collect())
                .collect();
            let groups = decompose_groups(&per_row, b, k)
                .map_err(|_| PatternError::NoValidPermutation)
                .context("group decomposition failed (mask passed validation — bug)")?;
            for group in &groups {
                for &(slot, col) in group {
                    value.push(dense.at(members[slot], col as usize));
                    index.push(col);
                }
            }
            indptr.push(indptr.last().unwrap() + groups.len() as u32);
            rowmap.extend(members.iter().map(|&r| r as u32));
        }

        Ok(GsFormat {
            b,
            k,
            rows: dense.rows,
            cols: dense.cols,
            value,
            index,
            indptr,
            rowmap: if scatter { Some(rowmap) } else { None },
        })
    }

    /// Expand back to dense (inverse of `from_dense` on the kept entries).
    pub fn to_dense(&self) -> Dense {
        let mut out = Dense::zeros(self.rows, self.cols);
        for band in 0..self.nbands() {
            for g in self.indptr[band] as usize..self.indptr[band + 1] as usize {
                for j in 0..self.b {
                    let col = self.index[g * self.b + j] as usize;
                    let row = self.entry_row(band, j);
                    out.set(row, col, self.value[g * self.b + j]);
                }
            }
        }
        out
    }

    /// Structural self-check: indptr monotonic & consistent, bands fit
    /// inside the matrix, residues within every group are a permutation
    /// of `0..b`, indices in range.
    pub fn validate(&self) -> Result<()> {
        if self.value.len() != self.index.len() {
            bail!("value/index length mismatch");
        }
        if self.value.len() % self.b != 0 {
            bail!("value length not a multiple of b");
        }
        if *self.indptr.last().unwrap() as usize != self.ngroups() {
            bail!("indptr total != ngroups");
        }
        // Non-scatter: band slots map to rows by identity, so the banded
        // range must fit (scatter rows are covered by the rowmap
        // permutation check below). Guards `entry_row`/`to_dense` and the
        // exec-plan row tables against hostile deserialized formats.
        if self.rowmap.is_none() && self.nbands() * self.band_rows() > self.rows {
            bail!(
                "{} bands of {} rows exceed the matrix's {} rows",
                self.nbands(),
                self.band_rows(),
                self.rows
            );
        }
        for w in self.indptr.windows(2) {
            if w[1] < w[0] {
                bail!("indptr not monotone");
            }
        }
        if let Some(map) = &self.rowmap {
            if map.len() != self.nbands() * self.band_rows() {
                bail!("rowmap length mismatch");
            }
            let mut seen = vec![false; self.rows];
            for &r in map {
                if r as usize >= self.rows || seen[r as usize] {
                    bail!("rowmap not a permutation");
                }
                seen[r as usize] = true;
            }
        }
        for g in 0..self.ngroups() {
            let mut hit = vec![false; self.b];
            for j in 0..self.b {
                let col = self.index[g * self.b + j] as usize;
                if col >= self.cols {
                    bail!("column index {col} out of range in group {g}");
                }
                let res = col % self.b;
                if hit[res] {
                    bail!("group {g} has a bank conflict at residue {res}");
                }
                hit[res] = true;
            }
        }
        Ok(())
    }

    /// The paper's cache-locality optimization: one joined buffer with each
    /// group's indices immediately followed by its values (bit-cast f32).
    /// Layout per group: `[idx; b] ++ [value.to_bits(); b]`.
    pub fn to_joined(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.value.len() * 2);
        for g in 0..self.ngroups() {
            out.extend_from_slice(&self.index[g * self.b..(g + 1) * self.b]);
            out.extend(
                self.value[g * self.b..(g + 1) * self.b]
                    .iter()
                    .map(|v| v.to_bits()),
            );
        }
        out
    }

    /// The joined layout at the paper's storage resolution (§X): `u16`
    /// column indices and IEEE binary16 values, halving the buffer's
    /// bytes. Requires `cols <= 65536` (checked by the plan builder;
    /// asserted here).
    pub fn to_joined_f16(&self) -> Vec<u16> {
        assert!(
            self.cols <= u16::MAX as usize + 1,
            "f16 joined layout indexes columns with u16"
        );
        let mut out = Vec::with_capacity(self.value.len() * 2);
        for g in 0..self.ngroups() {
            out.extend(
                self.index[g * self.b..(g + 1) * self.b]
                    .iter()
                    .map(|&i| i as u16),
            );
            out.extend(
                self.value[g * self.b..(g + 1) * self.b]
                    .iter()
                    .map(|&v| crate::util::f16::f32_to_f16_bits(v)),
            );
        }
        out
    }

    /// The format with every value rounded through f16 storage — the
    /// weights an f16 execution plan actually multiplies with. Oracle
    /// kernels on the quantized format are bit-identical to the f16 plan
    /// kernels.
    pub fn quantize_f16(&self) -> GsFormat {
        let mut q = self.clone();
        for v in &mut q.value {
            *v = crate::util::f16::f16_round(*v);
        }
        q
    }

    /// Compressed size in bytes assuming fp16 values + u16 indices (the
    /// paper's storage resolution, §X) plus u32 indptr (+ u32 rowmap).
    pub fn compact_bytes(&self) -> usize {
        self.value.len() * 2
            + self.index.len() * 2
            + self.indptr.len() * 4
            + self.rowmap.as_ref().map_or(0, |m| m.len() * 4)
    }
}

/// Decompose one band's entries into conflict-free gather groups.
///
/// `per_row[slot]` lists the column indices of band row-slot `slot`.
/// Returns groups of exactly `b` entries `(row_slot, col)`, each taking `k`
/// entries per row-slot with all residues distinct, ordered by row-slot.
pub fn decompose_groups(
    per_row: &[Vec<u32>],
    b: usize,
    k: usize,
) -> Result<Vec<Vec<(usize, u32)>>, ()> {
    let band_rows = b / k;
    assert_eq!(per_row.len(), band_rows);
    let n: usize = per_row.iter().map(|r| r.len()).sum();
    if n == 0 {
        return Ok(Vec::new());
    }
    if n % b != 0 {
        return Err(());
    }
    let d = n / b; // groups to extract = matchings to find

    // Edge list: (left = virtual row-slot, right = residue, col).
    // Each physical row-slot splits into k virtual slots; its edges are
    // distributed round-robin so every virtual slot has degree exactly d,
    // preserving regularity (see module docs).
    let mut edges: Vec<Vec<(usize, u32)>> = vec![Vec::new(); b]; // per left node: (residue, col)
    for (slot, cols) in per_row.iter().enumerate() {
        if cols.len() != d * k {
            return Err(()); // row imbalance
        }
        for (i, &col) in cols.iter().enumerate() {
            let vslot = slot * k + i % k;
            edges[vslot].push(((col as usize) % b, col));
        }
    }

    let mut groups = Vec::with_capacity(d);
    let mut used: Vec<Vec<bool>> = edges.iter().map(|e| vec![false; e.len()]).collect();

    for _ in 0..d {
        // Kuhn's augmenting-path matching: left = b virtual slots,
        // right = b residues, over unused edges.
        let mut match_right: Vec<Option<(usize, usize)>> = vec![None; b]; // residue -> (left, edge idx)
        for left in 0..b {
            let mut visited = vec![false; b];
            if !kuhn_augment(left, &edges, &used, &mut match_right, &mut visited) {
                return Err(()); // should not happen for a valid band
            }
        }
        // Extract the matching as one group; mark edges used.
        let mut group: Vec<(usize, u32)> = Vec::with_capacity(b);
        for (_residue, m) in match_right.iter().enumerate() {
            let (left, eidx) = m.ok_or(())?;
            let (_, col) = edges[left][eidx];
            used[left][eidx] = true;
            group.push((left / k, col)); // physical row-slot
        }
        group.sort_by_key(|&(slot, col)| (slot, col));
        groups.push(group);
    }
    Ok(groups)
}

/// Try to find an augmenting path from `left`.
fn kuhn_augment(
    left: usize,
    edges: &[Vec<(usize, u32)>],
    used: &[Vec<bool>],
    match_right: &mut Vec<Option<(usize, usize)>>,
    visited: &mut Vec<bool>,
) -> bool {
    for (eidx, &(residue, _)) in edges[left].iter().enumerate() {
        if used[left][eidx] || visited[residue] {
            continue;
        }
        visited[residue] = true;
        let free = match match_right[residue] {
            None => true,
            Some((other_left, _)) => kuhn_augment(other_left, edges, used, match_right, visited),
        };
        if free {
            match_right[residue] = Some((left, eidx));
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    /// Dense matrix from explicit entries.
    fn dense_from(rows: usize, cols: usize, entries: &[(usize, usize, f32)]) -> Dense {
        let mut d = Dense::zeros(rows, cols);
        for &(r, c, v) in entries {
            d.set(r, c, v);
        }
        d
    }

    #[test]
    fn horizontal_roundtrip_fig3a() {
        // Two rows in the style of Fig. 3(a): each row two groups of 4.
        let d = dense_from(
            2,
            16,
            &[
                (0, 0, 1.0),
                (0, 5, 2.0),
                (0, 10, 3.0),
                (0, 3, 4.0),
                (0, 4, 5.0),
                (0, 7, 6.0),
                (0, 13, 7.0),
                (0, 14, 8.0),
                (1, 8, 1.5),
                (1, 1, 2.5),
                (1, 6, 3.5),
                (1, 11, 4.5),
                (1, 12, 5.5),
                (1, 9, 6.5),
                (1, 2, 7.5),
                (1, 15, 8.5),
            ],
        );
        let gs = GsFormat::from_dense(&d, Pattern::Gs { b: 4, k: 4 }).unwrap();
        gs.validate().unwrap();
        assert_eq!(gs.ngroups(), 4);
        assert_eq!(gs.nbands(), 2);
        assert_eq!(gs.to_dense(), d);
    }

    #[test]
    fn vertical_roundtrip() {
        // B=4, k=1: 4 rows, 2 nnz each, residues balanced (2 per class).
        let d = dense_from(
            4,
            8,
            &[
                (0, 0, 1.0),
                (0, 5, 2.0),
                (1, 2, 3.0),
                (1, 7, 4.0),
                (2, 4, 5.0),
                (2, 1, 6.0),
                (3, 6, 7.0),
                (3, 3, 8.0),
            ],
        );
        let gs = GsFormat::from_dense(&d, Pattern::Gs { b: 4, k: 1 }).unwrap();
        gs.validate().unwrap();
        assert_eq!(gs.ngroups(), 2);
        assert_eq!(gs.nbands(), 1);
        assert_eq!(gs.to_dense(), d);
        // Vertical groups: entry j belongs to row-slot j (k = 1).
        for g in 0..gs.ngroups() {
            for j in 0..4 {
                assert_eq!(gs.entry_row(0, j), j);
            }
            let _ = g;
        }
    }

    #[test]
    fn hybrid_roundtrip() {
        // B=4, k=2: band of 2 rows, 2 nnz per group per row.
        let d = dense_from(
            2,
            8,
            &[
                (0, 0, 1.0),
                (0, 5, 2.0),
                (1, 2, 3.0),
                (1, 7, 4.0),
                (0, 1, 5.0),
                (0, 4, 6.0),
                (1, 3, 7.0),
                (1, 6, 8.0),
            ],
        );
        let gs = GsFormat::from_dense(&d, Pattern::Gs { b: 4, k: 2 }).unwrap();
        gs.validate().unwrap();
        assert_eq!(gs.to_dense(), d);
    }

    #[test]
    fn rejects_conflicting_mask() {
        let d = dense_from(1, 8, &[(0, 0, 1.0), (0, 4, 2.0), (0, 1, 3.0), (0, 2, 4.0)]);
        assert!(GsFormat::from_dense(&d, Pattern::Gs { b: 4, k: 4 }).is_err());
    }

    #[test]
    fn scatter_roundtrip_with_rowmap() {
        // Valid GS(4,1) rows, but shuffled so consecutive banding fails and
        // only the sorted (scatter) banding works. All rows have equal nnz
        // here, so scatter sorting is by index — use residue imbalance in
        // consecutive bands instead: rows 0..3 hold residues {0,0,1,1,...}.
        let d = dense_from(
            4,
            8,
            &[
                (0, 0, 1.0), // residue 0
                (1, 4, 2.0), // residue 0
                (2, 1, 3.0), // residue 1
                (3, 5, 4.0), // residue 1
                (0, 2, 5.0), // residue 2
                (1, 6, 6.0), // residue 2
                (2, 3, 7.0), // residue 3
                (3, 7, 8.0), // residue 3
            ],
        );
        // As a plain vertical GS this band *is* balanced; make sure scatter
        // also handles it and records a rowmap that is a permutation.
        let gs = GsFormat::from_dense(&d, Pattern::GsScatter { b: 4, k: 1 }).unwrap();
        gs.validate().unwrap();
        assert!(gs.rowmap.is_some());
        assert_eq!(gs.to_dense(), d);
    }

    #[test]
    fn joined_layout_interleaves() {
        let d = dense_from(1, 4, &[(0, 0, 1.0), (0, 1, 2.0), (0, 2, 3.0), (0, 3, 4.0)]);
        let gs = GsFormat::from_dense(&d, Pattern::Gs { b: 4, k: 4 }).unwrap();
        let joined = gs.to_joined();
        assert_eq!(joined.len(), 8);
        // First 4 entries are indices (a permutation of 0..4)…
        let mut idx = joined[..4].to_vec();
        idx.sort_unstable();
        assert_eq!(idx, vec![0, 1, 2, 3]);
        // …next 4 are f32 bit patterns of the values.
        let vals: Vec<f32> = joined[4..].iter().map(|&b| f32::from_bits(b)).collect();
        let mut sorted = vals.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(sorted, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn decompose_groups_regular_band_always_succeeds() {
        // Randomized regular bands must decompose (König).
        let mut rng = Prng::new(123);
        for &(b, k) in &[(4usize, 1usize), (4, 2), (4, 4), (8, 1), (8, 2), (8, 8), (16, 4)] {
            let band_rows = b / k;
            let d = 3; // groups per band
            // Build per-row column lists with exact residue balance: take a
            // random permutation of residues per group and map to columns.
            let cols_total = 8 * b;
            let mut per_row: Vec<Vec<u32>> = vec![Vec::new(); band_rows];
            for _ in 0..d {
                let mut residues: Vec<usize> = (0..b).collect();
                rng.shuffle(&mut residues);
                for (j, &res) in residues.iter().enumerate() {
                    let slot = j / k;
                    let mult = rng.below(cols_total / b);
                    per_row[slot].push((mult * b + res) as u32);
                }
            }
            let groups = decompose_groups(&per_row, b, k)
                .unwrap_or_else(|_| panic!("decompose failed for GS({b},{k})"));
            assert_eq!(groups.len(), d);
            for g in &groups {
                let mut hit = vec![false; b];
                for &(slot, col) in g {
                    assert!(slot < band_rows);
                    let res = col as usize % b;
                    assert!(!hit[res], "conflict in decomposed group");
                    hit[res] = true;
                }
            }
        }
    }

    #[test]
    fn compact_bytes_accounting() {
        let d = dense_from(1, 4, &[(0, 0, 1.0), (0, 1, 2.0), (0, 2, 3.0), (0, 3, 4.0)]);
        let gs = GsFormat::from_dense(&d, Pattern::Gs { b: 4, k: 4 }).unwrap();
        // 4 values*2B + 4 indices*2B + 2 indptr*4B = 8+8+8 = 24.
        assert_eq!(gs.compact_bytes(), 24);
    }
}
