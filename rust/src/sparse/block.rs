//! Block-sparse (BSR-like) baseline for `Block(B,k)` patterns.
//!
//! A block is `B/k` rows × `k` columns, aligned; the paper's *block
//! horizontal* is `Block(B,B)` (a 1×B run along the reduction dimension,
//! matching the SIMD width) and *block vertical* is `Block(B,1)`.

use super::dense::Dense;
use super::pattern::Pattern;
use anyhow::{bail, Context, Result};

/// Block compressed sparse row storage.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockSparse {
    pub b: usize,
    pub k: usize,
    pub rows: usize,
    pub cols: usize,
    /// `nblocks * b` values, block-major, row-major within a block.
    pub value: Vec<f32>,
    /// `nblocks` block-column indices (in units of `k` columns).
    pub index: Vec<u32>,
    /// `nbandrows + 1` cumulative block counts per block-row.
    pub indptr: Vec<u32>,
}

impl BlockSparse {
    pub fn block_rows(&self) -> usize {
        self.b / self.k
    }

    pub fn nblocks(&self) -> usize {
        self.index.len()
    }

    pub fn nnz(&self) -> usize {
        self.value.len()
    }

    /// Build from a dense matrix whose mask satisfies `Block(b,k)`.
    pub fn from_dense(d: &Dense, pattern: Pattern) -> Result<BlockSparse> {
        let (b, k) = match pattern {
            Pattern::Block { b, k } => (b, k),
            p => bail!("BlockSparse requires a Block pattern, got {}", p.name()),
        };
        pattern
            .validate(&d.nonzero_mask())
            .with_context(|| format!("mask does not satisfy {}", pattern.name()))?;
        let br = b / k;
        let mut value = Vec::new();
        let mut index = Vec::new();
        let mut indptr = vec![0u32];
        for r0 in (0..d.rows).step_by(br) {
            for c0 in (0..d.cols).step_by(k) {
                let nonzero = (r0..r0 + br).any(|r| (c0..c0 + k).any(|c| d.at(r, c) != 0.0));
                if nonzero {
                    for r in r0..r0 + br {
                        for c in c0..c0 + k {
                            value.push(d.at(r, c));
                        }
                    }
                    index.push((c0 / k) as u32);
                }
            }
            indptr.push(index.len() as u32);
        }
        Ok(BlockSparse {
            b,
            k,
            rows: d.rows,
            cols: d.cols,
            value,
            index,
            indptr,
        })
    }

    pub fn to_dense(&self) -> Dense {
        let mut out = Dense::zeros(self.rows, self.cols);
        let br = self.block_rows();
        for band in 0..self.indptr.len() - 1 {
            for blk in self.indptr[band] as usize..self.indptr[band + 1] as usize {
                let c0 = self.index[blk] as usize * self.k;
                for i in 0..br {
                    for j in 0..self.k {
                        let v = self.value[blk * self.b + i * self.k + j];
                        out.set(band * br + i, c0 + j, v);
                    }
                }
            }
        }
        out
    }

    /// spMV oracle (numerics; the cycle-level version lives in `kernels`).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let br = self.block_rows();
        let mut y = vec![0.0; self.rows];
        for band in 0..self.indptr.len() - 1 {
            for blk in self.indptr[band] as usize..self.indptr[band + 1] as usize {
                let c0 = self.index[blk] as usize * self.k;
                for i in 0..br {
                    let mut acc = 0.0;
                    for j in 0..self.k {
                        acc += self.value[blk * self.b + i * self.k + j] * x[c0 + j];
                    }
                    y[band * br + i] += acc;
                }
            }
        }
        y
    }

    /// Compressed size in bytes with fp16 values + u16 block indices + u32
    /// indptr (mirrors [`GsFormat::compact_bytes`] assumptions).
    pub fn compact_bytes(&self) -> usize {
        self.value.len() * 2 + self.index.len() * 2 + self.indptr.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    /// Random Block(b,k) matrix with `keep` fraction of blocks non-zero.
    pub fn random_block(
        rows: usize,
        cols: usize,
        b: usize,
        k: usize,
        keep: f64,
        seed: u64,
    ) -> Dense {
        let mut rng = Prng::new(seed);
        let br = b / k;
        let mut d = Dense::zeros(rows, cols);
        for r0 in (0..rows).step_by(br) {
            for c0 in (0..cols).step_by(k) {
                if rng.chance(keep) {
                    for r in r0..r0 + br {
                        for c in c0..c0 + k {
                            d.set(r, c, rng.gaussian_f32());
                        }
                    }
                }
            }
        }
        d
    }

    #[test]
    fn roundtrip_horizontal_blocks() {
        let d = random_block(8, 32, 4, 4, 0.3, 1);
        let bs = BlockSparse::from_dense(&d, Pattern::Block { b: 4, k: 4 }).unwrap();
        assert_eq!(bs.to_dense(), d);
    }

    #[test]
    fn roundtrip_vertical_blocks() {
        let d = random_block(8, 32, 4, 1, 0.3, 2);
        let bs = BlockSparse::from_dense(&d, Pattern::Block { b: 4, k: 1 }).unwrap();
        assert_eq!(bs.to_dense(), d);
        assert_eq!(bs.block_rows(), 4);
    }

    #[test]
    fn matvec_matches_dense() {
        let d = random_block(16, 64, 8, 4, 0.25, 3);
        let bs = BlockSparse::from_dense(&d, Pattern::Block { b: 8, k: 4 }).unwrap();
        let mut rng = Prng::new(4);
        let x = rng.normal_vec(64, 1.0);
        let want = d.matvec(&x);
        let got = bs.matvec(&x);
        for i in 0..16 {
            assert!((got[i] - want[i]).abs() < 1e-4, "row {i}");
        }
    }

    #[test]
    fn rejects_non_block_mask() {
        let mut d = Dense::zeros(4, 8);
        d.set(0, 0, 1.0); // lone element is not an aligned 1x4 block
        assert!(BlockSparse::from_dense(&d, Pattern::Block { b: 4, k: 4 }).is_err());
    }

    #[test]
    fn nnz_counts_block_payload() {
        let d = random_block(4, 16, 4, 4, 0.5, 5);
        let bs = BlockSparse::from_dense(&d, Pattern::Block { b: 4, k: 4 }).unwrap();
        assert_eq!(bs.nnz(), bs.nblocks() * 4);
    }
}
