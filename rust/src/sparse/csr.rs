//! CSR and COO baseline formats.
//!
//! These are the "canonical sparse formats" of §IV that the paper argues
//! cannot exploit the gather/scatter engine: consecutive CSR indices map to
//! arbitrary sub-banks, so gathers serialize. The §IV claim (2.8× accesses
//! in ascending order, 1.54× after per-row reordering, at 90% irregular
//! sparsity with 16 banks) is reproduced in `benches/ablation_patterns.rs`
//! using [`Csr::gather_accesses`] / [`Csr::gather_accesses_reordered`].

use super::dense::Dense;

/// Compressed sparse row.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub value: Vec<f32>,
    pub index: Vec<u32>,
    pub indptr: Vec<u32>,
}

impl Csr {
    /// Build from dense, keeping current non-zeros, indices ascending.
    pub fn from_dense(d: &Dense) -> Csr {
        let mut value = Vec::new();
        let mut index = Vec::new();
        let mut indptr = vec![0u32];
        for r in 0..d.rows {
            for c in 0..d.cols {
                let v = d.at(r, c);
                if v != 0.0 {
                    value.push(v);
                    index.push(c as u32);
                }
            }
            indptr.push(value.len() as u32);
        }
        Csr {
            rows: d.rows,
            cols: d.cols,
            value,
            index,
            indptr,
        }
    }

    pub fn to_dense(&self) -> Dense {
        let mut out = Dense::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for i in self.indptr[r] as usize..self.indptr[r + 1] as usize {
                out.set(r, self.index[i] as usize, self.value[i]);
            }
        }
        out
    }

    pub fn nnz(&self) -> usize {
        self.value.len()
    }

    /// spMV oracle.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|r| {
                (self.indptr[r] as usize..self.indptr[r + 1] as usize)
                    .map(|i| self.value[i] * x[self.index[i] as usize])
                    .sum()
            })
            .collect()
    }

    /// Gather accesses needed to stream each row through a `b`-bank engine
    /// taking indices **in ascending (stored) order**, `b` at a time: each
    /// batch of `b` consecutive indices costs `max_bank_occupancy` accesses
    /// (conflicts serialize).
    pub fn gather_accesses(&self, b: usize) -> usize {
        let mut total = 0;
        for r in 0..self.rows {
            let idx = &self.index[self.indptr[r] as usize..self.indptr[r + 1] as usize];
            for chunk in idx.chunks(b) {
                let mut occ = vec![0usize; b];
                for &c in chunk {
                    occ[c as usize % b] += 1;
                }
                total += occ.iter().max().copied().unwrap_or(0);
            }
        }
        total
    }

    /// Gather accesses after the §IV mitigation: indices in a row are
    /// reordered to minimize conflicts. Optimal per row: with residue
    /// histogram `h`, the minimum number of `b`-wide conflict-free-as-
    /// possible batches is `max(max(h), ceil(nnz/b))` — each batch can take
    /// at most one index per residue.
    pub fn gather_accesses_reordered(&self, b: usize) -> usize {
        let mut total = 0;
        for r in 0..self.rows {
            let idx = &self.index[self.indptr[r] as usize..self.indptr[r + 1] as usize];
            if idx.is_empty() {
                continue;
            }
            let mut h = vec![0usize; b];
            for &c in idx {
                h[c as usize % b] += 1;
            }
            let maxh = *h.iter().max().unwrap();
            let lower = idx.len().div_ceil(b);
            total += maxh.max(lower);
        }
        total
    }

    /// Accesses for a perfectly balanced pattern with the same nnz.
    pub fn gather_accesses_balanced(&self, b: usize) -> usize {
        (0..self.rows)
            .map(|r| {
                let n = (self.indptr[r + 1] - self.indptr[r]) as usize;
                n.div_ceil(b)
            })
            .sum()
    }
}

/// Coordinate list.
#[derive(Clone, Debug, PartialEq)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub entries: Vec<(u32, u32, f32)>,
}

impl Coo {
    pub fn from_dense(d: &Dense) -> Coo {
        let mut entries = Vec::new();
        for r in 0..d.rows {
            for c in 0..d.cols {
                let v = d.at(r, c);
                if v != 0.0 {
                    entries.push((r as u32, c as u32, v));
                }
            }
        }
        Coo {
            rows: d.rows,
            cols: d.cols,
            entries,
        }
    }

    pub fn to_dense(&self) -> Dense {
        let mut out = Dense::zeros(self.rows, self.cols);
        for &(r, c, v) in &self.entries {
            out.set(r as usize, c as usize, v);
        }
        out
    }

    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.rows];
        for &(r, c, v) in &self.entries {
            y[r as usize] += v * x[c as usize];
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn random_sparse(rows: usize, cols: usize, keep: f64, seed: u64) -> Dense {
        let mut rng = Prng::new(seed);
        let mut d = Dense::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                if rng.chance(keep) {
                    d.set(r, c, rng.gaussian_f32());
                }
            }
        }
        d
    }

    #[test]
    fn csr_roundtrip() {
        let d = random_sparse(13, 29, 0.2, 1);
        let csr = Csr::from_dense(&d);
        assert_eq!(csr.to_dense(), d);
        assert_eq!(csr.nnz(), d.nnz());
    }

    #[test]
    fn coo_roundtrip() {
        let d = random_sparse(7, 11, 0.3, 2);
        assert_eq!(Coo::from_dense(&d).to_dense(), d);
    }

    #[test]
    fn matvec_matches_dense() {
        let d = random_sparse(16, 24, 0.25, 3);
        let mut rng = Prng::new(4);
        let x = rng.normal_vec(24, 1.0);
        let want = d.matvec(&x);
        let got_csr = Csr::from_dense(&d).matvec(&x);
        let got_coo = Coo::from_dense(&d).matvec(&x);
        for i in 0..16 {
            assert!((got_csr[i] - want[i]).abs() < 1e-4);
            assert!((got_coo[i] - want[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn gather_accesses_orderings() {
        // Row with indices all ≡ 0 mod 4: ascending order serializes fully.
        let mut d = Dense::zeros(1, 32);
        for i in 0..8 {
            d.set(0, i * 4, 1.0);
        }
        let csr = Csr::from_dense(&d);
        // 8 indices in chunks of 4 → each chunk has occupancy 4 → 8 accesses.
        assert_eq!(csr.gather_accesses(4), 8);
        // Reordering cannot help when all residues collide: still 8.
        assert_eq!(csr.gather_accesses_reordered(4), 8);
        // Balanced lower bound: ceil(8/4) = 2.
        assert_eq!(csr.gather_accesses_balanced(4), 2);
    }

    #[test]
    fn reorder_helps_mixed_residues() {
        // Indices: residues [0,0,1,1,2,2,3,3] — ascending chunks of 4 give
        // occupancy 2 each → 4 accesses; reordered → 2 conflict-free.
        let mut d = Dense::zeros(1, 32);
        for (i, &c) in [0u32, 4, 1, 5, 2, 6, 3, 7].iter().enumerate() {
            let _ = i;
            d.set(0, c as usize, 1.0);
        }
        let csr = Csr::from_dense(&d);
        // stored ascending: [0,1,2,3,4,5,6,7] → chunks [0..4],[4..8]:
        // residues {0,1,2,3} each → no conflict → 2 accesses total.
        assert_eq!(csr.gather_accesses(4), 2);
        assert_eq!(csr.gather_accesses_reordered(4), 2);
    }
}
