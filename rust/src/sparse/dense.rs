//! Row-major dense matrices and boolean masks.

use crate::util::prng::Prng;

/// A row-major dense f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Dense {
    pub fn zeros(rows: usize, cols: usize) -> Dense {
        Dense {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Dense {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Dense { rows, cols, data }
    }

    /// iid N(0, scale²) entries — the stand-in weight initializer.
    pub fn random(rows: usize, cols: usize, scale: f32, rng: &mut Prng) -> Dense {
        Dense {
            rows,
            cols,
            data: rng.normal_vec(rows * cols, scale),
        }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Count of non-zero entries.
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }

    /// Fraction of zero entries.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / self.data.len() as f64
    }

    /// Element-wise multiply by a mask (prune in place).
    pub fn apply_mask(&mut self, mask: &Mask) {
        assert_eq!((self.rows, self.cols), (mask.rows, mask.cols));
        for (v, &keep) in self.data.iter_mut().zip(&mask.data) {
            if !keep {
                *v = 0.0;
            }
        }
    }

    /// Dense mat-vec: y = W x  (x has `cols` entries, y has `rows`).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|r| {
                self.row(r)
                    .iter()
                    .zip(x)
                    .map(|(&w, &a)| w * a)
                    .sum::<f32>()
            })
            .collect()
    }

    /// The mask of current non-zeros.
    pub fn nonzero_mask(&self) -> Mask {
        Mask {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v != 0.0).collect(),
        }
    }
}

/// A boolean keep/prune mask with the same layout as [`Dense`].
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<bool>,
}

impl Mask {
    pub fn all_true(rows: usize, cols: usize) -> Mask {
        Mask {
            rows,
            cols,
            data: vec![true; rows * cols],
        }
    }

    pub fn all_false(rows: usize, cols: usize) -> Mask {
        Mask {
            rows,
            cols,
            data: vec![false; rows * cols],
        }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> bool {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        self.data[r * self.cols + c] = v;
    }

    /// Number of kept (true) entries.
    pub fn kept(&self) -> usize {
        self.data.iter().filter(|&&b| b).count()
    }

    /// Fraction pruned.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.kept() as f64 / self.data.len() as f64
    }

    /// Column indices kept in row `r`.
    pub fn row_indices(&self, r: usize) -> Vec<usize> {
        (0..self.cols).filter(|&c| self.at(r, c)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_roundtrip() {
        let mut m = Dense::zeros(3, 4);
        m.set(2, 3, 7.5);
        assert_eq!(m.at(2, 3), 7.5);
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn matvec_matches_manual() {
        let w = Dense::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let y = w.matvec(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![6.0, 15.0]);
    }

    #[test]
    fn mask_application() {
        let mut w = Dense::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut mask = Mask::all_true(2, 2);
        mask.set(0, 1, false);
        mask.set(1, 0, false);
        w.apply_mask(&mask);
        assert_eq!(w.data, vec![1.0, 0.0, 0.0, 4.0]);
        assert!((w.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn nonzero_mask_roundtrip() {
        let w = Dense::from_vec(2, 2, vec![0.0, 2.0, 0.0, 4.0]);
        let m = w.nonzero_mask();
        assert_eq!(m.kept(), 2);
        assert!(m.at(0, 1) && m.at(1, 1));
        assert!(!m.at(0, 0) && !m.at(1, 0));
    }

    #[test]
    fn random_matrix_moments() {
        let mut rng = Prng::new(1);
        let w = Dense::random(64, 64, 0.5, &mut rng);
        let mean = w.data.iter().sum::<f32>() / 4096.0;
        assert!(mean.abs() < 0.05);
    }
}
