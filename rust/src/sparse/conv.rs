//! Definition 4.2: GS patterns for convolution filters.
//!
//! A 2-D conv weight tensor `W ∈ R^{O×h×w×I}` (OhwI layout, matching NHWC
//! activations) is projected to `R^{O×(hwI)}` with the input-channel
//! dimension scanned innermost; the flattened matrix then carries any GS
//! pattern. Because `I` is innermost and the activation feature map is
//! stored channel-innermost in the TCM, a flat filter index `f` maps to the
//! *engine offset* `f + kh·(W_act − w)·I` relative to the output pixel's
//! base address (the paper's "(W−w)C" row adjustment, §V) — the format is
//! kernel-shape aware. When `B | I` the offset adjustment is a multiple of
//! `B`, so bank residues are preserved and a conflict-free flattened group
//! stays conflict-free at the engine. 1-D conv (`O×L×I`) flattens the same
//! way and needs no adjustment.

use super::dense::Dense;
use super::format::GsFormat;
use super::pattern::Pattern;
use anyhow::{bail, Result};

/// Shape of a conv filter bank in OhwI layout (1-D conv: `h = 1`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvShape {
    pub out_ch: usize,
    pub h: usize,
    pub w: usize,
    pub in_ch: usize,
}

impl ConvShape {
    pub fn conv2d(out_ch: usize, h: usize, w: usize, in_ch: usize) -> ConvShape {
        ConvShape { out_ch, h, w, in_ch }
    }

    /// 1-D conv of kernel length `l` (Definition 4.2's O×L×I case).
    pub fn conv1d(out_ch: usize, l: usize, in_ch: usize) -> ConvShape {
        ConvShape {
            out_ch,
            h: 1,
            w: l,
            in_ch,
        }
    }

    /// Flattened reduction length `h·w·I`.
    pub fn flat_cols(&self) -> usize {
        self.h * self.w * self.in_ch
    }

    pub fn weight_len(&self) -> usize {
        self.out_ch * self.flat_cols()
    }

    /// Decompose a flat column index into (kh, kw, ic).
    #[inline]
    pub fn unflatten_col(&self, f: usize) -> (usize, usize, usize) {
        let ic = f % self.in_ch;
        let rest = f / self.in_ch;
        (rest / self.w, rest % self.w, ic)
    }

    /// Flat column index of (kh, kw, ic).
    #[inline]
    pub fn flatten_col(&self, kh: usize, kw: usize, ic: usize) -> usize {
        (kh * self.w + kw) * self.in_ch + ic
    }
}

/// The Definition 4.2 projection `f : R^{O×h×w×I} → R^{O×(hwI)}`.
/// `weights` is OhwI-ordered (I innermost).
pub fn flatten_filters(weights: &[f32], shape: ConvShape) -> Dense {
    assert_eq!(weights.len(), shape.weight_len(), "weight length mismatch");
    // OhwI with I innermost *is* row-major O×(hwI); the projection is a
    // reinterpretation, which is exactly why the pattern transfers.
    Dense::from_vec(shape.out_ch, shape.flat_cols(), weights.to_vec())
}

/// Inverse of [`flatten_filters`].
pub fn unflatten_filters(d: &Dense, shape: ConvShape) -> Vec<f32> {
    assert_eq!(d.rows, shape.out_ch);
    assert_eq!(d.cols, shape.flat_cols());
    d.data.clone()
}

/// A GS-compressed convolution filter bank with engine offsets baked for a
/// given activation width.
#[derive(Clone, Debug)]
pub struct GsConv {
    pub shape: ConvShape,
    /// The flattened-matrix GS format (indices are *flat filter columns*).
    pub gs: GsFormat,
}

impl GsConv {
    /// Compress OhwI weights under `pattern` (a GS pattern on the
    /// flattened matrix). Requires `B | I` so that bank residues survive
    /// the kernel-shape offset adjustment.
    pub fn from_weights(weights: &[f32], shape: ConvShape, pattern: Pattern) -> Result<GsConv> {
        let b = match pattern {
            Pattern::Gs { b, .. } | Pattern::GsScatter { b, .. } => b,
            p => bail!("GsConv requires a GS pattern, got {}", p.name()),
        };
        if shape.in_ch % b != 0 {
            bail!(
                "GS conv requires B | I for residue preservation (B={b}, I={})",
                shape.in_ch
            );
        }
        let flat = flatten_filters(weights, shape);
        let gs = GsFormat::from_dense(&flat, pattern)?;
        Ok(GsConv { shape, gs })
    }

    /// Engine offsets for every stored index, for an activation feature map
    /// of width `act_w` (NHWC, channel-innermost, stride 1): offset of
    /// entry relative to the output pixel's base address
    /// `((y·act_w)+x)·I`. This is the §V index adjustment
    /// `f + kh·(act_w − w)·I`.
    pub fn engine_offsets(&self, act_w: usize) -> Vec<u32> {
        assert!(act_w >= self.shape.w, "activation narrower than kernel");
        let adj = (act_w - self.shape.w) * self.shape.in_ch;
        self.gs
            .index
            .iter()
            .map(|&f| {
                let (kh, _, _) = self.shape.unflatten_col(f as usize);
                f + (kh * adj) as u32
            })
            .collect()
    }

    /// Check that engine offsets keep residues conflict-free per group
    /// (true by construction when `B | I`; exposed for tests/benches).
    pub fn offsets_conflict_free(&self, act_w: usize) -> bool {
        let offs = self.engine_offsets(act_w);
        let b = self.gs.b;
        offs.chunks(b).all(|group| {
            let mut hit = vec![false; b];
            group.iter().all(|&o| {
                let r = o as usize % b;
                !std::mem::replace(&mut hit[r], true)
            })
        })
    }
}

/// Direct (oracle) 2-D convolution, NHWC activations, OhwI weights,
/// stride 1, no padding. Returns NHWC output `(act_h-h+1)×(act_w-w+1)×O`
/// for a single image.
pub fn conv2d_reference(
    act: &[f32],
    act_h: usize,
    act_w: usize,
    weights: &[f32],
    shape: ConvShape,
) -> Vec<f32> {
    assert_eq!(act.len(), act_h * act_w * shape.in_ch);
    assert_eq!(weights.len(), shape.weight_len());
    let oh = act_h - shape.h + 1;
    let ow = act_w - shape.w + 1;
    let mut out = vec![0.0f32; oh * ow * shape.out_ch];
    for y in 0..oh {
        for x in 0..ow {
            for o in 0..shape.out_ch {
                let mut acc = 0.0;
                for kh in 0..shape.h {
                    for kw in 0..shape.w {
                        for ic in 0..shape.in_ch {
                            let a = act[((y + kh) * act_w + (x + kw)) * shape.in_ch + ic];
                            let wv =
                                weights[o * shape.flat_cols() + shape.flatten_col(kh, kw, ic)];
                            acc += a * wv;
                        }
                    }
                }
                out[(y * ow + x) * shape.out_ch + o] = acc;
            }
        }
    }
    out
}

/// Direct (oracle) 1-D convolution, (len × I) activations, O×L×I weights,
/// stride 1, no padding. Output `(len-L+1) × O`.
pub fn conv1d_reference(
    act: &[f32],
    act_len: usize,
    weights: &[f32],
    shape: ConvShape,
) -> Vec<f32> {
    assert_eq!(shape.h, 1, "use ConvShape::conv1d");
    conv2d_reference(act, 1, act_len, weights, shape)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn flatten_is_reinterpretation() {
        let shape = ConvShape::conv2d(2, 2, 2, 4);
        let w: Vec<f32> = (0..shape.weight_len()).map(|i| i as f32).collect();
        let d = flatten_filters(&w, shape);
        assert_eq!(d.rows, 2);
        assert_eq!(d.cols, 16);
        assert_eq!(unflatten_filters(&d, shape), w);
        // flat col of (kh=1, kw=0, ic=3) = (1*2+0)*4+3 = 11.
        assert_eq!(shape.flatten_col(1, 0, 3), 11);
        assert_eq!(shape.unflatten_col(11), (1, 0, 3));
    }

    #[test]
    fn engine_offsets_match_paper_example() {
        // Paper §V: 2×2 filter, 4 channels, first group indices
        // {0, 3, 6, WC+1} where the flat indices were {0,3,6,9}: flat 9 =
        // (kh=1,kw=0,ic=1) so offset = 9 + 1*(W-2)*4 = (W*4)+1 for act
        // width W. Construct that exact group.
        let shape = ConvShape::conv2d(1, 2, 2, 4);
        let mut w = vec![0.0f32; shape.weight_len()];
        for &f in &[0usize, 3, 6, 9] {
            w[f] = 1.0;
        }
        let gc = GsConv::from_weights(&w, shape, Pattern::Gs { b: 4, k: 4 }).unwrap();
        let act_w = 8;
        let offs = gc.engine_offsets(act_w);
        let mut offs_sorted = offs.clone();
        offs_sorted.sort_unstable();
        assert_eq!(offs_sorted, vec![0, 3, 6, (act_w as u32) * 4 + 1]);
        assert!(gc.offsets_conflict_free(act_w));
    }

    #[test]
    fn b_must_divide_in_ch() {
        let shape = ConvShape::conv2d(1, 2, 2, 3);
        let w = vec![1.0f32; shape.weight_len()];
        assert!(GsConv::from_weights(&w, shape, Pattern::Gs { b: 4, k: 4 }).is_err());
    }

    #[test]
    fn conv2d_reference_known_value() {
        // 1 output channel, 1x1 kernel, identity-ish check.
        let shape = ConvShape::conv2d(1, 1, 1, 2);
        let weights = vec![2.0, 3.0]; // o=0: w[ic=0]=2, w[ic=1]=3
        let act = vec![
            1.0, 1.0, /* pixel (0,0) */
            2.0, 0.5, /* pixel (0,1) */
        ];
        let out = conv2d_reference(&act, 1, 2, &weights, shape);
        assert_eq!(out, vec![5.0, 5.5]);
    }

    #[test]
    fn conv1d_matches_manual() {
        // O=1, L=2, I=1: simple correlation.
        let shape = ConvShape::conv1d(1, 2, 1);
        let weights = vec![1.0, -1.0];
        let act = vec![3.0, 5.0, 2.0];
        // out[t] = act[t]*1 + act[t+1]*(-1)
        assert_eq!(conv1d_reference(&act, 3, &weights, shape), vec![-2.0, 3.0]);
    }

    #[test]
    fn gsconv_roundtrip_preserves_values() {
        let mut rng = Prng::new(7);
        let shape = ConvShape::conv2d(4, 3, 3, 8);
        // Build weights whose flat mask is GS(8,8)-valid: per row take one
        // entry per residue class per group; simplest: first 8 flat columns
        // (residues 0..7).
        let mut w = vec![0.0f32; shape.weight_len()];
        for o in 0..4 {
            for j in 0..8 {
                w[o * shape.flat_cols() + j] = rng.gaussian_f32();
            }
        }
        let gc = GsConv::from_weights(&w, shape, Pattern::Gs { b: 8, k: 8 }).unwrap();
        let flat = gc.gs.to_dense();
        assert_eq!(unflatten_filters(&flat, shape), w);
    }
}
